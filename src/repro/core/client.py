"""SDFLMQ client logic (paper §III-C, Listing 1 API).

A client holds: a Role Arbiter (duties + topic subscriptions), a Model
Controller (per-session model repository), and the aggregation service.
The aggregation semantics are pluggable (repro.api.strategies): sessions
carry a strategy name, and every aggregator applies the same strategy hooks
the compiled collective path uses (core/aggregation.py).

"sum"-reduction strategies (fedavg, fedprox, fedadam) move *weighted
partial sums* up the cluster tree through MQTTFC — mathematically identical
to flat aggregation (property-tested).  A trainer publishes its raw model
into its leaf cluster's topic; cluster heads (which subscribe to their own
topic, so their own model self-delivers) accumulate ``expected`` inputs and
forward the partial sum to the parent cluster; the root finalizes once and
publishes the global model (retained).

"stack"-reduction strategies (trimmed_mean, coordinate_median) are not
decomposable into partial sums, so heads forward their collected
contributions unchanged; the root stacks everything and applies the robust
combine — permutation-invariant, hence bit-identical to the flat reference
no matter the tree shape.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.api.strategies import (AggregationStrategy, get_strategy,
                                  register_strategy)
from repro.core import topics as T
from repro.core.mqttfc import MQTTFC, raw_handler
from repro.core.roles import ClientAssignment, RoleArbiter
from repro.core.stats import ClientStats, local_stats

Params = dict[str, np.ndarray]


def weighted_add(acc: Optional[Params], p: Params, w: float) -> Params:
    if acc is None:
        return {k: np.asarray(v, np.float64) * w for k, v in p.items()}
    for k, v in p.items():
        acc[k] = acc[k] + np.asarray(v, np.float64) * w
    return acc


@dataclass
class _Accumulator:
    acc: Optional[Params] = None             # sum reduction: weighted sums
    entries: list = field(default_factory=list)   # stack reduction: raw
    weight: float = 0.0
    received: int = 0
    flushed: bool = False

    def restart(self) -> None:
        self.acc, self.weight, self.received = None, 0.0, 0
        self.entries = []
        self.flushed = False


@dataclass
class _SessionCtx:
    session_id: str
    model_name: str
    params: Optional[Params] = None
    weight: float = 1.0                      # FedAvg weight (sample count)
    strategy: str = "fedavg"                 # session-wide (from topology)
    global_params: Optional[Params] = None   # last global (strategy ref)
    server_state: Optional[dict] = None      # stateful strategies (fedadam)
    global_version: int = 0
    round_idx: int = 0
    accs: dict[str, _Accumulator] = field(default_factory=dict)
    tree: Optional[dict] = None
    terminated: bool = False
    peak_acc_bytes: int = 0                  # memory evaluation (paper §VI)
    stale_dropped: int = 0                   # late contributions discarded

    def acc_for(self, cluster_id: str) -> _Accumulator:
        return self.accs.setdefault(cluster_id, _Accumulator())

    def reset_round(self, round_idx: int) -> None:
        self.round_idx = round_idx
        self.accs.clear()


class ModelController:
    """Per-session model repository (paper: tracks local + global updates)."""

    def __init__(self):
        self.sessions: dict[str, _SessionCtx] = {}

    def get(self, sid: str) -> _SessionCtx:
        return self.sessions[sid]

    def ensure(self, sid: str, model_name: str) -> _SessionCtx:
        if sid not in self.sessions:
            self.sessions[sid] = _SessionCtx(sid, model_name)
        return self.sessions[sid]


class SDFLMQClient:
    """Mirrors the paper's SDFLMQ_Client (Listing 1).  ``broker`` is any
    repro.api.transport.Transport implementation."""

    def __init__(self, client_id: str, broker,
                 preferred_role: str = "trainer",
                 stats: Optional[ClientStats] = None):
        self.client_id = client_id
        self.preferred_role = preferred_role
        self.stats = stats or local_stats(client_id)
        self.fc = MQTTFC(broker, client_id, will_topic=T.will(client_id),
                         will_payload=_will_payload(client_id))
        self.arbiter = RoleArbiter(client_id)
        self.models = ModelController()
        self.on_global_update: Optional[Callable] = None
        self.on_round_start: Optional[Callable] = None
        self.fc.bind(T.client_ctrl(client_id), self._on_ctrl)

    # ------------------------------------------------------------------
    # Paper Listing-1 API
    # ------------------------------------------------------------------
    def create_fl_session(self, session_id: str, model_name: str,
                          fl_rounds: int, session_capacity_min: int,
                          session_capacity_max: int,
                          session_time_s: float = 3600.0,
                          waiting_time_s: float = 120.0,
                          preferred_role: Optional[str] = None,
                          strategy: str = "fedavg") -> None:
        strat = get_strategy(strategy)           # fail fast on unknown names
        if isinstance(strategy, str):
            strategy = strat.name
        else:
            # tuned instance: register under a session-scoped name so every
            # aggregator applies the same hyperparameters without touching
            # what the plain name resolves to for other sessions (a real
            # deployment registers the same factory on every node; the wire
            # carries the name)
            strategy = f"{strat.name}@{session_id}"
            register_strategy(strategy, lambda s=strat: s)
        ctx = self.models.ensure(session_id, model_name)
        ctx.strategy = strategy
        self._subscribe_session(session_id)
        self.fc.call(T.coord("create_session"), session_id, model_name,
                     self.client_id, fl_rounds, session_capacity_min,
                     session_capacity_max, session_time_s, waiting_time_s,
                     preferred_role or self.preferred_role,
                     self.stats.to_dict(), strategy=strategy)

    def join_fl_session(self, session_id: str, model_name: str,
                        fl_rounds: int = 0,
                        preferred_role: Optional[str] = None) -> None:
        self.models.ensure(session_id, model_name)
        self._subscribe_session(session_id)
        self.fc.call(T.coord("join_session"), session_id, self.client_id,
                     model_name, fl_rounds,
                     preferred_role or self.preferred_role,
                     self.stats.to_dict())

    def set_model(self, session_id: str, params: Params,
                  n_samples: int = 1) -> None:
        ctx = self.models.get(session_id)
        ctx.params = {k: np.asarray(v) for k, v in params.items()}
        ctx.weight = float(n_samples)

    def get_model(self, session_id: str) -> Params:
        return self.models.get(session_id).params

    def send_local(self, session_id: str) -> None:
        """Publish the locally trained model for global updating.  The
        cluster head's own copy self-delivers via its subscription."""
        ctx = self.models.get(session_id)
        asg = self.arbiter.assignment
        if asg is None or asg.train_cluster is None:
            raise RuntimeError(f"{self.client_id}: no trainer assignment yet")
        self.fc.call(T.cluster_agg(session_id, asg.train_cluster),
                     {"params": ctx.params, "weight": ctx.weight,
                      "sender": self.client_id, "partial": False,
                      "round": ctx.round_idx})

    def wait_global_update(self, session_id: str) -> Params:
        """Synchronous in the simulated broker: delivery already happened by
        the time send_local returned on the last contributor."""
        return self.models.get(session_id).params

    def leave(self, session_id: str) -> None:
        self.fc.call(T.coord("leave_session"), session_id, self.client_id)

    def fail(self) -> None:
        """Simulate abnormal death -> broker fires the LWT."""
        self.fc.close(graceful=False)

    def signal_ready(self, session_id: str,
                     stats: Optional[ClientStats] = None,
                     metrics: Optional[dict] = None) -> None:
        """Round-status update to the coordinator (paper §III-E4), stamped
        with the client's current round so a signal held back by the
        network can't count toward a later round."""
        st = (stats or self.stats).to_dict()
        ctx = self.models.sessions.get(session_id)
        self.fc.call(T.coord("client_ready"), session_id, self.client_id,
                     st, metrics or {},
                     round_idx=ctx.round_idx if ctx else None)

    # ------------------------------------------------------------------
    # Control-plane handlers
    # ------------------------------------------------------------------
    def _subscribe_session(self, session_id: str) -> None:
        self.fc.subscribe_raw(T.session_status(session_id),
                              raw_handler(self._on_status))
        self.fc.subscribe_raw(T.global_model(session_id),
                              raw_handler(self._on_global))

    def _on_ctrl(self, payload: dict) -> None:
        ev = payload.get("event")
        if ev == "role_assignment":
            asg = ClientAssignment.from_dict(payload["assignment"])
            to_unsub, to_sub = self.arbiter.update(asg)
            for t in to_unsub:
                self.fc.unbind(t)
            for t in to_sub:
                self.fc.subscribe_raw(t, raw_handler(self._on_cluster_input))

    def _on_status(self, topic: str, payload) -> None:
        body = _body(payload)
        sid = topic.split("/")[2]
        ctx = self.models.sessions.get(sid)
        if ctx is None:
            return
        ev = body.get("event")
        if ev == "topology":
            ctx.tree = body.get("tree")
            # session-wide strategy rides the retained topology broadcast
            ctx.strategy = body.get("strategy", ctx.strategy)
            # a (re)joining client syncs its round counter from the retained
            # topology, so its next contribution carries the live round
            rnd = body.get("round")
            if rnd is not None and rnd > ctx.round_idx:
                ctx.reset_round(rnd)
        elif ev == "round_start":
            ctx.reset_round(body.get("round", ctx.round_idx))
            if self.on_round_start:
                self.on_round_start(sid, ctx.round_idx)
        elif ev == "flush":
            lvl = body.get("level")
            for cid in list(ctx.accs):
                duty = self.arbiter.duty_for(cid)
                if duty is not None and (lvl is None or duty.level == lvl):
                    self._flush(sid, cid, force=True)
        elif ev == "session_terminated":
            ctx.terminated = True

    def _strategy_for(self, ctx: _SessionCtx) -> AggregationStrategy:
        return get_strategy(ctx.strategy)

    def _on_cluster_input(self, topic: str, payload) -> None:
        """Aggregation service: accumulate inputs for one duty under the
        session's strategy (weighted partial sums, or stacked raw
        contributions for robust strategies)."""
        body = _body(payload)
        parts = topic.split("/")       # sdflmq/session/<sid>/cluster/<cid>/agg
        sid, cluster_id = parts[2], parts[4]
        ctx = self.models.sessions.get(sid)
        duty = self.arbiter.duty_for(cluster_id)
        if ctx is None or duty is None:
            return
        # asynchronous delivery: a contribution held by a partition (or a
        # straggler's QoS-1 retransmission) can arrive after its round was
        # deadline-cut — drop it instead of polluting the current round
        rnd = body.get("round")
        if rnd is not None and rnd < ctx.round_idx:
            ctx.stale_dropped += 1
            return
        strat = self._strategy_for(ctx)
        a = ctx.acc_for(cluster_id)
        if a.flushed:        # new aggregation cycle starts on first input
            a.restart()
        w = float(body["weight"])
        if strat.reduction == "stack":
            if body.get("partial"):
                a.entries.extend(body["entries"])
            else:
                a.entries.append({"params": body["params"], "weight": w})
        else:
            if body.get("partial"):
                a.acc = weighted_add(a.acc, body["params"], 1.0)
            else:
                contrib = strat.premap(body["params"], ctx.global_params, np)
                a.acc = weighted_add(a.acc, contrib, w)
        a.weight += w
        a.received += 1
        ctx.peak_acc_bytes = max(ctx.peak_acc_bytes, _acc_bytes(ctx))
        if a.received >= duty.expected:
            self._flush(sid, cluster_id)

    def _flush(self, session_id: str, cluster_id: str, force: bool = False) -> None:
        ctx = self.models.get(session_id)
        duty = self.arbiter.duty_for(cluster_id)
        a = ctx.accs.get(cluster_id)
        if duty is None or a is None or a.flushed \
                or (a.acc is None and not a.entries):
            return
        if not force and a.received < duty.expected:
            return
        strat = self._strategy_for(ctx)
        if duty.parent is not None:
            if strat.reduction == "stack":
                payload = {"entries": a.entries, "weight": a.weight,
                           "sender": self.client_id, "partial": True,
                           "round": ctx.round_idx}
            else:
                payload = {"params": a.acc, "weight": a.weight,
                           "sender": self.client_id, "partial": True,
                           "round": ctx.round_idx}
            self.fc.call(T.cluster_agg(session_id, duty.parent), payload)
        else:
            glob, new_state = self._finalize_root(ctx, strat, a)
            msg = {"params": glob, "version": ctx.global_version + 1,
                   "round": ctx.round_idx}
            if new_state is not None:
                # server-optimizer state rides the retained global publish,
                # so whichever client roots the next round resumes it
                msg["server_state"] = new_state
            self.fc.call(T.global_model(session_id), msg, retain=True)
        a.restart()
        a.flushed = True

    def _finalize_root(self, ctx: _SessionCtx, strat: AggregationStrategy,
                       a: _Accumulator):
        """Root aggregator: collected inputs -> (global float32, state)."""
        if strat.reduction == "stack":
            stacked = {k: np.stack([np.asarray(e["params"][k])
                                    for e in a.entries])
                       for k in a.entries[0]["params"]}
            weights = np.asarray([e["weight"] for e in a.entries], np.float64)
            glob = strat.combine(stacked, weights, np)
            return {k: np.asarray(v, np.float32) for k, v in glob.items()}, None
        mean = {k: v / a.weight for k, v in a.acc.items()}
        glob, new_state = strat.finalize(mean, ctx.global_params,
                                         ctx.server_state, np)
        return {k: np.asarray(v, np.float32) for k, v in glob.items()}, new_state

    def _on_global(self, topic: str, payload) -> None:
        body = _body(payload)
        sid = topic.split("/")[2]
        ctx = self.models.sessions.get(sid)
        if ctx is None:
            return
        ctx.params = {k: np.asarray(v) for k, v in body["params"].items()}
        strat = self._strategy_for(ctx)
        if strat.needs_ref or strat.stateful:
            # only reference-using strategies pay for a retained global copy
            ctx.global_params = {k: np.array(v) for k, v in ctx.params.items()}
        if "server_state" in body:
            ctx.server_state = body["server_state"]
        ctx.global_version = body.get("version", ctx.global_version + 1)
        if self.on_global_update:
            self.on_global_update(sid, ctx.params, ctx.global_version)


def _body(payload):
    if isinstance(payload, dict) and "a" in payload:
        args = payload["a"]
        return args[0] if args else {}
    return payload


def _acc_bytes(ctx: _SessionCtx) -> int:
    total = 0
    for a in ctx.accs.values():
        if a.acc is not None:
            total += sum(v.nbytes for v in a.acc.values())
        for e in a.entries:
            total += sum(np.asarray(v).nbytes for v in e["params"].values())
    return total


def _will_payload(client_id: str) -> bytes:
    # a minimal MQTTFC frame announcing the dead client
    from repro.core import mqttfc as F
    import msgpack
    body = F.encode({"a": [client_id], "k": {}, "s": client_id})
    header = msgpack.packb((client_id, 0, 0, 1, 0, "zlib"))
    return len(header).to_bytes(4, "big") + header + body
