"""SDFLMQ client logic (paper §III-C, Listing 1 API).

A client holds: a Role Arbiter (duties + topic subscriptions), a Model
Controller (per-session model repository), and the aggregation service.
The aggregation semantics are pluggable (repro.api.strategies): sessions
carry a strategy name, and every aggregator applies the same strategy hooks
the compiled collective path uses (core/aggregation.py).

"sum"-reduction strategies (fedavg, fedprox, fedadam) move *weighted
partial sums* up the cluster tree through MQTTFC.  The aggregation service
is **streaming and in-place**: each duty holds ONE preallocated flat
float64 accumulator (plus a reusable scratch buffer) and applies
``np.multiply(view, w, out=scratch); np.add(acc, scratch, out=acc)`` —
no per-contribution float64 dicts are ever allocated, and a head forwards
its partial sum by re-framing the accumulator buffer (zero re-serialization
of the leaves).  The fused path is bit-identical to the legacy
``acc + asarray(v, float64) * w`` semantics (property-tested).

"stack"-reduction strategies (trimmed_mean, coordinate_median) are not
decomposable into partial sums; contributions are appended as flat rows
into one growing row buffer.  Heads forward the collected rows as a single
``TensorStack`` slice (one memcpy into the frame, leaves never
re-serialized) and the root builds per-tensor ``(n, ...)`` *strided views*
over the row buffer — no per-key ``np.stack`` duplicate — before applying
the robust combine.  Permutation invariance keeps the tree result
bit-identical to the flat reference no matter the tree shape.

An opt-in int8 + error-feedback uplink codec (``uplink_codec="int8_ef"``)
quantizes leaf updates with the same per-row absmax scheme as the compiled
``compressed`` schedule (repro.dist.compression), carrying the residual
across rounds so repeated compressed rounds do not drift.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Union

import numpy as np

from repro.api.strategies import (AggregationStrategy, get_strategy,
                                  register_strategy)
from repro.core import topics as T
from repro.core.mqttfc import MQTTFC, raw_handler
from repro.core.roles import ClientAssignment, RoleArbiter
from repro.core.stats import ClientStats, local_stats
from repro.core.wire import TensorBundle, TensorStack

Params = dict[str, np.ndarray]

# EF residual damping for the delta-coded top-k uplink (see
# _quantize_uplink_topk): 0 would drop deferred mass, 1 would double-count
# it against the self-correcting delta.
_DELTA_EF_DECAY = 0.5


def weighted_add(acc: Optional[Params], p: Params, w: float) -> Params:
    """Legacy reference semantics (kept as the bit-identity oracle for the
    in-place accumulator; see tests/test_wire.py)."""
    if acc is None:
        return {k: np.asarray(v, np.float64) * w for k, v in p.items()}
    for k, v in p.items():
        acc[k] = acc[k] + np.asarray(v, np.float64) * w
    return acc


def _f64_schema(items: list[tuple[str, tuple]]) -> tuple:
    """Schema of (name, '<f8', shape, offset, nbytes) for a flat f64 acc."""
    schema = []
    off = 0
    for name, shape in items:
        nb = int(np.prod(shape, dtype=np.int64)) * 8 if shape else 8
        schema.append((name, np.dtype(np.float64).str, tuple(shape), off, nb))
        off += nb
    return tuple(schema)


class _Accumulator:
    """Streaming per-duty aggregation state.

    sum reduction: ``flat`` is ONE preallocated float64 buffer covering the
    whole model; contributions are fused in with
    ``multiply(src, w, out=scratch); add(flat, scratch, out=flat)``.

    stack reduction: ``rows`` is one growing byte buffer of flattened
    contributions (row-major, shared schema); strided views stack it with
    zero copies at finalize.
    """

    __slots__ = ("flat", "scratch", "acc_schema", "src_schema", "_views",
                 "_src_flat_dtype", "rows", "rows_used", "row_schema",
                 "row_nbytes", "row_weights", "weight", "received",
                 "flushed", "alloc_bytes", "noted_bytes")

    def __init__(self):
        # bytes last folded into the owning _SessionCtx's running total
        # (survives hard_reset so the delta goes negative on a re-layout)
        self.noted_bytes = 0
        self.hard_reset()

    def hard_reset(self) -> None:
        """Drop buffers too (model/strategy layout changed)."""
        self.flat: Optional[np.ndarray] = None
        self.scratch: Optional[np.ndarray] = None
        self.acc_schema = None           # f64 layout of `flat`
        self.src_schema = None           # wire schema the fast path matches
        self._views: Optional[Params] = None
        self._src_flat_dtype = None      # uniform source dtype (fast path)
        self.rows: Optional[bytearray] = None
        self.rows_used = 0
        self.row_schema = None
        self.row_nbytes = 0
        self.row_weights: list[float] = []
        self.weight = 0.0
        self.received = 0
        self.flushed = False
        self.alloc_bytes = 0

    def restart(self) -> None:
        """New aggregation cycle: reset counters but KEEP the buffers —
        reallocating multi-MB accumulators every round costs ~ms of page
        faults; the first add of the next cycle overwrites in place.  A
        layout change triggers ``hard_reset`` from the add paths."""
        self.rows_used = 0
        self.row_weights = []
        self.weight = 0.0
        self.received = 0
        self.flushed = False

    # ------------------------------------------------------------------
    # sum reduction
    # ------------------------------------------------------------------
    def _ensure_flat(self, items: list[tuple[str, tuple]],
                     src_schema=None) -> None:
        if self.flat is not None:
            return
        self.acc_schema = _f64_schema(items)
        self.src_schema = src_schema
        total = sum(b for *_x, b in self.acc_schema) // 8
        self.flat = np.empty(total, np.float64)
        self.alloc_bytes += self.flat.nbytes
        mv = memoryview(self.flat)
        self._views = {}
        for name, _d, shape, off, nb in self.acc_schema:
            self._views[name] = np.frombuffer(
                mv.cast("B"), np.float64, count=nb // 8,
                offset=off).reshape(shape)
        if src_schema is not None:
            dts = {d for _n, d, *_r in src_schema}
            self._src_flat_dtype = np.dtype(next(iter(dts))) \
                if len(dts) == 1 else None

    def _ensure_scratch(self) -> None:
        if self.scratch is None:
            self.scratch = np.empty_like(self.flat)
            self.alloc_bytes += self.scratch.nbytes

    def acc_views(self) -> Params:
        return self._views

    def add_sum(self, contrib: Union[TensorBundle, Params], w: float) -> None:
        """Fused in-place ``acc += contrib * w`` (bit-identical to the
        legacy weighted_add float64 semantics)."""
        w64 = np.float64(w)
        if isinstance(contrib, TensorBundle):
            if (self.received == 0 and self.src_schema is not None
                    and contrib.schema != self.src_schema):
                self.hard_reset()        # layout changed between cycles
            if self.flat is None:
                self._ensure_flat([(n, s) for n, _d, s, _o, _b
                                   in contrib.schema], contrib.schema)
            if (self._src_flat_dtype is not None
                    and contrib.schema == self.src_schema):
                # uniform-dtype source with identical layout: ONE fused op
                # pair over the entire model.  w == 1.0 (the tree's
                # partial-sum merge) needs no multiply at all — a single
                # cast-add pass (x * 1.0 is exact, so still bit-identical
                # to the legacy semantics).
                dt = self._src_flat_dtype
                src = np.frombuffer(memoryview(contrib.buffer).cast("B"), dt)
                if self.received == 0:
                    if w == 1.0:
                        np.copyto(self.flat, src)
                    else:
                        np.multiply(src, w64, out=self.flat)
                elif w == 1.0:
                    np.add(self.flat, src, out=self.flat)
                else:
                    self._ensure_scratch()
                    np.multiply(src, w64, out=self.scratch)
                    np.add(self.flat, self.scratch, out=self.flat)
                return
            contrib = contrib.views()
        items = [(k, np.asarray(v).shape) for k, v in contrib.items()]
        if (self.received == 0 and self.acc_schema is not None
                and items != [(n, s) for n, _d, s, _o, _b
                              in self.acc_schema]):
            self.hard_reset()            # layout changed between cycles
        if self.flat is None:
            self._ensure_flat(items)
        first = self.received == 0
        if not first and w != 1.0:
            self._ensure_scratch()
        for name, _d, shape, off, nb in self.acc_schema:
            v = np.asarray(contrib[name])
            dst = self._views[name]
            if first:
                if w == 1.0:
                    np.copyto(dst, v)
                else:
                    np.multiply(v, w64, out=dst)
            elif w == 1.0:
                np.add(dst, v, out=dst)
            else:
                scr = np.frombuffer(memoryview(self.scratch).cast("B"),
                                    np.float64, count=nb // 8,
                                    offset=off).reshape(shape)
                np.multiply(v, w64, out=scr)
                np.add(dst, scr, out=dst)

    def add_sum_quantized(self, q_params: Params, scales: Params,
                          w: float) -> None:
        """Fused int8 consume: dequantize each leaf (``q.f32 * scale``) and
        stream it straight into the f64 accumulator — bit-identical to
        ``_dequantize`` + ``add_sum`` but never materializes the
        model-sized dense f32 dict (the host-path analogue of the
        ``qagg`` Pallas kernel)."""
        w64 = np.float64(w)
        items = [(k, np.asarray(v).shape) for k, v in q_params.items()]
        if (self.received == 0 and self.acc_schema is not None
                and items != [(n, s) for n, _d, s, _o, _b
                              in self.acc_schema]):
            self.hard_reset()            # layout changed between cycles
        if self.flat is None:
            self._ensure_flat(items)
        first = self.received == 0
        if not first and w != 1.0:
            self._ensure_scratch()
        for name, _d, shape, off, nb in self.acc_schema:
            deq = np.asarray(q_params[name]).astype(np.float32)
            deq *= np.asarray(scales[name], np.float32)
            dst = self._views[name]
            if first:
                if w == 1.0:
                    np.copyto(dst, deq)
                else:
                    np.multiply(deq, w64, out=dst)
            elif w == 1.0:
                np.add(dst, deq, out=dst)
            else:
                scr = np.frombuffer(memoryview(self.scratch).cast("B"),
                                    np.float64, count=nb // 8,
                                    offset=off).reshape(shape)
                np.multiply(deq, w64, out=scr)
                np.add(dst, scr, out=dst)

    def add_sum_topk(self, indices: Params, q_params: Params, scales: Params,
                     shapes: dict, w: float,
                     base: Optional[Params] = None) -> None:
        """Fused sparse consume for the top-k uplink codec: scatter the
        dequantized survivors directly into the flat f64 accumulator.

        With ``base=None`` the payload carries absolute values (round 0,
        before any global exists): un-sent coordinates contribute exactly
        0.0, so this agrees with densify-then-``add_sum`` everywhere.
        With a ``base`` (the shared last global) the payload is
        delta-coded: each contribution is ``base + scatter(delta)``, so
        the base streams in densely and the sparse deltas ride on top."""
        w64 = np.float64(w)
        items = [(k, tuple(shapes[k])) for k in q_params]
        if (self.received == 0 and self.acc_schema is not None
                and items != [(n, s) for n, _d, s, _o, _b
                              in self.acc_schema]):
            self.hard_reset()
        if self.flat is None:
            self._ensure_flat(items)
        if self.received == 0:
            self.flat.fill(0.0)          # sparse writes need a zero base
        for name, _d, shape, off, nb in self.acc_schema:
            idx = np.asarray(indices[name])
            deq = np.asarray(q_params[name]).astype(np.float32)
            deq *= np.float32(np.asarray(scales[name]).reshape(-1)[0])
            dst = self._views[name].reshape(-1)
            b = None
            if base is not None and name in base:
                b = np.asarray(base[name], np.float32).reshape(-1)
                if b.shape != dst.shape:
                    b = None
            if b is not None:
                # delta-coded: the dense base rides every contribution
                if w == 1.0:
                    np.add(dst, b, out=dst)
                else:
                    dst += np.multiply(b, w64)
                np.add.at(dst, idx, deq if w == 1.0
                          else np.multiply(deq, w64))
                continue
            if w == 1.0:
                if self.received == 0:
                    dst[idx] = deq
                else:
                    np.add.at(dst, idx, deq)
            elif self.received == 0:
                dst[idx] = np.multiply(deq, w64)
            else:
                np.add.at(dst, idx, np.multiply(deq, w64))

    def partial_bundle(self) -> TensorBundle:
        """Re-frame the accumulator as a wire bundle — no re-serialization,
        the frame encoder copies the buffer once."""
        return TensorBundle(self.acc_schema, self.flat)

    # ------------------------------------------------------------------
    # stack reduction
    # ------------------------------------------------------------------
    def _ensure_rows(self, schema, expected_rows: int) -> None:
        if self.rows is not None:
            return
        self.row_schema = tuple(
            (n, d, tuple(s), o, b) for n, d, s, o, b in schema)
        self.row_nbytes = sum(b for *_x, b in self.row_schema)
        cap = max(1, expected_rows) * self.row_nbytes
        self.rows = bytearray(cap)
        self.alloc_bytes += cap

    def _grow_rows(self, need: int) -> None:
        if self.rows_used + need <= len(self.rows):
            return
        new_cap = self.rows_used + need
        grown = bytearray(new_cap)
        grown[:self.rows_used] = memoryview(self.rows)[:self.rows_used]
        self.alloc_bytes += new_cap - len(self.rows)
        self.rows = grown

    def add_stack_row(self, contrib: Union[TensorBundle, Params], w: float,
                      expected_rows: int) -> None:
        if not isinstance(contrib, TensorBundle):
            contrib = TensorBundle.from_params(
                {k: np.asarray(v) for k, v in contrib.items()})
        if (not self.row_weights and self.row_schema is not None
                and contrib.schema != self.row_schema):
            self.hard_reset()            # layout changed between cycles
        self._ensure_rows(contrib.schema, expected_rows)
        if contrib.schema != self.row_schema:
            # canonicalize to the first row's layout (key order / dtypes)
            contrib = TensorBundle.from_params(
                {n: np.asarray(contrib.view(n), np.dtype(d)).reshape(s)
                 for n, d, s, _o, _b in self.row_schema})
        self._grow_rows(self.row_nbytes)
        memoryview(self.rows)[self.rows_used:
                              self.rows_used + self.row_nbytes] = \
            memoryview(contrib.buffer).cast("B")
        self.rows_used += self.row_nbytes
        self.row_weights.append(float(w))

    def add_stack_batch(self, batch: TensorStack, weights: list) -> None:
        """A forwarded partial: n rows land with ONE memcpy."""
        if (not self.row_weights and self.row_schema is not None
                and batch.schema != self.row_schema):
            self.hard_reset()
        self._ensure_rows(batch.schema, batch.n)
        assert batch.schema == self.row_schema, "stack schema mismatch"
        nb = batch.nbytes
        self._grow_rows(nb)
        memoryview(self.rows)[self.rows_used:self.rows_used + nb] = \
            memoryview(batch.buffer).cast("B")
        self.rows_used += nb
        self.row_weights.extend(float(x) for x in weights)

    @property
    def n_rows(self) -> int:
        return len(self.row_weights)

    def stack_slice(self) -> TensorStack:
        """Collected rows as one zero-copy wire object."""
        return TensorStack(self.row_schema, self.n_rows,
                           memoryview(self.rows)[:self.rows_used])

    def stacked_views(self) -> Params:
        """Per-tensor (n, ...) strided views over the row buffer — the
        no-duplicate replacement for per-key np.stack."""
        return self.stack_slice().stacked_views()

    def has_data(self) -> bool:
        return self.flat is not None or self.rows_used > 0


@dataclass
class _SessionCtx:
    session_id: str
    model_name: str
    params: Optional[Params] = None
    weight: float = 1.0                      # FedAvg weight (sample count)
    strategy: str = "fedavg"                 # session-wide (from topology)
    global_params: Optional[Params] = None   # last global (strategy ref)
    server_state: Optional[dict] = None      # stateful strategies (fedadam)
    global_version: int = 0
    round_idx: int = 0
    accs: dict[str, _Accumulator] = field(default_factory=dict)
    tree: Optional[dict] = None
    terminated: bool = False
    peak_acc_bytes: int = 0                  # memory evaluation (paper §VI)
    acc_bytes_now: int = 0                   # running total behind the peak
    stale_dropped: int = 0                   # late contributions discarded
    uplink_err: Optional[Params] = None      # int8 error-feedback residual
    topk_base: Optional[Params] = None       # last global: top-k delta base
    # -- adversarial defense (core/defense.py; rides the topology) ------
    defense: Optional[dict] = None           # screening rules (from topology)
    reputation: dict = field(default_factory=dict)   # coordinator trust map
    defense_rejected: int = 0                # updates this node rejected
    gate_ewma: float = 0.0                   # norm-per-weight EWMA baseline
    gate_n: int = 0                          # observations toward warmup
    # -- asynchronous mode (repro.api.async_fl) ------------------------
    async_cfg: Optional[dict] = None         # admission rules (from topology)
    async_bufs: dict = field(default_factory=dict)   # cluster -> AsyncBuffer
    view_params: Optional[Params] = None     # latest model view (training base)
    site_seq: int = 0                        # gossip site-model generation
    version_from_gossip: bool = False        # current version adopted, not
                                             # received: the real global (with
                                             # ref/server state) is still due
    async_admitted: int = 0
    async_rejected: int = 0                  # contributions past the bound
    gossip_sent: int = 0
    gossip_adopts: int = 0
    gossip_merges: int = 0
    site_updates: int = 0

    def acc_for(self, cluster_id: str) -> _Accumulator:
        return self.accs.setdefault(cluster_id, _Accumulator())

    def note_mem(self, acc: Optional[_Accumulator] = None) -> None:
        """Incremental peak tracking: O(1) per ingest, not O(#duties) — a
        cohort endpoint heads thousands of clusters, so even one pass over
        ``accs`` per contribution is quadratic at fleet scale.  Each
        accumulator remembers the bytes it last reported (``noted_bytes``)
        and only the delta folds into the running total."""
        if acc is not None:
            self.acc_bytes_now += acc.alloc_bytes - acc.noted_bytes
            acc.noted_bytes = acc.alloc_bytes
        else:
            self.acc_bytes_now = 0
            for a in self.accs.values():
                a.noted_bytes = a.alloc_bytes
                self.acc_bytes_now += a.alloc_bytes
        if self.acc_bytes_now > self.peak_acc_bytes:
            self.peak_acc_bytes = self.acc_bytes_now

    def reset_round(self, round_idx: int) -> None:
        self.round_idx = round_idx
        # keep accumulators (and their preallocated buffers) for duties
        # that were actually exercised; drop idle ones (stale after a role
        # rearrangement) so their memory is released
        stale = [cid for cid, a in self.accs.items()
                 if a.received == 0 and not a.flushed]
        for cid in stale:
            self.acc_bytes_now -= self.accs[cid].noted_bytes
            del self.accs[cid]
        for a in self.accs.values():
            a.restart()


class ModelController:
    """Per-session model repository (paper: tracks local + global updates)."""

    def __init__(self):
        self.sessions: dict[str, _SessionCtx] = {}

    def get(self, sid: str) -> _SessionCtx:
        return self.sessions[sid]

    def ensure(self, sid: str, model_name: str) -> _SessionCtx:
        if sid not in self.sessions:
            self.sessions[sid] = _SessionCtx(sid, model_name)
        return self.sessions[sid]


class SDFLMQClient:
    """Mirrors the paper's SDFLMQ_Client (Listing 1).  ``broker`` is any
    repro.api.transport.Transport implementation.

    ``wire_format``: "tb" (zero-copy TensorBundle, default) or "legacy"
    (msgpack ExtType) — receivers understand both, so fleets can mix.
    ``uplink_codec``: None, or "int8_ef" for int8 + error-feedback
    quantized leaf uplinks (mirrors the compiled ``compressed`` schedule).
    """

    def __init__(self, client_id: str, broker,
                 preferred_role: str = "trainer",
                 stats: Optional[ClientStats] = None,
                 wire_format: str = "tb",
                 uplink_codec: Optional[str] = None,
                 downlink_codec: Optional[str] = None,
                 update_filter=None,
                 topk_density: float = 0.01,
                 topk_warmup_rounds: int = 0):
        assert uplink_codec in (None, "int8_ef", "topk_int8_ef"), uplink_codec
        assert downlink_codec in (None, "int8"), downlink_codec
        self.client_id = client_id
        self.preferred_role = preferred_role
        self.stats = stats or local_stats(client_id)
        self.uplink_codec = uplink_codec
        self.downlink_codec = downlink_codec
        if update_filter is not None:       # lazy: knob pulls in fl_step
            from repro.core.fl_step import ParamFilter
            update_filter = ParamFilter.parse(update_filter)
        self.update_filter = update_filter
        self.topk_density = float(topk_density)
        self.topk_warmup_rounds = int(topk_warmup_rounds)
        # codec telemetry (repro.obs reads these; cheap plain counters)
        self.codec_stats = {"uplink_bytes": 0, "uplink_msgs": 0,
                            "ef_residual_norm": 0.0,
                            "topk_density": 1.0}
        self.fc = MQTTFC(broker, client_id, will_topic=T.will(client_id),
                         will_payload=_will_payload(client_id),
                         wire_format=wire_format)
        self.arbiter = RoleArbiter(client_id)
        self.models = ModelController()
        self.on_global_update: Optional[Callable] = None
        self.on_round_start: Optional[Callable] = None
        # optional telemetry facade (repro.obs.Telemetry); set by
        # Federation(metrics=...).  None = zero-overhead default.
        self.obs = None
        self.fc.bind(T.client_ctrl(client_id), self._on_ctrl)

    # ------------------------------------------------------------------
    # Paper Listing-1 API
    # ------------------------------------------------------------------
    def create_fl_session(self, session_id: str, model_name: str,
                          fl_rounds: int, session_capacity_min: int,
                          session_capacity_max: int,
                          session_time_s: float = 3600.0,
                          waiting_time_s: float = 120.0,
                          preferred_role: Optional[str] = None,
                          strategy: str = "fedavg",
                          async_cfg: Optional[dict] = None,
                          defense_cfg: Optional[dict] = None) -> None:
        strat = get_strategy(strategy)           # fail fast on unknown names
        if isinstance(strategy, str):
            strategy = strat.name
        else:
            # tuned instance: register under a session-scoped name so every
            # aggregator applies the same hyperparameters without touching
            # what the plain name resolves to for other sessions (a real
            # deployment registers the same factory on every node; the wire
            # carries the name)
            strategy = f"{strat.name}@{session_id}"
            register_strategy(strategy, lambda s=strat: s)
        ctx = self.models.ensure(session_id, model_name)
        ctx.strategy = strategy
        self._subscribe_session(session_id)
        self.fc.call(T.coord("create_session"), session_id, model_name,
                     self.client_id, fl_rounds, session_capacity_min,
                     session_capacity_max, session_time_s, waiting_time_s,
                     preferred_role or self.preferred_role,
                     self.stats.to_dict(), strategy=strategy,
                     async_cfg=async_cfg, defense_cfg=defense_cfg)

    def join_fl_session(self, session_id: str, model_name: str,
                        fl_rounds: int = 0,
                        preferred_role: Optional[str] = None) -> None:
        self.models.ensure(session_id, model_name)
        self._subscribe_session(session_id)
        self.fc.call(T.coord("join_session"), session_id, self.client_id,
                     model_name, fl_rounds,
                     preferred_role or self.preferred_role,
                     self.stats.to_dict())

    def set_model(self, session_id: str, params: Params,
                  n_samples: int = 1) -> None:
        ctx = self.models.get(session_id)
        ctx.params = {k: np.asarray(v) for k, v in params.items()}
        ctx.weight = float(n_samples)

    def get_model(self, session_id: str) -> Params:
        return self.models.get(session_id).params

    def send_local(self, session_id: str) -> None:
        """Publish the locally trained model for global updating.  The
        cluster head's own copy self-delivers via its subscription."""
        ctx = self.models.get(session_id)
        asg = self.arbiter.assignment
        if asg is None or asg.train_cluster is None:
            raise RuntimeError(f"{self.client_id}: no trainer assignment yet")
        topic = T.cluster_agg(session_id, asg.train_cluster)
        # async sessions stamp the *global version the training started
        # from* (the FedBuff staleness reference); sync sessions stamp the
        # round barrier index
        stamp = ctx.global_version if ctx.async_cfg is not None \
            else ctx.round_idx
        if self.obs is not None:
            self.obs.trace("contribute", session=session_id,
                           client=self.client_id, cluster=asg.train_cluster,
                           stamp=stamp)
        ship = ctx.params
        if self.update_filter is not None:
            # partial update: only the filtered (adapter) subset leaves the
            # device; the frozen base never hits the wire
            ship = self.update_filter.extract(ctx.params)
        # density warm-up (gradient-compression practice): the first
        # ``topk_warmup_rounds`` rounds ship the dense int8 codec so the
        # early globals aren't starved to k coordinates, then top-k kicks in
        warm = (self.uplink_codec == "topk_int8_ef"
                and ctx.round_idx < self.topk_warmup_rounds)
        if self.uplink_codec == "topk_int8_ef" and not warm:
            idx, q, scales, shapes = self._quantize_uplink_topk(ctx, ship)
            payload = {"params": q, "indices": idx, "scales": scales,
                       "shapes": shapes, "codec": "topk_int8_ef",
                       "quantized": True, "weight": ctx.weight,
                       "sender": self.client_id, "partial": False,
                       "round": stamp,
                       # delta-coded against this global version (None =
                       # absolute values, no global seen yet)
                       "base_version": (ctx.global_version
                                        if ctx.topk_base is not None
                                        else None)}
            self._note_uplink(idx, q, scales)
            if self.fc.wire_format == "tb":   # legacy msgpack takes dicts
                for key in ("params", "indices", "scales"):
                    payload[key] = TensorBundle.from_params(payload[key])
            self.fc.call(topic, payload, quantized=True)
            return
        if self.uplink_codec == "int8_ef" or warm:
            q, scales = self._quantize_uplink(ctx, ship)
            self._note_uplink(None, q, scales)
            if self.fc.wire_format == "tb":   # legacy msgpack takes dicts
                q = TensorBundle.from_params(q)
                scales = TensorBundle.from_params(scales)
            self.fc.call(topic,
                         {"params": q, "scales": scales, "quantized": True,
                          "weight": ctx.weight, "sender": self.client_id,
                          "partial": False, "round": stamp},
                         quantized=True)
            return
        self._note_uplink(None, ship, None)
        params = ship
        if self.fc.wire_format == "tb":
            params = TensorBundle.from_params(params)
        self.fc.call(topic, {"params": params, "weight": ctx.weight,
                             "sender": self.client_id, "partial": False,
                             "round": stamp})

    def _note_uplink(self, idx, payload: Params, scales) -> None:
        """Codec telemetry: payload bytes actually shipped this uplink."""
        nb = sum(np.asarray(v).nbytes for v in payload.values())
        if idx is not None:
            nb += sum(np.asarray(v).nbytes for v in idx.values())
        if scales is not None:
            nb += sum(np.asarray(v).nbytes for v in scales.values())
        cs = self.codec_stats
        cs["uplink_bytes"] += nb
        cs["uplink_msgs"] += 1

    def _quantize_uplink(self, ctx: _SessionCtx, ship: Params):
        """int8 + error feedback, same per-row absmax scheme the compiled
        ``compressed`` schedule uses (repro.dist.compression, xp=numpy)."""
        from repro.dist import compression as C
        if ctx.uplink_err is None or set(ctx.uplink_err) != set(ship):
            ctx.uplink_err = {k: np.zeros_like(np.asarray(v, np.float32))
                              for k, v in ship.items()}
        q_params, scales = {}, {}
        res_sq = 0.0
        for k, v in ship.items():
            q, scale, new_err = C.quantize_with_error_feedback(
                v, ctx.uplink_err[k], xp=np)
            q_params[k] = q
            scales[k] = np.asarray(scale, np.float32)
            ctx.uplink_err[k] = new_err
            res_sq += float(np.dot(new_err.ravel(), new_err.ravel()))
        self.codec_stats["ef_residual_norm"] = float(np.sqrt(res_sq))
        return q_params, scales

    def _quantize_uplink_topk(self, ctx: _SessionCtx, ship: Params):
        """Top-k + int8 + error feedback (repro.dist.compression,
        xp=numpy): ship only the largest-magnitude ``topk_density``
        fraction of each leaf; the EF residual carries the un-sent mass
        forward so nothing is ever lost, only deferred.

        Once a global exists the payload is *delta-coded* against it
        (``ctx.topk_base``): sparsifying the update instead of the raw
        weights keeps the un-sent coordinates at the shared global rather
        than zero, so a k-sparse uplink no longer starves the model."""
        from repro.dist import compression as C
        if ctx.uplink_err is None or set(ctx.uplink_err) != set(ship):
            ctx.uplink_err = {k: np.zeros_like(np.asarray(v, np.float32))
                              for k, v in ship.items()}
        base = ctx.topk_base
        idx, q_params, scales, shapes = {}, {}, {}, {}
        res_sq = 0.0
        sent = total = 0
        for k, v in ship.items():
            v = np.asarray(v, np.float32)
            delta_coded = (base is not None and k in base
                           and np.shape(base[k]) == v.shape)
            if delta_coded:
                v = v - np.asarray(base[k], np.float32)
            # In delta mode the residual is *damped*, not carried whole: a
            # delta against the actual global partially re-derives the
            # un-applied mass on its own (local SGD pushes the weights the
            # same way again), so a full carry double-counts it and can
            # ring on near-stationary clients, while dropping it entirely
            # slows real training.  Geometric decay keeps most of the EF
            # acceleration with a strictly bounded residual.
            err_in = (ctx.uplink_err[k] * _DELTA_EF_DECAY if delta_coded
                      else ctx.uplink_err[k])
            i, q, scale, new_err = C.quantize_topk_int8_ef(
                v, err_in, self.topk_density, xp=np)
            idx[k] = i
            q_params[k] = q
            scales[k] = scale
            shapes[k] = list(v.shape)
            ctx.uplink_err[k] = new_err
            res_sq += float(np.dot(new_err.ravel(), new_err.ravel()))
            sent += int(i.size)
            total += int(v.size)
        self.codec_stats["ef_residual_norm"] = float(np.sqrt(res_sq))
        self.codec_stats["topk_density"] = sent / total if total else 1.0
        return idx, q_params, scales, shapes

    def wait_global_update(self, session_id: str) -> Params:
        """Synchronous in the simulated broker: delivery already happened by
        the time send_local returned on the last contributor."""
        return self.models.get(session_id).params

    def leave(self, session_id: str) -> None:
        self.fc.call(T.coord("leave_session"), session_id, self.client_id)

    def fail(self) -> None:
        """Simulate abnormal death -> broker fires the LWT."""
        self.fc.close(graceful=False)

    def heartbeat(self, session_id: str) -> None:
        """Liveness beat to the coordinator (defense; metadata only)."""
        self.fc.call(T.coord("heartbeat"), session_id, self.client_id)

    def signal_ready(self, session_id: str,
                     stats: Optional[ClientStats] = None,
                     metrics: Optional[dict] = None) -> None:
        """Round-status update to the coordinator (paper §III-E4), stamped
        with the client's current round so a signal held back by the
        network can't count toward a later round."""
        st = (stats or self.stats).to_dict()
        ctx = self.models.sessions.get(session_id)
        self.fc.call(T.coord("client_ready"), session_id, self.client_id,
                     st, metrics or {},
                     round_idx=ctx.round_idx if ctx else None)

    # ------------------------------------------------------------------
    # Control-plane handlers
    # ------------------------------------------------------------------
    def _subscribe_session(self, session_id: str) -> None:
        self.fc.subscribe_raw(T.session_status(session_id),
                              raw_handler(self._on_status))
        self.fc.subscribe_raw(T.global_model(session_id),
                              raw_handler(self._on_global))
        # async-mode head gossip: cheap to hold in sync sessions (nothing
        # publishes there), and late role changes need no re-subscription
        self.fc.subscribe_raw(T.gossip_all(session_id),
                              raw_handler(self._on_gossip))

    def _on_ctrl(self, payload: dict) -> None:
        ev = payload.get("event")
        if ev == "role_assignment":
            asg = ClientAssignment.from_dict(payload["assignment"])
            to_unsub, to_sub = self.arbiter.update(asg)
            for t in to_unsub:
                self.fc.unbind(t)
            for t in to_sub:
                self.fc.subscribe_raw(t, raw_handler(self._on_cluster_input))

    def _on_status(self, topic: str, payload) -> None:
        body = _body(payload)
        sid = topic.split("/")[2]
        ctx = self.models.sessions.get(sid)
        if ctx is None:
            return
        ev = body.get("event")
        if ev == "topology":
            ctx.tree = body.get("tree")
            # session-wide strategy rides the retained topology broadcast
            ctx.strategy = body.get("strategy", ctx.strategy)
            # async admission rules (incl. live cohort size) ride along too
            ctx.async_cfg = body.get("async") or ctx.async_cfg
            # defense screening rules + the coordinator's live reputation
            # map: every aggregator (incl. late joiners) screens the same
            d = body.get("defense")
            if d is not None:
                ctx.defense = d
                ctx.reputation = dict(d.get("reputation") or {})
            # a (re)joining client syncs its round counter from the retained
            # topology, so its next contribution carries the live round.
            # Async sessions have no round barrier: rearrangements must NOT
            # reset the FedBuff buffers mid-fill.
            rnd = body.get("round")
            if ctx.async_cfg is None and rnd is not None \
                    and rnd > ctx.round_idx:
                ctx.reset_round(rnd)
        elif ev == "round_start":
            ctx.reset_round(body.get("round", ctx.round_idx))
            if self.on_round_start:
                self.on_round_start(sid, ctx.round_idx)
        elif ev == "flush":
            lvl = body.get("level")
            for cid in list(ctx.accs):
                duty = self.arbiter.duty_for(cid)
                if duty is not None and (lvl is None or duty.level == lvl):
                    self._flush(sid, cid, force=True)
        elif ev == "session_terminated":
            ctx.terminated = True

    def _strategy_for(self, ctx: _SessionCtx) -> AggregationStrategy:
        return get_strategy(ctx.strategy)

    @staticmethod
    def _premap_is_identity(strat: AggregationStrategy) -> bool:
        return type(strat).premap is AggregationStrategy.premap

    # ------------------------------------------------------------------
    # Defense screening (core/defense.py rules ride the topology)
    # ------------------------------------------------------------------
    def _defense_screen(self, ctx: _SessionCtx, sid: str, body,
                        w: float) -> Optional[float]:
        """Screen one inbound contribution under the session's defense
        rules.  Returns the (reputation-weighted) combine weight, or None
        when the update is rejected.  Two instruments, coarse to fine:
        the *norm gate* (an EWMA baseline of update-delta magnitudes;
        anything ``norm_gate_mult``× above it is rejected and reported to
        the coordinator) catches scaling/inflation attacks, while the
        robust combine downstream handles direction-only poisoning the
        gate cannot see."""
        d = ctx.defense
        sender = body.get("sender", "")
        partial = bool(body.get("partial"))
        rep = 1.0 if partial else float(ctx.reputation.get(sender, 1.0))
        if not partial and rep < float(d.get("reject_below", 0.2)):
            # quarantined sender: refuse outright, no re-report (the
            # coordinator already knows — that is WHY the score is low)
            self._reject_update(ctx, sid, sender, "reputation",
                                report=False)
            return None
        mult = float(d.get("norm_gate_mult", 4.0))
        if mult > 0:
            metric = self._update_metric(ctx, body)
            if metric is not None:
                warm = int(d.get("norm_warmup", 3))
                alpha = float(d.get("norm_alpha", 0.3))
                if ctx.gate_n >= warm and ctx.gate_ewma > 0.0 \
                        and metric > mult * ctx.gate_ewma:
                    self._reject_update(ctx, sid, sender, "norm_outlier",
                                        report=True)
                    return None
                ctx.gate_n += 1
                ctx.gate_ewma = metric if ctx.gate_n == 1 else \
                    (1.0 - alpha) * ctx.gate_ewma + alpha * metric
        return w * rep

    def _update_metric(self, ctx: _SessionCtx, body) -> Optional[float]:
        """Magnitude of a contribution as an L2 delta from the last global
        (raw norm before the first global exists): per-client for leaves,
        the weighted-mean delta for sum partials, the worst row for stack
        batches — one comparable scale for everything the gate sees."""
        g = ctx.global_params

        def delta_norm(params: Params, scale: float = 1.0) -> float:
            total = 0.0
            for k, v in params.items():
                x = np.asarray(v, np.float64) * scale
                if g is not None and k in g:
                    x = x - np.asarray(g[k], np.float64)
                x = x.ravel()
                total += float(np.dot(x, x))
            return float(np.sqrt(total))

        try:
            if "stack" in body:                   # TensorStack batch
                views = body["stack"].stacked_views()
                ws = body.get("weights") or []
                worst = 0.0
                for i in range(len(ws)):
                    worst = max(worst, delta_norm(
                        {k: v[i] for k, v in views.items()}))
                return worst
            if "entries" in body:                 # legacy stack partial
                return max((delta_norm(_as_params(e["params"]))
                            for e in body["entries"]), default=0.0)
            params = _as_params(_bundle_or_params(body, base=ctx.topk_base))
            if body.get("partial"):
                # flat-f64 partial sum: normalize by the carried weight so
                # the metric is the weighted-mean member delta
                wsum = max(float(body.get("weight", 1.0)), 1e-12)
                return delta_norm(params, scale=1.0 / wsum)
            return delta_norm(params)
        except Exception:
            return None           # malformed frame: let the accumulators
                                  # apply their own schema checks

    def _reject_update(self, ctx: _SessionCtx, sid: str, sender: str,
                       reason: str, report: bool) -> None:
        ctx.defense_rejected += 1
        if self.obs is not None:
            self.obs.trace("update_rejected", session=sid, client=sender,
                           by=self.client_id, reason=reason,
                           round=ctx.round_idx)
        if report and sender:
            self.fc.call(T.coord("defense_report"), sid, sender, reason,
                         self.client_id)

    def _on_cluster_input(self, topic: str, payload) -> None:
        """Aggregation service: accumulate inputs for one duty under the
        session's strategy — streaming into the preallocated flat
        accumulator (sum) or the row buffer (stack)."""
        body = _body(payload)
        parts = topic.split("/")       # sdflmq/session/<sid>/cluster/<cid>/agg
        sid, cluster_id = parts[2], parts[4]
        ctx = self.models.sessions.get(sid)
        duty = self.arbiter.duty_for(cluster_id)
        if ctx is None or duty is None:
            return
        if ctx.async_cfg is not None:
            return self._on_cluster_input_async(sid, cluster_id, body,
                                                ctx, duty)
        # asynchronous delivery: a contribution held by a partition (or a
        # straggler's QoS-1 retransmission) can arrive after its round was
        # deadline-cut — drop it instead of polluting the current round
        rnd = body.get("round")
        if rnd is not None and rnd < ctx.round_idx:
            ctx.stale_dropped += 1
            return
        strat = self._strategy_for(ctx)
        a = ctx.acc_for(cluster_id)
        if a.flushed:        # new aggregation cycle starts on first input
            a.restart()
        # ``covers``: how many of this cluster's expected members the
        # message accounts for — 1 for an individual contribution, k for a
        # cohort's pre-aggregated batch of k fronted members
        covers = int(body.get("covers", 1))
        w = float(body["weight"])
        if ctx.defense is not None:
            w = self._defense_screen(ctx, sid, body, w)
            if w is None:
                # the refusal still counts toward this duty's fan-in, so
                # the honest subset flushes without waiting for an update
                # that was rejected
                a.received += covers
                if a.received >= duty.expected:
                    self._flush(sid, cluster_id)
                return
        if strat.reduction == "stack":
            if body.get("partial"):
                if "stack" in body:       # TensorStack batch (tb wire)
                    a.add_stack_batch(body["stack"], body["weights"])
                else:                     # legacy entries list
                    for e in body["entries"]:
                        a.add_stack_row(_as_params(e["params"]),
                                        float(e["weight"]), duty.expected)
            else:
                contrib = _bundle_or_params(body, base=ctx.topk_base)
                if not self._premap_is_identity(strat):
                    # defense premaps (norm clipping) apply per leaf row,
                    # exactly once — partials forward already-clipped rows
                    contrib = strat.premap(_as_params(contrib),
                                           ctx.global_params, np)
                a.add_stack_row(contrib, w, duty.expected)
        else:
            if body.get("partial"):
                a.add_sum(_bundle_or_params(body), 1.0)
            elif (body.get("quantized")
                  and self._premap_is_identity(strat)):
                # fused consume: the int8 (or sparse top-k) payload streams
                # straight into the f64 accumulator — the host-path twin of
                # the qagg kernel; never materializes the dense f32 model
                self._add_quantized(a, body, w, base=ctx.topk_base)
            else:
                contrib = _bundle_or_params(body, base=ctx.topk_base)
                if not self._premap_is_identity(strat):
                    contrib = strat.premap(_as_params(contrib),
                                           ctx.global_params, np)
                a.add_sum(contrib, w)
        a.weight += w
        a.received += covers
        ctx.note_mem(a)
        if a.received >= duty.expected:
            self._flush(sid, cluster_id)

    @staticmethod
    def _add_quantized(a: _Accumulator, body, w: float,
                       base: Optional[Params] = None) -> None:
        """Dispatch a quantized uplink body to the matching fused
        accumulator path (bit-compatible with densify-then-``add_sum``)."""
        if body.get("codec") == "topk_int8_ef":
            a.add_sum_topk(_as_params(body["indices"]),
                           _as_params(body["params"]),
                           _as_params(body["scales"]),
                           body["shapes"], w,
                           base=(base if body.get("base_version") is not None
                                 else None))
        else:
            a.add_sum_quantized(_as_params(body["params"]),
                                _as_params(body["scales"]), w)

    def _on_cluster_input_async(self, sid: str, cluster_id: str, body,
                                ctx: _SessionCtx, duty) -> None:
        """FedBuff admission (repro.api.async_fl): round-stamped
        contributions are rejected past the staleness bound, admitted at a
        discounted weight otherwise, and the duty flushes K-of-N style —
        the root when ``buffer_k`` leaf contributions landed, heads once a
        proportional share of their cluster reported.  Partials were
        admission-checked and discounted downstream, so they fold in
        unconditionally (their ``contribs`` count rides along)."""
        from repro.api import async_fl as A
        acfg = ctx.async_cfg
        strat = self._strategy_for(ctx)
        a = ctx.acc_for(cluster_id)
        buf = ctx.async_bufs.get(cluster_id)
        if buf is None or buf.acc is not a:
            buf = ctx.async_bufs[cluster_id] = A.AsyncBuffer(a, acfg, strat)
        if a.flushed:                  # first input of a new buffer cycle
            a.restart()
            buf.start_cycle()
        stamp = int(body.get("round") or 0)
        bound = acfg.get("bound")
        if body.get("partial"):
            # partials were discounted at their admission point, but a
            # partial held back (partition, slow link) can outlive the
            # bound in transit — its min-stamp decides, its whole
            # contribution count is rejected and counted
            pstamp = int(body.get("stamp", stamp))
            if bound is not None and ctx.global_version - pstamp > bound:
                nc = int(body.get("contribs", 1))
                buf.rejected_stale += nc
                ctx.async_rejected += nc
                ctx.stale_dropped += nc
                return
            w = float(body["weight"])
            if strat.reduction == "stack":
                if "stack" in body:
                    a.add_stack_batch(body["stack"], body["weights"])
                else:
                    for e in body["entries"]:
                        a.add_stack_row(_as_params(e["params"]),
                                        float(e["weight"]), duty.expected)
            else:
                a.add_sum(_bundle_or_params(body), 1.0)
            buf.contribs += int(body.get("contribs", 1))
            buf.note_stamp(int(body.get("stamp", stamp)))
        else:
            staleness = max(0, ctx.global_version - stamp)
            if self.obs is not None:
                self.obs.observe_staleness(staleness)
            if bound is not None and staleness > bound:
                buf.rejected_stale += 1
                ctx.async_rejected += 1
                ctx.stale_dropped += 1
                return
            w = float(body["weight"]) * float(buf.discount(staleness))
            if ctx.defense is not None:
                w = self._defense_screen(ctx, sid, body, w)
                if w is None:
                    return      # K-of-N: other admissions trigger the flush
            contrib = _bundle_or_params(body, base=ctx.topk_base)
            if not self._premap_is_identity(strat):
                contrib = strat.premap(_as_params(contrib),
                                       ctx.global_params, np)
            if strat.reduction == "stack":
                a.add_stack_row(contrib, w, duty.expected)
            else:
                a.add_sum(contrib, w)
            buf.contribs += 1
            buf.note_stamp(stamp)
            ctx.async_admitted += 1
        a.weight += w
        a.received += 1
        ctx.note_mem(a)
        cohort = max(1, int(acfg.get("cohort", 1)))
        k = min(max(1, int(acfg.get("k", 1))), cohort)
        if duty.parent is None:
            if buf.contribs >= k:
                self._flush(sid, cluster_id, force=True)
        elif a.received >= A.head_share(duty.expected, k, cohort):
            self._flush(sid, cluster_id, force=True)

    def _flush(self, session_id: str, cluster_id: str, force: bool = False) -> None:
        ctx = self.models.get(session_id)
        duty = self.arbiter.duty_for(cluster_id)
        a = ctx.accs.get(cluster_id)
        if duty is None or a is None or a.flushed or not a.has_data():
            return
        if not force and a.received < duty.expected:
            return
        strat = self._strategy_for(ctx)
        legacy_wire = self.fc.wire_format == "legacy"
        buf = ctx.async_bufs.get(cluster_id) \
            if ctx.async_cfg is not None else None
        stamp_round = ctx.global_version if buf is not None else ctx.round_idx
        if duty.parent is not None:
            if strat.reduction == "stack":
                if legacy_wire:
                    sv = a.stacked_views()
                    payload = {"entries": [
                        {"params": {k: sv[k][i] for k in sv},
                         "weight": a.row_weights[i]}
                        for i in range(a.n_rows)],
                        "weight": a.weight,
                        "sender": self.client_id, "partial": True,
                        "round": stamp_round}
                else:
                    # forward collected rows as ONE zero-copy slice; the
                    # frame encoder copies the buffer once — leaves are
                    # never re-encoded
                    payload = {"stack": a.stack_slice(),
                               "weights": list(a.row_weights),
                               "weight": a.weight,
                               "sender": self.client_id, "partial": True,
                               "round": stamp_round}
            else:
                partial = (dict(a.acc_views()) if legacy_wire
                           else a.partial_bundle())
                payload = {"params": partial, "weight": a.weight,
                           "sender": self.client_id, "partial": True,
                           "round": stamp_round}
            if buf is not None:
                # stamped partial: contribution count for the root's K-of-N
                # trigger + the oldest admitted stamp for reconciliation
                payload["contribs"] = buf.contribs
                payload["stamp"] = buf.min_stamp if buf.min_stamp is not None \
                    else ctx.global_version
                self._mint_site_model(ctx, strat, a)
            if self.obs is not None:
                self.obs.trace("flush", session=session_id,
                               client=self.client_id, cluster=cluster_id,
                               parent=duty.parent, received=a.received)
            self._send_cluster(session_id, duty.parent, payload)
        else:
            glob, new_state = self._finalize_root(ctx, strat, a)
            if buf is not None:
                # async root: apply the new global locally *now* — the next
                # buffer cycle must stamp against the new version even
                # before the published echo loops back (a second K-of-N
                # flush inside the same delivery cascade would otherwise
                # mint a duplicate version)
                ctx.global_version += 1
                ctx.params = glob
                ctx.view_params = glob
                ctx.site_seq = 0
                ctx.version_from_gossip = False
                if strat.needs_ref or strat.stateful \
                        or ctx.defense is not None:
                    ctx.global_params = {k: np.array(v)
                                         for k, v in glob.items()}
                if new_state is not None:
                    ctx.server_state = new_state
                version = ctx.global_version
                if self.on_global_update:
                    self.on_global_update(session_id, ctx.params, version)
            else:
                version = ctx.global_version + 1
            tb = self.fc.wire_format == "tb"
            quantized_call = False
            if self.downlink_codec == "int8":
                # quantized retained broadcast: the downlink twin of the
                # int8 uplink — late subscribers replay the retained int8
                # frames and dequantize locally
                from repro.dist import compression as C
                qd, sd = {}, {}
                for k, v in glob.items():
                    q, s = C.quantize_int8(np.asarray(v, np.float32), xp=np)
                    qd[k] = q
                    sd[k] = np.asarray(s, np.float32)
                msg = {"params": TensorBundle.from_params(qd) if tb else qd,
                       "scales": TensorBundle.from_params(sd) if tb else sd,
                       "quantized": True,
                       "version": version,
                       "round": version if buf is not None else ctx.round_idx}
                quantized_call = True
            else:
                msg = {"params": TensorBundle.from_params(glob)
                       if tb else glob,
                       "version": version,
                       "round": version if buf is not None else ctx.round_idx}
            if new_state is not None:
                # server-optimizer state rides the retained global publish,
                # so whichever client roots the next round resumes it
                msg["server_state"] = new_state
            if self.obs is not None:
                self.obs.trace("mint", session=session_id,
                               client=self.client_id, cluster=cluster_id,
                               version=version)
            self.fc.call(T.global_model(session_id), msg, retain=True,
                         quantized=quantized_call)
        if buf is not None:
            buf.flushes += 1
            buf.start_cycle()
        a.restart()
        a.flushed = True

    def _send_cluster(self, session_id: str, cluster_id: str,
                      payload: dict) -> None:
        """Deliver a payload to a cluster's aggregation topic.  Seam for
        ``CohortClient``: when the target cluster's head is fronted by the
        same endpoint, the broker round-trip is bypassed."""
        self.fc.call(T.cluster_agg(session_id, cluster_id), payload)

    def _finalize_root(self, ctx: _SessionCtx, strat: AggregationStrategy,
                       a: _Accumulator):
        """Root aggregator: collected inputs -> (global float32, state)."""
        if strat.reduction == "stack":
            stacked = a.stacked_views()     # strided, no duplicate copies
            weights = np.asarray(a.row_weights, np.float64)
            glob = strat.combine(stacked, weights, np)
            return {k: np.asarray(v, np.float32) for k, v in glob.items()}, None
        wsum = np.float64(a.weight)
        mean = {k: v / wsum for k, v in a.acc_views().items()}
        glob, new_state = strat.finalize(mean, ctx.global_params,
                                         ctx.server_state, np)
        return {k: np.asarray(v, np.float32) for k, v in glob.items()}, new_state

    # ------------------------------------------------------------------
    # Head gossip (async mode, repro.api.async_fl)
    # ------------------------------------------------------------------
    def _mint_site_model(self, ctx: _SessionCtx, strat: AggregationStrategy,
                         a: _Accumulator) -> None:
        """Gossip mode: a head that just flushed a partial also blends the
        buffer mean into its own model view (a *site model*, stamped
        ``(version, site_seq)``).  During a partition this is what keeps
        the root-less side converging; a real global (strictly newer
        version) always supersedes it."""
        acfg = ctx.async_cfg
        if not acfg or float(acfg.get("gossip_period_s", 0.0)) <= 0:
            return
        if strat.reduction == "stack":
            if a.n_rows == 0:
                return
            glob = strat.combine(a.stacked_views(),
                                 np.asarray(a.row_weights, np.float64), np)
            mean = {k: np.asarray(v, np.float32) for k, v in glob.items()}
        else:
            if a.weight <= 0:
                return
            wsum = np.float64(a.weight)
            mean = {k: np.asarray(v / wsum, np.float32)
                    for k, v in a.acc_views().items()}
        alpha = float(acfg.get("gossip_alpha", 0.5))
        view = ctx.view_params
        if view is None or any(k not in view for k in mean):
            ctx.view_params = mean
        else:
            ctx.view_params = {
                k: ((1.0 - alpha) * np.asarray(view[k], np.float64)
                    + alpha * np.asarray(mean[k], np.float64)).astype(
                        np.float32)
                for k in mean}
        ctx.site_seq += 1
        ctx.site_updates += 1

    def gossip_publish(self, session_id: str) -> bool:
        """Publish this head's current model view (global or site model) on
        the session's gossip topic.  QoS 1, so a partition holds — not
        drops — cross-site gossip until heal."""
        ctx = self.models.sessions.get(session_id)
        if ctx is None or ctx.async_cfg is None or ctx.terminated \
                or ctx.view_params is None:
            return False
        params = {k: np.asarray(v, np.float32)
                  for k, v in ctx.view_params.items()}
        if self.fc.wire_format == "tb":
            params = TensorBundle.from_params(params)
        if self.obs is not None:
            self.obs.trace("gossip", session=session_id,
                           client=self.client_id,
                           version=ctx.global_version,
                           site_seq=ctx.site_seq)
        self.fc.call(T.gossip(session_id, self.client_id),
                     {"params": params, "version": ctx.global_version,
                      "site_seq": ctx.site_seq, "sender": self.client_id})
        ctx.gossip_sent += 1
        return True

    def _on_gossip(self, topic: str, payload) -> None:
        """Round-stamped gossip merge: adopt a strictly-newer version,
        average same-version site models (symmetric gossip averaging — two
        heads converge to consensus), ignore older stamps.  Applied by
        every participant, so cluster members train on their head's site
        model while partitioned away from the root."""
        body = _body(payload)
        sid = topic.split("/")[2]
        ctx = self.models.sessions.get(sid)
        if ctx is None or ctx.async_cfg is None or ctx.terminated:
            return
        if body.get("sender") == self.client_id:
            return
        v = int(body.get("version", 0))
        s = int(body.get("site_seq", 0))
        if v > ctx.global_version:
            ctx.view_params = _as_params(body["params"])
            ctx.global_version = v
            ctx.site_seq = s
            ctx.version_from_gossip = True
            ctx.gossip_adopts += 1
        elif v == ctx.global_version and (s > 0 or ctx.site_seq > 0):
            inc = _as_params(body["params"])
            view = ctx.view_params
            if view is None:
                ctx.view_params = {k: np.asarray(x, np.float32)
                                   for k, x in inc.items()}
                ctx.site_seq = s
                ctx.gossip_adopts += 1
                return
            if set(view) != set(inc):
                return
            ctx.view_params = {
                k: ((np.asarray(view[k], np.float64)
                     + np.asarray(inc[k], np.float64))
                    * 0.5).astype(np.float32)
                for k in view}
            ctx.site_seq = max(ctx.site_seq, s)
            ctx.gossip_merges += 1

    def _on_global(self, topic: str, payload) -> None:
        body = _body(payload)
        sid = topic.split("/")[2]
        ctx = self.models.sessions.get(sid)
        if ctx is None:
            return
        if ctx.async_cfg is not None:
            ver = body.get("version", 0)
            # drop stale echoes (incl. the async root's own mint) — but a
            # version first learned through *gossip* still owes us its real
            # global: that publish carries the strategy reference and any
            # server-optimizer state the gossip message did not
            if ver < ctx.global_version or (ver == ctx.global_version
                                            and not ctx.version_from_gossip):
                return
        incoming = _as_params(_bundle_or_params(body))
        if self.update_filter is not None and ctx.params:
            # partial-update downlink: the aggregated (adapter) subset
            # merges over the locally-kept frozen base
            merged = dict(ctx.params)
            merged.update(incoming)
            ctx.params = merged
        else:
            ctx.params = incoming
        strat = self._strategy_for(ctx)
        if strat.needs_ref or strat.stateful or ctx.defense is not None:
            # only reference-using strategies pay for a retained global copy
            # (the defense norm gate also measures deltas against it)
            ctx.global_params = {k: np.array(v) for k, v in ctx.params.items()}
        if self.uplink_codec == "topk_int8_ef":
            # top-k delta base: both the sender (delta coding) and any
            # aggregator duty (densify over base) key off this shared copy
            # of the latest global
            ctx.topk_base = {k: np.asarray(v, np.float32)
                             for k, v in ctx.params.items()}
        if "server_state" in body:
            ctx.server_state = body["server_state"]
        ctx.global_version = body.get("version", ctx.global_version + 1)
        # a real global supersedes any gossip site model as the training base
        ctx.view_params = ctx.params
        ctx.site_seq = 0
        ctx.version_from_gossip = False
        if self.on_global_update:
            self.on_global_update(sid, ctx.params, ctx.global_version)


def _body(payload):
    if isinstance(payload, dict) and "a" in payload:
        args = payload["a"]
        return args[0] if args else {}
    return payload


def _as_params(obj) -> Params:
    """Normalize a wire params object to a dict of arrays (views when the
    source is a TensorBundle — zero copy)."""
    if isinstance(obj, TensorBundle):
        return obj.to_params()
    return {k: np.asarray(v) for k, v in obj.items()}


def _bundle_or_params(body, base: Optional[Params] = None) \
        -> Union[TensorBundle, Params]:
    p = body["params"]
    if body.get("codec") == "topk_int8_ef":
        return _densify_topk(body, base)
    if body.get("quantized"):
        return _dequantize(p, body["scales"])
    return p


def _dequantize(q_obj, s_obj) -> Params:
    """int8 + per-row scales -> float32 params, via the SAME dequantizer
    the compiled ``compressed`` schedule uses."""
    from repro.dist.compression import dequantize_int8
    q = _as_params(q_obj)
    s = _as_params(s_obj)
    return {k: dequantize_int8(v, s[k], xp=np) for k, v in q.items()}


def _densify_topk(body, base: Optional[Params] = None) -> Params:
    """Top-k int8 payload -> dense float32 params (the slow path: defense
    screening and stack strategies; the sum accumulators consume the
    sparse form directly).  Delta-coded payloads densify over ``base``
    (the receiver's copy of the global the sender coded against)."""
    from repro.dist.compression import densify_topk
    q = _as_params(body["params"])
    idx = _as_params(body["indices"])
    s = _as_params(body["scales"])
    shapes = body["shapes"]
    out = {k: densify_topk(idx[k], v, s[k], tuple(shapes[k]), xp=np)
           for k, v in q.items()}
    if body.get("base_version") is not None and base is not None:
        for k, v in out.items():
            if k in base and np.shape(base[k]) == v.shape:
                out[k] = v + np.asarray(base[k], np.float32)
    return out


def _acc_bytes(ctx: _SessionCtx) -> int:
    """Live accumulator bytes for ``ctx`` (incremental counters; kept for
    introspection/tests)."""
    return sum(a.alloc_bytes for a in ctx.accs.values())


def _will_payload(client_id: str) -> bytes:
    # a minimal MQTTFC frame announcing the dead client (legacy header:
    # receivers accept both generations)
    from repro.core import mqttfc as F
    import msgpack
    body = F.encode({"a": [client_id], "k": {}, "s": client_id})
    header = msgpack.packb((client_id, 0, 0, 1, 0, "zlib"))
    return len(header).to_bytes(4, "big") + header + body
