"""Fleet-scale cohorts: thousands of logical clients behind ONE endpoint.

The paper's target deployments are edge fleets of 10^4-10^6 devices, but
one Python ``SDFLMQClient`` per participant tops out at a few hundred.  A
``CohortClient`` fronts N *logical* client ids over a single MQTT
connection, with memory-bounded per-member state:

  * **ParamBank** — a struct-of-arrays parameter bank: per tensor key one
    ``(N, *shape)`` array; logical client i IS row i.  No per-member param
    pytrees, no per-member Python objects beyond a row index.
  * **shared accumulator arenas** — aggregation duties held by fronted
    members reuse the same streaming flat-f64 ``_Accumulator`` machinery as
    individual clients, in one shared per-session dict (``_SessionCtx``).
  * **control-plane batching** — one ``cohort_session`` RPC joins all N
    ids, one ``cohort_ready`` reports the round, and the coordinator sends
    one ``role_assignment_batch`` per cohort instead of N messages.
  * **intra-cohort bypass** — a contribution whose target cluster head is
    fronted by the same cohort is ingested by a direct call (the exact
    ``_on_cluster_input`` handler the broker would invoke), skipping frame
    encode/route/decode; only cross-cohort partials and the retained
    global publish touch the broker.

Bit-identity: a federation fronted by one cohort replays the exact
per-accumulator float64 operation order of N individual clients (members
ingest in global sorted order with the same depth-first flush cascade), so
the final global is bit-identical — property-tested for fedavg / fedprox /
trimmed_mean at cohort sizes {1, 7, 64}.  With several cohorts whose
members share a cluster, the pre-aggregated cross-cohort partial changes
the f64 association order; results then agree to float tolerance instead.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core import topics as T
from repro.core.client import (Params, SDFLMQClient, _Accumulator,
                               _SessionCtx)
from repro.core.mqttfc import raw_handler
from repro.core.roles import ClientAssignment, Duty
from repro.core.stats import ClientStats


class ParamBank:
    """Struct-of-arrays per-member parameter storage.

    ``data[key]`` is one ``(N, *shape)`` C-contiguous array; logical
    member i owns row i.  Row views are C-contiguous slices, so numpy
    reductions over a row are bit-identical to the same reduction over a
    standalone copy of that row (same pairwise-summation layout).
    """

    def __init__(self, member_ids: list, template: Params):
        self.ids: list[str] = sorted(member_ids)
        self.index: dict[str, int] = {c: i for i, c in enumerate(self.ids)}
        self.n = len(self.ids)
        # explicit allocate-and-fill: ascontiguousarray of a broadcast view
        # can hand back the read-only view itself when n == 1
        self.data: dict[str, np.ndarray] = {}
        for k, v in template.items():
            v = np.asarray(v)
            arr = np.empty((self.n,) + v.shape, v.dtype)
            arr[...] = v
            self.data[k] = arr
        self.weights = np.ones(self.n, np.float64)

    @property
    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.data.values()) + self.weights.nbytes

    def row(self, member_id: str) -> Params:
        """Member's params as views into the bank (zero copy)."""
        i = self.index[member_id]
        return {k: v[i] for k, v in self.data.items()}

    def set_row(self, member_id: str, params: Params,
                weight: Optional[float] = None) -> None:
        i = self.index[member_id]
        for k, v in params.items():
            self.data[k][i] = v
        if weight is not None:
            self.weights[i] = float(weight)

    def weight(self, member_id: str) -> float:
        return float(self.weights[self.index[member_id]])

    def broadcast(self, params: Params) -> None:
        """Load a new global into every row (round start)."""
        for k, v in params.items():
            self.data[k][:] = np.asarray(v)[None]


class CohortArbiter:
    """Role arbiter over N fronted members: per-member assignments, one
    merged duty index (cluster ids are unique per head, so duties never
    collide), and the cohort connection's subscription set as the union of
    every member's duty topics."""

    def __init__(self, cohort_id: str):
        self.client_id = cohort_id
        self.members: dict[str, ClientAssignment] = {}
        self._duties: dict[str, Duty] = {}          # cluster_id -> duty
        self.subscribed_topics: list[str] = []
        self.role_changes = 0
        self.assignment = None      # base-class surface (unused by cohorts)

    @property
    def is_aggregator(self) -> bool:
        return bool(self._duties)

    def duty_for(self, cluster_id: str) -> Optional[Duty]:
        return self._duties.get(cluster_id)

    def train_cluster_of(self, member_id: str) -> Optional[str]:
        asg = self.members.get(member_id)
        return asg.train_cluster if asg is not None else None

    def apply_batch(self, assignments: list[dict]) -> tuple[list[str], list[str]]:
        """Fold a ``role_assignment_batch`` in; returns the subscription
        delta (to_unsubscribe, to_subscribe) for the shared connection."""
        for d in assignments:
            asg = ClientAssignment.from_dict(d)
            self.members[asg.client_id] = asg
            self.role_changes += 1
        return self._rebuild()

    def remove_members(self, member_ids) -> tuple[list[str], list[str]]:
        for cid in member_ids:
            self.members.pop(cid, None)
        return self._rebuild()

    def _rebuild(self) -> tuple[list[str], list[str]]:
        self._duties = {}
        new_topics = set()
        for asg in self.members.values():
            sid = (asg.duties[0].cluster_id if asg.duties
                   else asg.train_cluster or "").split(":")[0]
            for d in asg.duties:
                self._duties[d.cluster_id] = d
                new_topics.add(T.cluster_agg(sid, d.cluster_id))
        old_topics = set(self.subscribed_topics)
        self.subscribed_topics = sorted(new_topics)
        return sorted(old_topics - new_topics), sorted(new_topics - old_topics)


class CohortClient(SDFLMQClient):
    """One endpoint fronting N logical client ids (fleet-scale mode).

    The aggregation service, strategy hooks, defense plumbing, and global
    handling are inherited unchanged from ``SDFLMQClient`` — a cohort IS a
    client whose arbiter merges N members' duties and whose local-training
    state lives in a ``ParamBank`` instead of one pytree.
    """

    def __init__(self, cohort_id: str, broker, member_ids: list,
                 wire_format: str = "tb",
                 stats: Optional[ClientStats] = None):
        super().__init__(cohort_id, broker, preferred_role="trainer",
                         stats=stats or ClientStats(cohort_id),
                         wire_format=wire_format)
        self.member_ids: list[str] = sorted(str(m) for m in member_ids)
        self.active: set[str] = set(self.member_ids)
        self.arbiter = CohortArbiter(cohort_id)     # replaces RoleArbiter
        self.banks: dict[str, ParamBank] = {}       # session -> bank
        self.joined: dict[str, list] = {}           # session -> accepted ids
        # cross-cohort uplink arenas: one accumulator per remote-headed
        # cluster, pre-aggregating our members' contributions into a single
        # covers=k partial (buffers reused across rounds)
        self._uplink: dict[tuple, _Accumulator] = {}
        self.bypassed_messages = 0      # intra-cohort deliveries kept local
        self.uplink_partials = 0        # cross-cohort batched publishes

    # ------------------------------------------------------------------
    # Control plane
    # ------------------------------------------------------------------
    def join_fleet_session(self, session_id: str, model_name: str,
                           fl_rounds: int = 0, capacity_min: int = 0,
                           capacity_max: int = 0,
                           session_time_s: float = 3600.0,
                           waiting_time_s: float = 120.0,
                           strategy: str = "fedavg") -> None:
        """Create-or-join ``session_id`` with every fronted member in ONE
        RPC (the coordinator's ``cohort_session`` endpoint)."""
        from repro.api.strategies import get_strategy
        strategy = get_strategy(strategy).name      # fail fast, canonical
        ctx = self.models.ensure(session_id, model_name)
        ctx.strategy = strategy
        self._subscribe_session(session_id)
        self.fc.call(T.coord("cohort_session"), session_id, self.client_id,
                     sorted(self.active), model_name, fl_rounds,
                     capacity_min, capacity_max, session_time_s,
                     waiting_time_s, preferred_role="trainer",
                     strategy=strategy)

    def _on_ctrl(self, payload: dict) -> None:
        ev = payload.get("event")
        if ev == "role_assignment_batch":
            self._apply_assignments(payload["assignments"])
        elif ev == "role_assignment":
            # an individually-routed member assignment (elastic paths)
            self._apply_assignments([payload["assignment"]])
        elif ev == "cohort_joined":
            sid = payload["session"]["session_id"]
            self.joined[sid] = list(payload.get("accepted", []))

    def _apply_assignments(self, assignments: list[dict]) -> None:
        to_unsub, to_sub = self.arbiter.apply_batch(assignments)
        for t in to_unsub:
            self.fc.unbind(t)
        for t in to_sub:
            self.fc.subscribe_raw(t, raw_handler(self._on_cluster_input))

    def signal_ready_all(self, session_id: str) -> None:
        """One batched readiness report for every active member."""
        ctx = self.models.sessions.get(session_id)
        self.fc.call(T.coord("cohort_ready"), session_id, self.client_id,
                     sorted(self.active),
                     round_idx=ctx.round_idx if ctx else None)

    def drop_members(self, session_id: str, member_ids) -> None:
        """Member-level churn: the named logical ids leave the session (one
        batched RPC, one coordinator rearrangement)."""
        gone = [m for m in member_ids if m in self.active]
        if not gone:
            return
        self.active.difference_update(gone)
        self.arbiter.remove_members(gone)
        self.fc.call(T.coord("cohort_leave"), session_id, self.client_id,
                     gone)

    # ------------------------------------------------------------------
    # Data plane
    # ------------------------------------------------------------------
    def set_bank(self, session_id: str, template: Params) -> ParamBank:
        """Allocate the session's struct-of-arrays bank from a per-member
        parameter template (all members start identical)."""
        bank = ParamBank(sorted(self.active), template)
        self.banks[session_id] = bank
        return bank

    def bank(self, session_id: str) -> ParamBank:
        return self.banks[session_id]

    def train_members(self, session_id: str,
                      fn: Callable[[str, Params], tuple[Params, float]],
                      from_global: bool = True) -> None:
        """Per-member training pass: ``fn(member_id, start_params) ->
        (new_params, weight)`` in sorted member order.  ``from_global``
        starts every member from the current global (standard FedAvg);
        otherwise from the member's own bank row (personalization)."""
        ctx = self.models.get(session_id)
        bank = self.banks[session_id]
        base = ctx.params if (from_global and ctx.params is not None) else None
        for cid in sorted(self.active):
            if cid not in bank.index:
                continue
            start = ({k: np.array(v) for k, v in base.items()}
                     if base is not None else
                     {k: np.array(v) for k, v in bank.row(cid).items()})
            new_params, w = fn(cid, start)
            bank.set_row(cid, new_params, w)

    def train_vectorized(self, session_id: str,
                         fn: Callable[[dict, np.ndarray, Optional[Params]],
                                      tuple[dict, np.ndarray]]) -> None:
        """Vectorized training pass over the whole bank: ``fn(data,
        weights, global_params) -> (data, weights)`` where every ``data``
        leaf is member-stacked ``(N, *shape)`` — the numpy twin of the
        compiled ``build_cohort_local_step`` vmap path."""
        ctx = self.models.get(session_id)
        bank = self.banks[session_id]
        data, weights = fn(bank.data, bank.weights, ctx.params)
        for k, v in data.items():
            if v is not bank.data[k]:
                bank.data[k][...] = v
        if weights is not bank.weights:
            bank.weights[...] = weights

    def run_local_round(self, session_id: str) -> None:
        """Publish every trained member row for aggregation, replaying the
        exact schedule N individual clients would produce: members ingest
        in global sorted order; a cluster headed by this cohort aggregates
        locally (direct handler call, depth-first flush cascade); a
        remote-headed cluster receives ONE pre-aggregated ``covers=k``
        partial at the position its last local member would have published.
        """
        ctx = self.models.get(session_id)
        if ctx.async_cfg is not None:
            raise RuntimeError("cohorts support synchronous sessions only")
        bank = self.banks[session_id]
        strat = self._strategy_for(ctx)
        members = [c for c in sorted(self.active)
                   if c in bank.index
                   and self.arbiter.train_cluster_of(c) is not None]
        # per remote-headed cluster: how many of our members remain before
        # the batched partial is complete and can be published
        remaining: dict[str, int] = {}
        for cid in members:
            cl = self.arbiter.train_cluster_of(cid)
            if self.arbiter.duty_for(cl) is None:
                remaining[cl] = remaining.get(cl, 0) + 1
        for cid in members:
            cluster = self.arbiter.train_cluster_of(cid)
            w = bank.weight(cid)
            if self.arbiter.duty_for(cluster) is not None:
                # head fronted by this cohort: direct ingest through the
                # real handler (defense, premap, flush — everything applies)
                body = {"params": bank.row(cid), "weight": w,
                        "sender": cid, "partial": False,
                        "round": ctx.round_idx}
                self.bypassed_messages += 1
                self._on_cluster_input(
                    T.cluster_agg(session_id, cluster), {"a": [body]})
            else:
                self._uplink_add(session_id, ctx, strat, cluster, cid, w,
                                 bank)
                remaining[cluster] -= 1
                if remaining[cluster] == 0:
                    self._uplink_publish(session_id, ctx, strat, cluster)

    # -- cross-cohort uplink: pre-aggregated covers=k partials ----------
    def _uplink_add(self, session_id: str, ctx: _SessionCtx, strat,
                    cluster: str, member_id: str, w: float,
                    bank: ParamBank) -> None:
        a = self._uplink.setdefault((session_id, cluster), _Accumulator())
        if a.flushed:
            a.restart()
        contrib: Params = bank.row(member_id)
        if not self._premap_is_identity(strat):
            # same premap, applied exactly once per leaf — the receiving
            # head treats the batch as already-premapped partial rows
            contrib = strat.premap(contrib, ctx.global_params, np)
        if strat.reduction == "stack":
            a.add_stack_row(contrib, w, expected_rows=1)
        else:
            a.add_sum(contrib, w)
        a.weight += w
        a.received += 1

    def _uplink_publish(self, session_id: str, ctx: _SessionCtx, strat,
                        cluster: str) -> None:
        a = self._uplink[(session_id, cluster)]
        if a.received == 0:
            return
        legacy_wire = self.fc.wire_format == "legacy"
        if strat.reduction == "stack":
            if legacy_wire:
                sv = a.stacked_views()
                payload = {"entries": [
                    {"params": {k: sv[k][i] for k in sv},
                     "weight": a.row_weights[i]} for i in range(a.n_rows)],
                    "weight": a.weight, "sender": self.client_id,
                    "partial": True, "covers": a.n_rows,
                    "round": ctx.round_idx}
            else:
                payload = {"stack": a.stack_slice(),
                           "weights": list(a.row_weights),
                           "weight": a.weight, "sender": self.client_id,
                           "partial": True, "covers": a.n_rows,
                           "round": ctx.round_idx}
        else:
            partial = (dict(a.acc_views()) if legacy_wire
                       else a.partial_bundle())
            payload = {"params": partial, "weight": a.weight,
                       "sender": self.client_id, "partial": True,
                       "covers": a.received, "round": ctx.round_idx}
        self.uplink_partials += 1
        self.fc.call(T.cluster_agg(session_id, cluster), payload)
        a.restart()
        a.flushed = True

    # -- intra-cohort bypass for the flush cascade ----------------------
    def _send_cluster(self, session_id: str, cluster_id: str,
                      payload: dict) -> None:
        if self.arbiter.duty_for(cluster_id) is not None:
            # parent head fronted by this cohort too: skip the broker
            self.bypassed_messages += 1
            self._on_cluster_input(
                T.cluster_agg(session_id, cluster_id), {"a": [payload]})
        else:
            self.fc.call(T.cluster_agg(session_id, cluster_id), payload)

    # cohorts never use the single-client training surface
    def send_local(self, session_id: str) -> None:  # pragma: no cover
        raise RuntimeError("CohortClient trains through run_local_round()")
