"""MQTT Fleet Control (MQTTFC) — the RFC layer SDFLMQ is built on
(paper §III-B1, §IV).

Remotely executable functions are bound to MQTT topics; any client can
publish to the function topic with arguments in the payload, and the bound
function runs on every subscriber.  Large payloads (model parameter sets)
ride the zero-copy TensorBundle wire format (repro.core.wire): tensors are
flattened once into the frame's data region, chunked into fixed-size parts
via memoryview slices (no per-part copies), reassembled into one
preallocated buffer at the receiver, and decoded as zero-copy views.  The
legacy msgpack-ExtType format remains as a fallback codec
(``wire_format="legacy"``) so every change is bit-identity-testable.

Frame layout (one wire message)::

    [4B header len][msgpack header][chunk]
    header = (sender, call_id, part_idx, n_parts, flags, codec,
              total_len, chunk_offset)            # 6-tuple = legacy frames
    flags:  1 = compressed   2 = TensorBundle body   4 = quantized payload

Compression defaults to zstd when the ``zstandard`` wheel is importable
(zlib — the paper's baseline — otherwise); bodies flagged as
int8-quantized skip the recompression attempt entirely, and incompressible
tensor bodies are detected with a cheap sample probe before paying for a
full-body compress.
"""
from __future__ import annotations

import itertools
import zlib
from collections import OrderedDict
from typing import Any, Callable, Optional

import msgpack
import numpy as np

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

from typing import TYPE_CHECKING

from repro.core import wire
from repro.core.broker import Message, TopicTrie

if TYPE_CHECKING:  # protocol import for typing only (no runtime cycle)
    from repro.api.transport import Transport

_NUMPY_EXT = 42

# frame flag bits
F_COMPRESSED = 1
F_TENSORBUNDLE = 2
F_QUANTIZED = 4


def default_codec() -> str:
    """zstd when the wheel is importable, else the paper's zlib baseline."""
    return "zstd" if _zstd is not None else "zlib"


def _default(obj):
    if isinstance(obj, np.ndarray):
        return msgpack.ExtType(_NUMPY_EXT, msgpack.packb(
            (obj.dtype.str, obj.shape, obj.tobytes())))
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"cannot serialize {type(obj)}")


def _ext_hook(code, data):
    if code == _NUMPY_EXT:
        dtype, shape, buf = msgpack.unpackb(data)
        return np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape).copy()
    return msgpack.ExtType(code, data)


def encode(obj: Any) -> bytes:
    """Legacy msgpack+ExtType body codec (fallback wire format)."""
    return msgpack.packb(obj, default=_default, use_bin_type=True)


def decode(data: bytes) -> Any:
    return msgpack.unpackb(data, ext_hook=_ext_hook, raw=False,
                           strict_map_key=False)


_FAST_LEVEL_BYTES = 1 << 20


def _build_control_dict() -> bytes:
    """Preset dictionary for SMALL control frames, derived from canonical
    SDFLMQ control payloads (join/create/heartbeat/topology shapes).  The
    corpus is hardcoded, so every endpoint derives the IDENTICAL
    dictionary — no wire negotiation, and the frame header's codec string
    is all a receiver needs.  zlib reads preset dictionaries back-to-front
    (most common substrings last)."""
    stats = {"cpu": 1.0, "memory_mb": 1024.0, "bandwidth_mbps": 10.0,
             "samples": 128, "battery": 1.0}
    samples = [
        {"a": ["train_session", "c0", "model", 0, "trainer", stats],
         "k": {}, "s": "c0"},
        {"a": ["train_session", "model", "c0", 8, 2, 64, 3600.0, 120.0,
               "aggregator", stats],
         "k": {"strategy": "fedavg", "async_cfg": None,
               "defense_cfg": None}, "s": "c0"},
        {"a": ["train_session", "c1"], "k": {}, "s": "c1"},
        {"a": [{"session_id": "train_session", "round": 1, "version": 1,
                "clusters": {"cluster_0": ["c0", "c1", "c2"]},
                "heads": ["c0"], "root": "c0", "strategy": "fedavg",
                "weight": 1.0, "sender": "coordinator",
                "partial": False}], "k": {}, "s": "coordinator"},
        {"a": ["sdflmq/session/train_session/cluster/cluster_0/agg",
               "sdflmq/session/train_session/global",
               "sdflmq/client/c0/ctrl"], "k": {}, "s": "param_server"},
    ]
    return b"".join(encode(s) for s in samples)[-32768:]


_CONTROL_DICT = _build_control_dict()
_ZSTD_DICT = (_zstd.ZstdCompressionDict(_CONTROL_DICT)
              if _zstd is not None else None)
# frames below this never try the dict codec (header + adler32 overhead)
DICT_MIN_BYTES = 48


def dict_codec() -> str:
    """Dictionary-trained codec for small control frames: zstd+dict when
    the wheel is importable, zlib's preset-dictionary mode otherwise."""
    return "zstd+dict" if _zstd is not None else "zlib+dict"


def compress(data, codec: str) -> bytes:
    # zlib/zstd accept any buffer-protocol object: no staging copy.
    # Large bodies (multi-MB float64 partial sums) drop to level 1: ~30%
    # less CPU for ~4% worse ratio on float-mantissa data.
    level = 1 if len(data) > _FAST_LEVEL_BYTES else 3
    if codec == "zlib":
        return zlib.compress(data, level=level)
    if codec == "zstd" and _zstd is not None:
        return _zstd.ZstdCompressor(level=level).compress(data)
    if codec == "zlib+dict":
        c = zlib.compressobj(3, zlib.DEFLATED, zlib.MAX_WBITS, 8,
                             zlib.Z_DEFAULT_STRATEGY, _CONTROL_DICT)
        return c.compress(data) + c.flush()
    if codec == "zstd+dict" and _zstd is not None:
        return _zstd.ZstdCompressor(level=3,
                                    dict_data=_ZSTD_DICT).compress(data)
    return data


def decompress(data, codec: str) -> bytes:
    # dispatch is on the FRAME header's codec string, so receivers decode
    # dictionary frames regardless of their own knobs
    if codec == "zlib":
        return zlib.decompress(data)
    if codec == "zstd" and _zstd is not None:
        return _zstd.ZstdDecompressor().decompress(data)
    if codec == "zlib+dict":
        d = zlib.decompressobj(zdict=_CONTROL_DICT)
        return d.decompress(data) + d.flush()
    if codec == "zstd+dict" and _zstd is not None:
        return _zstd.ZstdDecompressor(
            dict_data=_ZSTD_DICT).decompress(data)
    return data


_PROBE_BYTES = 4096
_PROBE_RATIO = 0.85


def _worth_compressing(body) -> bool:
    """Cheap entropy probe: compress small samples from the head, middle,
    and tail of the body; bail out early for high-entropy tensor payloads
    (random float mantissas probe at ~0.9, where a full-body compress
    costs ~16ms/MB for a marginal size win).  Three spread samples keep a
    mostly-zero body with one dense random region from skipping
    compression it would benefit from."""
    n = len(body)
    if n <= 3 * _PROBE_BYTES:
        return True
    mv = memoryview(body)
    k = _PROBE_BYTES
    sample = bytes(mv[:k]) + bytes(mv[n // 2:n // 2 + k]) + bytes(mv[n - k:])
    return len(zlib.compress(sample, 1)) < len(sample) * _PROBE_RATIO


class _FrameAssembly:
    """Multi-part frame reassembly into ONE preallocated buffer: each
    chunk is written at its header-declared offset (a single memcpy per
    part — the only copy on the receive path)."""

    __slots__ = ("buf", "n_parts", "got")

    def __init__(self, total_len: int, n_parts: int):
        self.buf = bytearray(total_len)
        self.n_parts = n_parts
        self.got: set[int] = set()

    def add(self, idx: int, offset: int, chunk) -> Optional[bytearray]:
        if idx not in self.got:
            self.got.add(idx)
            self.buf[offset:offset + len(chunk)] = chunk
        if len(self.got) == self.n_parts:
            return self.buf
        return None

    def has(self, idx: int) -> bool:
        return idx in self.got

    @property
    def nbytes(self) -> int:
        return len(self.buf)


class _LegacyAssembly:
    """Legacy reassembly (no total length on the wire): parts are kept and
    joined on completion."""

    __slots__ = ("n_parts", "parts")

    def __init__(self, n_parts: int):
        self.n_parts = n_parts
        self.parts: dict[int, bytes] = {}

    def add(self, idx: int, offset: int, chunk) -> Optional[bytes]:
        self.parts[idx] = bytes(chunk)
        if len(self.parts) == self.n_parts:
            return b"".join(self.parts[i] for i in range(self.n_parts))
        return None

    def has(self, idx: int) -> bool:
        return idx in self.parts

    @property
    def nbytes(self) -> int:
        return sum(len(p) for p in self.parts.values())


class MQTTFC:
    """Per-client fleet-control endpoint.  ``broker`` is any object
    implementing the ``repro.api.transport.Transport`` protocol (the sim
    broker, a LatencyTransport decorator, a real MQTT backend, ...).

    ``wire_format`` selects the body codec for tensor-bearing payloads:
    ``"tb"`` (default) is the zero-copy TensorBundle format, ``"legacy"``
    the original msgpack-ExtType path.  Receivers always understand both
    (the frame flags carry the format), so mixed fleets interoperate.
    """

    def __init__(self, broker: "Transport", client_id: str,
                 max_batch_bytes: int = 64 * 1024,
                 codec: Optional[str] = None,
                 compress_threshold: int = 4 * 1024,
                 will_topic: Optional[str] = None,
                 will_payload: bytes = b"",
                 wire_format: str = "tb",
                 max_assemblies: int = 256,
                 control_dict: bool = True):
        assert wire_format in ("tb", "legacy"), wire_format
        self.broker = broker
        self.client_id = client_id
        self._call_ids = itertools.count(1)   # per-endpoint: deterministic
        self.max_batch_bytes = max_batch_bytes
        self.codec = codec if codec is not None else default_codec()
        self.compress_threshold = compress_threshold
        # dictionary-trained codec for small control frames (below the
        # compress threshold, which plain compression never touches)
        self.control_dict = control_dict
        self.wire_format = wire_format
        self.max_assemblies = max_assemblies
        self._fns: dict[str, Callable] = {}
        self._filter_trie = TopicTrie()       # wildcard-bound handlers
        self._dispatch_cache: dict[str, Optional[Callable]] = {}
        # incomplete multi-part frames, LRU-ordered; key=(sender, topic),
        # value = {call_id: assembly} — per-sender FIFO delivery means a
        # part for call N+1 proves call N's missing parts were lost
        self._buffers: "OrderedDict[tuple, dict[int, Any]]" = OrderedDict()
        # at-least-once dedup: highest COMPLETED call_id per (sender,
        # topic).  call_ids are monotonic per endpoint and delivery is
        # per-sender FIFO, so one highwater integer detects any broker
        # redelivery of an already-processed call; duplicate parts inside
        # a still-assembling call are caught by the assembly itself.
        # Retained replays are exempt (a re-SUBSCRIBE legitimately
        # re-delivers the same call; routed deliveries carry retain=0).
        self._dedup_hw: "OrderedDict[tuple, int]" = OrderedDict()
        self._dedup_cap = 4096
        will = Message(will_topic, will_payload, qos=1) if will_topic else None
        self.session = broker.connect(client_id, self._on_message, will=will)
        # reusable encode buffer for tensor-bearing bodies: steady-state
        # rounds re-encode the same model size, so the second call onward
        # allocates nothing for the body
        self._arena = wire.FrameArena()
        # wire-stats (paper evaluates load): logical calls vs wire messages
        self.calls_sent = 0
        self.parts_sent = 0
        self.bytes_sent = 0
        self.raw_bytes_sent = 0
        self.reassembly_evictions = 0
        self.calls_received = 0
        self.parts_received = 0
        self.bytes_received = 0
        self.duplicate_drops = 0
        self.compress_attempts = 0
        self.compress_wins = 0
        self.dict_compress_wins = 0
        self.dict_bytes_saved = 0

    # ---- binding ---------------------------------------------------------
    def bind(self, topic: str, fn: Callable, qos: int = 1) -> None:
        """Bind a remotely executable function to a topic."""
        self._fns[topic] = fn
        if "+" in topic or "#" in topic:
            self._filter_trie.insert(topic, topic)
        self._dispatch_cache.clear()
        self.broker.subscribe(self.client_id, topic, qos=qos)

    def unbind(self, topic: str) -> None:
        if self._fns.pop(topic, None) is not None and (
                "+" in topic or "#" in topic):
            self._filter_trie.remove(topic, topic)
        self._dispatch_cache.clear()
        self.broker.unsubscribe(self.client_id, topic)

    def subscribe_raw(self, topic_filter: str, fn: Callable, qos: int = 1) -> None:
        """Subscribe with wildcard support; fn receives (topic, payload)."""
        if not getattr(fn, "_raw", False):
            fn = raw_handler(fn)
        self._fns[topic_filter] = fn
        if "+" in topic_filter or "#" in topic_filter:
            self._filter_trie.insert(topic_filter, topic_filter)
        self._dispatch_cache.clear()
        self.broker.subscribe(self.client_id, topic_filter, qos=qos)

    # ---- calling ---------------------------------------------------------
    def call(self, topic: str, *args, qos: int = 1, retain: bool = False,
             quantized: bool = False, **kwargs) -> None:
        """Invoke the function bound to ``topic`` on all subscribers.
        ``quantized=True`` marks the payload as already int8-compressed:
        the recompression attempt is skipped and the frame flagged."""
        obj = {"a": list(args), "k": kwargs, "s": self.client_id}
        flags = 0
        arena_view = None
        if self.wire_format == "tb" and wire.is_wire_payload(obj):
            body = arena_view = wire.encode_body(obj, arena=self._arena)
            flags |= F_TENSORBUNDLE
        else:
            body = encode(obj)
        self.raw_bytes_sent += len(body)
        frame_codec = self.codec
        if quantized:
            flags |= F_QUANTIZED
        elif len(body) >= self.compress_threshold and _worth_compressing(body):
            self.compress_attempts += 1
            comp = compress(body, self.codec)
            if len(comp) < len(body):
                body = comp
                flags |= F_COMPRESSED
                self.compress_wins += 1
                # the compressed copy supersedes the arena body
                if arena_view is not None:
                    self._arena.release(arena_view)
                    arena_view = None
        elif self.control_dict and DICT_MIN_BYTES <= len(body):
            # small control frame: plain compression never engages below
            # the threshold, but a shared preset dictionary seeded with
            # canonical SDFLMQ control shapes routinely halves these
            comp = compress(body, dict_codec())
            if len(comp) < len(body):
                self.dict_compress_wins += 1
                self.dict_bytes_saved += len(body) - len(comp)
                body = comp
                flags |= F_COMPRESSED
                frame_codec = dict_codec()
                if arena_view is not None:
                    self._arena.release(arena_view)
                    arena_view = None
        call_id = next(self._call_ids)
        total = len(body)
        n_parts = max(1, -(-total // self.max_batch_bytes))
        self.calls_sent += 1
        # Each frame copies its chunk out of the body before publishing, so
        # handlers re-entering call() from a synchronous broker delivery
        # only ever see completed frames.  The arena checkout stays open
        # until the last chunk is copied: a re-entrant take() falls back to
        # a fresh buffer, and the ownership-checked release below ignores
        # the nested caller releasing that fallback.
        mv = memoryview(body)
        for i in range(n_parts):
            off = i * self.max_batch_bytes
            chunk = mv[off:off + self.max_batch_bytes]
            header = msgpack.packb((self.client_id, call_id, i, n_parts,
                                    flags, frame_codec, total, off))
            frame = bytearray(4 + len(header) + len(chunk))
            frame[0:4] = len(header).to_bytes(4, "big")
            frame[4:4 + len(header)] = header
            frame[4 + len(header):] = chunk
            self.parts_sent += 1
            self.bytes_sent += len(frame)
            self.broker.publish(topic, frame, qos=qos, retain=retain,
                                sender=self.client_id)
        if arena_view is not None:
            self._arena.release(arena_view)

    # ---- reassembly ------------------------------------------------------
    def _assembly_for(self, key: tuple, call_id: int, total: int,
                      n_parts: int, legacy: bool):
        calls = self._buffers.get(key)
        if calls is None:
            calls = self._buffers[key] = {}
        else:
            self._buffers.move_to_end(key)
        asm = calls.get(call_id)
        if asm is None:
            # per-sender FIFO: a part of a NEWER call proves every missing
            # part of an older incomplete call was dropped — evict them
            stale = [c for c in calls if c < call_id]
            for c in stale:
                del calls[c]
                self.reassembly_evictions += 1
            asm = calls[call_id] = (_LegacyAssembly(n_parts) if legacy
                                    else _FrameAssembly(total, n_parts))
            self._evict_lru()
        return asm

    def _evict_lru(self) -> None:
        while sum(len(c) for c in self._buffers.values()) > self.max_assemblies:
            key, calls = next(iter(self._buffers.items()))
            calls.pop(next(iter(calls)))
            self.reassembly_evictions += 1
            if not calls:
                del self._buffers[key]

    def reassembly_pending(self) -> int:
        return sum(len(c) for c in self._buffers.values())

    def wire_stats(self) -> dict:
        return {
            "calls_sent": self.calls_sent,
            "parts_sent": self.parts_sent,
            "bytes_sent": self.bytes_sent,
            "raw_bytes_sent": self.raw_bytes_sent,
            "calls_received": self.calls_received,
            "parts_received": self.parts_received,
            "bytes_received": self.bytes_received,
            "duplicate_drops": self.duplicate_drops,
            "compress_attempts": self.compress_attempts,
            "compress_wins": self.compress_wins,
            "dict_compress_wins": self.dict_compress_wins,
            "dict_bytes_saved": self.dict_bytes_saved,
            "arena_reuse_hits": self._arena.reuse_hits,
            "arena_grows": self._arena.grows,
            "arena_busy_allocs": self._arena.busy_allocs,
            "arena_capacity_bytes": len(self._arena),
            "reassembly_pending": self.reassembly_pending(),
            "reassembly_evictions": self.reassembly_evictions,
            "codec": self.codec,
            "wire_format": self.wire_format,
        }

    # ---- dispatch --------------------------------------------------------
    def _on_message(self, msg: Message) -> None:
        payload = memoryview(msg.payload)
        self.parts_received += 1
        self.bytes_received += len(payload)
        hlen = int.from_bytes(payload[:4], "big")
        header = msgpack.unpackb(payload[4:4 + hlen])
        if len(header) >= 8:
            sender, call_id, idx, n_parts, flags, codec, total, off = header[:8]
            legacy_frame = False
        else:   # legacy 6-tuple frame
            sender, call_id, idx, n_parts, flags, codec = header
            total, off = 0, 0
            legacy_frame = True
        chunk = payload[4 + hlen:]
        fresh = not msg.retain
        if fresh:
            hw = self._dedup_hw.get((sender, msg.topic))
            if hw is not None and call_id <= hw:
                # broker redelivery of an already-completed call
                self.duplicate_drops += 1
                return
        if n_parts == 1:
            body = chunk
        else:
            key = (sender, msg.topic)
            asm = self._assembly_for(key, call_id, total, n_parts,
                                     legacy_frame)
            if fresh and asm.has(idx):
                self.duplicate_drops += 1   # duplicate part, call still open
                return
            body = asm.add(idx, off, chunk)
            if body is None:
                return
            del self._buffers[key][call_id]
            if not self._buffers[key]:
                del self._buffers[key]
        if fresh:
            self._mark_completed(sender, msg.topic, call_id)
        self.calls_received += 1
        if flags & F_COMPRESSED:
            body = decompress(body, codec)
        fn = self._dispatch(msg.topic)
        if fn is None:
            return
        if flags & F_TENSORBUNDLE:
            obj = wire.decode_body(body)
        else:
            obj = decode(body if isinstance(body, bytes) else bytes(body))
        if getattr(fn, "_raw", False):
            fn(msg.topic, obj)
        else:
            fn(*obj["a"], **obj["k"])

    def _mark_completed(self, sender: str, topic: str, call_id: int) -> None:
        key = (sender, topic)
        cur = self._dedup_hw.get(key)
        if cur is None or call_id > cur:
            self._dedup_hw[key] = call_id
        self._dedup_hw.move_to_end(key)
        while len(self._dedup_hw) > self._dedup_cap:
            self._dedup_hw.popitem(last=False)

    def _dispatch(self, topic: str) -> Optional[Callable]:
        """Handler lookup: exact map hit, then the wildcard trie through a
        per-topic cache (invalidated on bind/unbind)."""
        fn = self._fns.get(topic)
        if fn is not None:
            return fn
        if topic in self._dispatch_cache:
            return self._dispatch_cache[topic]
        filts = self._filter_trie.match(topic)
        fn = self._fns.get(filts[0]) if filts else None
        self._dispatch_cache[topic] = fn
        return fn

    def close(self, graceful: bool = True) -> None:
        self.broker.disconnect(self.client_id, graceful=graceful)


def raw_handler(fn):
    """Mark a handler as wanting (topic, payload) instead of (*args)."""
    def wrapper(topic, payload):
        return fn(topic, payload)
    wrapper._raw = True
    return wrapper
