"""MQTT Fleet Control (MQTTFC) — the RFC layer SDFLMQ is built on
(paper §III-B1, §IV).

Remotely executable functions are bound to MQTT topics; any client can
publish to the function topic with arguments in the payload, and the bound
function runs on every subscriber.  Large payloads (model parameter sets)
are serialized (msgpack with numpy extension), optionally compressed
(zlib — as in the paper — or zstd), split into fixed-size batches with
``batch_id``/part counters, and reassembled at the receiver.
"""
from __future__ import annotations

import itertools
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import msgpack
import numpy as np

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

from typing import TYPE_CHECKING

from repro.core.broker import Message

if TYPE_CHECKING:  # protocol import for typing only (no runtime cycle)
    from repro.api.transport import Transport

_NUMPY_EXT = 42


def _default(obj):
    if isinstance(obj, np.ndarray):
        return msgpack.ExtType(_NUMPY_EXT, msgpack.packb(
            (obj.dtype.str, obj.shape, obj.tobytes())))
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"cannot serialize {type(obj)}")


def _ext_hook(code, data):
    if code == _NUMPY_EXT:
        dtype, shape, buf = msgpack.unpackb(data)
        return np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape).copy()
    return msgpack.ExtType(code, data)


def encode(obj: Any) -> bytes:
    return msgpack.packb(obj, default=_default, use_bin_type=True)


def decode(data: bytes) -> Any:
    return msgpack.unpackb(data, ext_hook=_ext_hook, raw=False,
                           strict_map_key=False)


def compress(data: bytes, codec: str) -> bytes:
    if codec == "zlib":
        return zlib.compress(data, level=3)
    if codec == "zstd" and _zstd is not None:
        return _zstd.ZstdCompressor(level=3).compress(data)
    return data


def decompress(data: bytes, codec: str) -> bytes:
    if codec == "zlib":
        return zlib.decompress(data)
    if codec == "zstd" and _zstd is not None:
        return _zstd.ZstdDecompressor().decompress(data)
    return data


@dataclass
class _Reassembly:
    n_parts: int
    parts: dict[int, bytes] = field(default_factory=dict)

    def add(self, idx: int, data: bytes) -> Optional[bytes]:
        self.parts[idx] = data
        if len(self.parts) == self.n_parts:
            return b"".join(self.parts[i] for i in range(self.n_parts))
        return None


class MQTTFC:
    """Per-client fleet-control endpoint.  ``broker`` is any object
    implementing the ``repro.api.transport.Transport`` protocol (the sim
    broker, a LatencyTransport decorator, a real MQTT backend, ...)."""

    def __init__(self, broker: "Transport", client_id: str,
                 max_batch_bytes: int = 64 * 1024,
                 codec: str = "zlib",
                 compress_threshold: int = 4 * 1024,
                 will_topic: Optional[str] = None,
                 will_payload: bytes = b""):
        self.broker = broker
        self.client_id = client_id
        self._call_ids = itertools.count(1)   # per-endpoint: deterministic
        self.max_batch_bytes = max_batch_bytes
        self.codec = codec
        self.compress_threshold = compress_threshold
        self._fns: dict[str, Callable] = {}
        self._buffers: dict[tuple, _Reassembly] = {}
        will = Message(will_topic, will_payload, qos=1) if will_topic else None
        self.session = broker.connect(client_id, self._on_message, will=will)
        # wire-stats (paper evaluates load): logical calls vs wire messages
        self.calls_sent = 0
        self.parts_sent = 0
        self.bytes_sent = 0
        self.raw_bytes_sent = 0

    # ---- binding ---------------------------------------------------------
    def bind(self, topic: str, fn: Callable, qos: int = 1) -> None:
        """Bind a remotely executable function to a topic."""
        self._fns[topic] = fn
        self.broker.subscribe(self.client_id, topic, qos=qos)

    def unbind(self, topic: str) -> None:
        self._fns.pop(topic, None)
        self.broker.unsubscribe(self.client_id, topic)

    def subscribe_raw(self, topic_filter: str, fn: Callable, qos: int = 1) -> None:
        """Subscribe with wildcard support; fn receives (topic, payload)."""
        if not getattr(fn, "_raw", False):
            fn = raw_handler(fn)
        self._fns[topic_filter] = fn
        self.broker.subscribe(self.client_id, topic_filter, qos=qos)

    # ---- calling ---------------------------------------------------------
    def call(self, topic: str, *args, qos: int = 1, retain: bool = False,
             **kwargs) -> None:
        """Invoke the function bound to ``topic`` on all subscribers."""
        body = encode({"a": list(args), "k": kwargs, "s": self.client_id})
        self.raw_bytes_sent += len(body)
        flags = 0
        if len(body) >= self.compress_threshold:
            comp = compress(body, self.codec)
            if len(comp) < len(body):
                body, flags = comp, 1
        call_id = next(self._call_ids)
        n_parts = max(1, -(-len(body) // self.max_batch_bytes))
        self.calls_sent += 1
        for i in range(n_parts):
            chunk = body[i * self.max_batch_bytes:(i + 1) * self.max_batch_bytes]
            header = msgpack.packb((self.client_id, call_id, i, n_parts, flags,
                                    self.codec))
            frame = len(header).to_bytes(4, "big") + header + chunk
            self.parts_sent += 1
            self.bytes_sent += len(frame)
            self.broker.publish(topic, frame, qos=qos, retain=retain,
                                sender=self.client_id)

    # ---- dispatch --------------------------------------------------------
    def _on_message(self, msg: Message) -> None:
        hlen = int.from_bytes(msg.payload[:4], "big")
        sender, call_id, idx, n_parts, flags, codec = msgpack.unpackb(
            msg.payload[4:4 + hlen])
        chunk = msg.payload[4 + hlen:]
        key = (sender, call_id, msg.topic)
        if n_parts == 1:
            body = chunk
        else:
            buf = self._buffers.setdefault(key, _Reassembly(n_parts))
            body = buf.add(idx, chunk)
            if body is None:
                return
            del self._buffers[key]
        if flags & 1:
            body = decompress(body, codec)
        payload = decode(body)
        fn = self._fns.get(msg.topic)
        if fn is None:  # wildcard-bound handlers
            for filt, f in self._fns.items():
                from repro.core.broker import topic_matches
                if topic_matches(filt, msg.topic):
                    fn = f
                    break
        if fn is None:
            return
        if getattr(fn, "_raw", False):
            fn(msg.topic, payload)
        else:
            fn(*payload["a"], **payload["k"])

    def close(self, graceful: bool = True) -> None:
        self.broker.disconnect(self.client_id, graceful=graceful)


def raw_handler(fn):
    """Mark a handler as wanting (topic, payload) instead of (*args)."""
    def wrapper(topic, payload):
        return fn(topic, payload)
    wrapper._raw = True
    return wrapper
