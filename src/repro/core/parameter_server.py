"""Parameter Server logic (paper §III-B2): repository of global models for
all sessions handled by the coordinator + global update synchronizer.
Listens on the public global-model topics; can run co-located with the
coordinator or standalone.  Retained MQTT messages double as the
"synchronizer": any client (re)subscribing immediately receives the latest
global model — which is also the crash-recovery path for rejoining nodes.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import topics as T
from repro.core.broker import SimBroker
from repro.core.mqttfc import MQTTFC, raw_handler
from repro.core.wire import TensorBundle


class ParameterServer:
    def __init__(self, broker: SimBroker, client_id: str = "param_server"):
        self.fc = MQTTFC(broker, client_id)
        self.store: dict[str, dict] = {}       # sid -> {params, version, round}
        self.history: dict[str, list[int]] = {}
        self.fc.subscribe_raw(f"{T.ROOT}/session/+/global",
                              raw_handler(self._on_global))

    def _on_global(self, topic: str, payload) -> None:
        args = payload["a"] if isinstance(payload, dict) and "a" in payload else [payload]
        body = args[0]
        sid = topic.split("/")[2]
        if body.get("quantized"):
            # int8 downlink codec: mirror the dequantized global so readers
            # always see plain f32 params
            from repro.core.client import _bundle_or_params
            p = _bundle_or_params(body)
        else:
            p = body["params"]
        params = (p.to_params() if isinstance(p, TensorBundle)
                  else {k: np.asarray(v) for k, v in p.items()})
        self.store[sid] = {
            "params": params,
            "version": body.get("version", 0),
            "round": body.get("round", 0),
        }
        self.history.setdefault(sid, []).append(body.get("version", 0))

    def get_global(self, sid: str) -> Optional[dict]:
        return self.store.get(sid)

    def versions(self, sid: str) -> list[int]:
        return self.history.get(sid, [])
