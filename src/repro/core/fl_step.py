"""The compiled FL round step — SDFLMQ's data plane.

One call = one federated round over all clients mapped onto the mesh:
  1. per-client local training step(s)  (vmap over the client axis),
  2. hierarchical weighted aggregation  (schedule from the coordinator's
     cluster tree via core/topology.py),
  3. implicit global broadcast          (every client slot ends up with the
                                         identical global model).

Client -> mesh mapping: client i owns index i of the FL client axis
("data" in replica mode, "pod" in shared mode); the coordinator's
``tree.client_order`` must be in the same order (the driver guarantees it).
Compiled steps are cached per AggSchedule signature — switching roles
between rounds costs a dictionary lookup once a topology has been seen,
the compiled-schedule analogue of the paper's re-subscription cheapness.
"""
from __future__ import annotations

from dataclasses import dataclass
from fnmatch import fnmatchcase
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.aggregation import aggregate_params
from repro.core.topology import AggSchedule
from repro.dist import sharding as shd
from repro.models import inputs as minputs
from repro.models import model_api
from repro.optim.api import apply_updates, make_optimizer


def client_axis_for(cfg: ArchConfig, mesh: Mesh) -> Optional[str]:
    ax = "data" if cfg.fl.mode == "replica" else "pod"
    return ax if ax in mesh.axis_names else None


def n_clients_for(cfg: ArchConfig, mesh: Mesh) -> int:
    ax = client_axis_for(cfg, mesh)
    return int(mesh.shape[ax]) if ax else 1


# --------------------------------------------------------------------------
# Partial updates: ParamFilter + LoRA-style adapter spec
# --------------------------------------------------------------------------

def _key_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def leaf_path_names(tree, is_leaf=None):
    """'/'-joined key-path name for every leaf, in ``tree_flatten`` order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    return ["/".join(_key_str(e) for e in path) for path, _ in flat]


@dataclass(frozen=True)
class ParamFilter:
    """Which parameter leaves are *trainable and shipped* in a federated
    round; everything else is the frozen base that never leaves the device.

    Patterns are ``fnmatch`` globs against the leaf's '/'-joined key path
    (e.g. ``"blocks/3/attn/wq"`` or a flat host-dict key).  A leaf is
    selected when it matches any ``include`` pattern and no ``exclude``
    pattern.  The string form accepted everywhere a knob is
    (``update_filter="*/lora_*,!*frozen*"``) separates patterns with commas
    and marks excludes with a leading ``!``.
    """
    include: tuple = ("*",)
    exclude: tuple = ()

    @staticmethod
    def parse(spec) -> Optional["ParamFilter"]:
        if spec is None or isinstance(spec, ParamFilter):
            return spec
        inc, exc = [], []
        for pat in str(spec).split(","):
            pat = pat.strip()
            if not pat:
                continue
            (exc if pat.startswith("!") else inc).append(pat.lstrip("!"))
        return ParamFilter(tuple(inc) or ("*",), tuple(exc))

    def matches(self, name: str) -> bool:
        if any(fnmatchcase(name, p) for p in self.exclude):
            return False
        return any(fnmatchcase(name, p) for p in self.include)

    def keep_list(self, tree, is_leaf=None):
        return [self.matches(n) for n in leaf_path_names(tree, is_leaf)]

    def mask(self, tree, is_leaf=None):
        """Same-structure pytree of Python bools (True = trainable)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_leaf)
        return jax.tree_util.tree_unflatten(
            treedef, self.keep_list(tree, is_leaf))

    def extract(self, tree) -> dict:
        """Flat ``{path_name: leaf}`` of the selected leaves — the wire
        payload for a partial update."""
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        out = {}
        for path, leaf in flat:
            name = "/".join(_key_str(e) for e in path)
            if self.matches(name):
                out[name] = leaf
        return out

    def merge(self, tree, update: dict):
        """Return ``tree`` with the leaves named in ``update`` replaced —
        the receive side of a partial update (frozen base kept local)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        for path, leaf in flat:
            name = "/".join(_key_str(e) for e in path)
            leaves.append(update.get(name, leaf))
        return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass(frozen=True)
class AdapterSpec:
    """LoRA-style adapter recipe: every 2-D weight whose path matches
    ``match`` gets a rank-``rank`` adapter pair ``<name>/lora_A`` (fan-in
    init) and ``<name>/lora_B`` (zeros — adapters start as the identity).
    ``filter()`` is the matching ParamFilter, so only adapter tensors are
    trained and shipped while the frozen base stays local."""
    rank: int = 8
    alpha: float = 16.0
    match: tuple = ("*",)

    def _adapts(self, name: str, d) -> bool:
        return (len(d.shape) == 2
                and any(fnmatchcase(name, p) for p in self.match))

    def adapter_decls(self, decls) -> dict:
        """Flat decl dict for the adapter bank of a base decl tree."""
        flat, _ = jax.tree_util.tree_flatten_with_path(
            decls, is_leaf=shd.is_decl)
        out = {}
        for path, d in flat:
            name = "/".join(_key_str(e) for e in path)
            if self._adapts(name, d):
                din, dout = d.shape
                out[f"{name}/lora_A"] = shd.decl(
                    (din, self.rank), (d.axes[0], None),
                    init="normal", dtype=jnp.float32)
                out[f"{name}/lora_B"] = shd.decl(
                    (self.rank, dout), (None, d.axes[1]),
                    init="zeros", dtype=jnp.float32)
        return out

    def filter(self) -> ParamFilter:
        return ParamFilter(include=("*/lora_A", "*/lora_B"))

    def apply(self, params, adapters: dict):
        """Fold the adapter bank into the base: W <- W + (alpha/r) A @ B
        for every adapted weight.  Pure function of both trees — usable
        inside a jitted loss or on host numpy params."""
        scale = self.alpha / float(self.rank)
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        leaves = []
        for path, leaf in flat:
            name = "/".join(_key_str(e) for e in path)
            a = adapters.get(f"{name}/lora_A")
            b = adapters.get(f"{name}/lora_B")
            if a is not None and b is not None:
                delta = (a.astype(jnp.float32) @ b.astype(jnp.float32))
                leaf = (leaf.astype(jnp.float32)
                        + scale * delta).astype(leaf.dtype)
            leaves.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, leaves)


# --------------------------------------------------------------------------
# Specs / structs
# --------------------------------------------------------------------------

def fl_param_decls(cfg: ArchConfig, n_clients: int):
    decls = model_api.param_decls(cfg)
    if n_clients > 1:
        decls = shd.prepend_axis(decls, n_clients, "clients")
    return decls


def fl_rules(cfg: ArchConfig, client_axis: Optional[str]):
    rules = shd.rules_for(cfg.fl.mode)
    rules["clients"] = client_axis
    return rules


def param_specs(cfg: ArchConfig, mesh: Mesh):
    n = n_clients_for(cfg, mesh)
    ax = client_axis_for(cfg, mesh)
    return shd.specs_for(fl_param_decls(cfg, n), fl_rules(cfg, ax), mesh)


def opt_state_specs(cfg: ArchConfig, mesh: Mesh, opt_name: str):
    n = n_clients_for(cfg, mesh)
    ax = client_axis_for(cfg, mesh)
    decls = fl_param_decls(cfg, n)
    rules = fl_rules(cfg, ax)
    pspecs = shd.specs_for(decls, rules, mesh)
    if opt_name == "sgdm":
        return {"mu": pspecs}
    if opt_name == "adamw":
        return {"m": pspecs, "v": pspecs}
    # adafactor: factoring applies to the PER-CLIENT shape (opt.init is
    # vmapped over the clients axis when present)
    lead = 1 if n > 1 else 0

    def f(d, s):
        parts = list(s) + [None] * (len(d.shape) - len(s))
        if len(d.shape) - lead >= 2:
            return {"vr": P(*parts[:-1]), "vc": P(*(parts[:-2] + [parts[-1]]))}
        return {"v": P(*parts)}
    fs = jax.tree_util.tree_map(f, decls, pspecs, is_leaf=shd.is_decl)
    return {"f": fs}


def state_specs(cfg: ArchConfig, mesh: Mesh, opt_name: str):
    return {"params": param_specs(cfg, mesh),
            "opt": opt_state_specs(cfg, mesh, opt_name),
            "step": P()}


def init_state(cfg: ArchConfig, mesh: Mesh, key, total_steps: int = 10000,
               update_filter=None):
    """Concrete, sharded train state (used by the real driver).

    With ``update_filter`` set, frozen (non-matching) leaves are broadcast
    from client 0 so every client starts from the SAME frozen base — the
    partial-update round never aggregates them, so they must agree up
    front (the shipped adapter subset is all that ever moves)."""
    opt = make_optimizer(cfg, total_steps=total_steps)
    n = n_clients_for(cfg, mesh)
    decls = fl_param_decls(cfg, n)
    rules = fl_rules(cfg, client_axis_for(cfg, mesh))
    shardings = shd.shardings_for(decls, rules, mesh)
    filt = ParamFilter.parse(update_filter)
    keep_mask = filt.mask(decls, is_leaf=shd.is_decl) if filt else None

    def mk():
        params = shd.materialize(decls, key)
        if keep_mask is not None and n > 1:
            params = jax.tree_util.tree_map(
                lambda p, k: p if k else jnp.broadcast_to(p[0:1], p.shape),
                params, keep_mask)
        return params
    params = jax.jit(mk, out_shardings=shardings)()
    init = jax.vmap(opt.init) if n > 1 else opt.init
    opt_state = jax.jit(init)(params)
    return {"params": params, "opt": opt_state, "step": jnp.zeros((), jnp.int32)}


def abstract_state(cfg: ArchConfig, mesh: Mesh, opt_name: str):
    """ShapeDtypeStruct state with shardings attached (dry-run)."""
    n = n_clients_for(cfg, mesh)
    decls = fl_param_decls(cfg, n)
    p_abs = shd.abstract(decls)
    opt = make_optimizer(cfg)
    init = jax.vmap(opt.init) if n > 1 else opt.init
    o_abs = jax.eval_shape(init, p_abs)
    specs = state_specs(cfg, mesh, opt.name)

    def attach(struct_tree, spec_tree):
        def one(st, sp):
            return jax.ShapeDtypeStruct(st.shape, st.dtype,
                                        sharding=NamedSharding(mesh, sp))
        return jax.tree_util.tree_map(one, struct_tree, spec_tree)

    return {
        "params": attach(p_abs, specs["params"]),
        "opt": attach(o_abs, specs["opt"]),
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=NamedSharding(mesh, P())),
    }


# --------------------------------------------------------------------------
# Step builders
# --------------------------------------------------------------------------

def _make_client_fn(cfg: ArchConfig, opt, local_steps: int,
                    frozen_mask=None):
    """One client's local training loop (E fused optimizer steps) — the body
    both the mesh-mapped round step and the host-path cohort step vmap.

    ``frozen_mask`` (same structure as params, Python-bool leaves, True =
    frozen) turns on partial updates: frozen leaves get zero gradients and
    are restored bit-exactly after the loop, so weight decay / momentum
    cannot drift the base the client never ships."""

    def local_step(params, opt_state, step, batch):
        (loss, parts), grads = jax.value_and_grad(
            model_api.loss_fn, argnums=1, has_aux=True)(cfg, params, batch)
        if frozen_mask is not None:
            grads = jax.tree_util.tree_map(
                lambda g, f: jnp.zeros_like(g) if f else g,
                grads, frozen_mask)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    def client_fn(params_c, opt_c, step, batch_c):
        base = params_c
        loss = jnp.float32(0.0)
        for _ in range(local_steps):
            params_c, opt_c, loss = local_step(params_c, opt_c, step, batch_c)
            step = step + 1
        if frozen_mask is not None:
            params_c = jax.tree_util.tree_map(
                lambda new, old, f: old if f else new,
                params_c, base, frozen_mask)
        return params_c, opt_c, loss

    return client_fn


def init_cohort_state(cfg: ArchConfig, n_cohort: int, key,
                      total_steps: int = 10000):
    """Struct-of-arrays bank for a host-path cohort: every parameter leaf
    gets a leading ``(n_cohort,)`` member axis and the optimizer state is
    vmapped to match — no mesh, no per-member pytrees."""
    opt = make_optimizer(cfg, total_steps=total_steps)
    decls = model_api.param_decls(cfg)
    if n_cohort > 1:
        decls = shd.prepend_axis(decls, n_cohort, "clients")
    params = shd.materialize(decls, key)
    init = jax.vmap(opt.init) if n_cohort > 1 else opt.init
    opt_state = jax.jit(init)(params)
    return {"params": params, "opt": opt_state,
            "step": jnp.zeros((), jnp.int32)}


def build_cohort_local_step(cfg: ArchConfig, n_cohort: int,
                            total_steps: int = 10000,
                            local_steps: Optional[int] = None):
    """Host-path cohort data plane: ONE compiled ``jax.vmap`` call trains
    all ``n_cohort`` members at once (the vectorized analogue of N
    individual ``Client.train`` calls).  No mesh is required — the member
    axis is a plain leading batch axis, so this runs on a single host
    device and feeds the MQTT-side cohort aggregation path.

    Returns ``cohort_local_step(state, batch) -> (state, metrics)`` where
    every leaf of ``state["params"]``/``state["opt"]`` and ``batch`` is
    member-stacked (leading dim ``n_cohort``) when ``n_cohort > 1``."""
    opt = make_optimizer(cfg, total_steps=total_steps)
    E = local_steps if local_steps is not None else cfg.fl.local_steps
    client_fn = _make_client_fn(cfg, opt, E)
    if n_cohort > 1:
        step_fn = jax.jit(jax.vmap(client_fn, in_axes=(0, 0, None, 0)))
    else:
        step_fn = jax.jit(client_fn)

    def cohort_local_step(state, batch):
        params, opt_state, losses = step_fn(
            state["params"], state["opt"], state["step"], batch)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + E}
        return new_state, {"loss": jnp.mean(losses)}

    return cohort_local_step


def build_fl_round_step(cfg: ArchConfig, mesh: Mesh, schedule: AggSchedule,
                        total_steps: int = 10000,
                        local_steps: Optional[int] = None,
                        strategy: str = "fedavg",
                        update_filter=None):
    """Returns fl_round_step(state, batch, weights) -> (state, metrics).

    batch: client-stacked when n_clients>1 (leading dim = clients);
    weights: (n_clients,) FedAvg weights (sample counts); ``strategy`` is
    any compiled-capable aggregation strategy name (repro.api.strategies) —
    the same registry the host MQTT path consumes.

    ``update_filter`` (ParamFilter or its comma string form) switches on
    partial updates: only matching leaves are trained and aggregated; the
    frozen remainder never enters a collective, so the aggregation traffic
    shrinks to the trainable (adapter) subset."""
    from repro.api.strategies import get_strategy
    strat = get_strategy(strategy)
    if not strat.compiled:
        raise ValueError(
            f"strategy {strat.name!r} has no compiled collective form "
            "(host path / Federation facade only)")
    model = model_api.get_model(cfg)
    opt = make_optimizer(cfg, total_steps=total_steps)
    n = n_clients_for(cfg, mesh)
    ax = client_axis_for(cfg, mesh)
    E = local_steps if local_steps is not None else cfg.fl.local_steps
    pspecs = param_specs(cfg, mesh)
    filt = ParamFilter.parse(update_filter)
    frozen_mask = None
    keep = None
    if filt is not None:
        decls = model_api.param_decls(cfg)  # per-client names (no axis)
        keep = filt.keep_list(decls, is_leaf=shd.is_decl)
        if all(keep):
            filt = keep = None              # filter selects everything
        else:
            if not any(keep):
                raise ValueError(
                    f"update_filter {update_filter!r} matches no parameter")
            leaves, treedef = jax.tree_util.tree_flatten(
                decls, is_leaf=shd.is_decl)
            frozen_mask = jax.tree_util.tree_unflatten(
                treedef, [not k for k in keep])
    client_fn = _make_client_fn(cfg, opt, E, frozen_mask=frozen_mask)

    def _agg(params, weights, ref):
        if keep is None:
            return aggregate_params(params, weights, mesh, ax,
                                    schedule, pspecs, strategy=strat,
                                    ref_params=ref)
        # aggregate only the trainable subset (as a flat-list pytree —
        # leaf order matches pspecs'); frozen leaves pass through from the
        # post-restore client params, which equal the pre-round state.
        leaves, treedef = jax.tree_util.tree_flatten(params)
        spec_leaves = jax.tree_util.tree_leaves(pspecs)
        sub = [l for l, k in zip(leaves, keep) if k]
        sub_specs = [s for s, k in zip(spec_leaves, keep) if k]
        sub_ref = None
        if ref is not None:
            rl = jax.tree_util.tree_leaves(ref)
            sub_ref = [r for r, k in zip(rl, keep) if k]
        agg = aggregate_params(sub, weights, mesh, ax, schedule,
                               sub_specs, strategy=strat,
                               ref_params=sub_ref)
        it = iter(agg)
        out = [next(it) if k else l for l, k in zip(leaves, keep)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def fl_round_step(state, batch, weights):
        if n > 1:
            params, opt_state, losses = jax.vmap(
                client_fn, in_axes=(0, 0, None, 0))(
                    state["params"], state["opt"], state["step"], batch)
            # pre-round params double as the previous global (every client
            # starts a round from the identical aggregated model)
            ref = state["params"] if strat.needs_ref else None
            params = _agg(params, weights, ref)
            loss = jnp.mean(losses)
        else:
            params, opt_state, loss = client_fn(
                state["params"], state["opt"], state["step"], batch)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + E}
        return new_state, {"loss": loss}

    return fl_round_step


def build_eval_step(cfg: ArchConfig):
    def eval_step(params, batch):
        loss, parts = model_api.loss_fn(cfg, params, batch)
        return parts["ce"]
    return eval_step
