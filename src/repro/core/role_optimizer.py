"""Role-optimization policies (paper §III-E6): the load balancer that ranks
clients for aggregator duty each round.  Policies are modular — register
new ones with ``@policy("name")``.  A policy sees the per-client stats and
the round index and returns client ids best-first.
"""
from __future__ import annotations

import functools
from typing import Callable

from repro.core.stats import ClientStats

_POLICIES: dict[str, Callable] = {}


def policy(name: str):
    def deco(fn):
        # Every policy sees the empty cohort (all clients churned out
        # mid-round); ranking nothing is [] — not a ZeroDivisionError in
        # round_robin's modulo or an arbitrary per-policy crash.
        @functools.wraps(fn)
        def guarded(stats: dict[str, ClientStats], round_idx: int,
                    *args, **kwargs) -> list[str]:
            if not stats:
                return []
            return fn(stats, round_idx, *args, **kwargs)
        _POLICIES[name] = guarded
        return guarded
    return deco


def get_policy(name: str) -> Callable:
    if name not in _POLICIES:
        raise KeyError(f"unknown role policy {name!r}; have {sorted(_POLICIES)}")
    return _POLICIES[name]


def list_policies() -> list[str]:
    return sorted(_POLICIES)


@policy("static")
def static_policy(stats: dict[str, ClientStats], round_idx: int) -> list[str]:
    """Fixed aggregator placement (the paper's client/server strawman)."""
    return sorted(stats)


@policy("round_robin")
def round_robin(stats: dict[str, ClientStats], round_idx: int) -> list[str]:
    """Rotate aggregator duty to avoid device exhaustion (paper §II)."""
    ids = sorted(stats)
    k = round_idx % len(ids)
    return ids[k:] + ids[:k]


@policy("memory_aware")
def memory_aware(stats: dict[str, ClientStats], round_idx: int) -> list[str]:
    """Rank by free memory + bandwidth (aggregators hold K models and
    receive them over the network — the paper's overflow scenario)."""
    def score(s: ClientStats) -> float:
        return s.mem_free_mb + 0.5 * s.bandwidth_mbps
    return sorted(stats, key=lambda c: -score(stats[c]))


@policy("perf_aware")
def perf_aware(stats: dict[str, ClientStats], round_idx: int) -> list[str]:
    """Memory/bandwidth/speed blend, penalizing measured round latency and
    consecutive aggregator duty (exhaustion avoidance)."""
    def score(s: ClientStats) -> float:
        return (s.mem_free_mb / max(s.mem_total_mb, 1.0)
                + 0.002 * s.bandwidth_mbps
                + 0.5 * s.cpu_speed
                - 0.2 * s.last_round_s
                - 0.1 * s.rounds_as_aggregator)
    return sorted(stats, key=lambda c: -score(stats[c]))


@policy("reputation_aware")
def reputation_aware(stats: dict[str, ClientStats], round_idx: int) -> list[str]:
    """Moving-target defense (fedstellar-style): aggregator duty rotates
    round-by-round across the *trusted* set (reputation >= 0.5, the
    coordinator's ``demote_below`` default), so a compromised head cannot
    own a cluster indefinitely; suspects sort to the back (best reputation
    first) and only ever rank when no trusted client remains."""
    def rep(c: str) -> float:
        return getattr(stats[c], "reputation", 1.0)
    ids = sorted(stats)
    trusted = [c for c in ids if rep(c) >= 0.5]
    suspects = [c for c in ids if rep(c) < 0.5]
    if not trusted:            # everyone quarantined: degrade gracefully
        return sorted(ids, key=lambda c: -rep(c))
    k = round_idx % len(trusted)
    return trusted[k:] + trusted[:k] + sorted(suspects, key=lambda c: -rep(c))


@policy("blackbox")
def blackbox(stats: dict[str, ClientStats], round_idx: int) -> list[str]:
    """Black-box optimizer stub (paper future work: swarm/GA): hill-climbs
    on last_round_s only, no visibility into client internals."""
    return sorted(stats, key=lambda c: stats[c].last_round_s)


@policy("genetic")
def genetic(stats: dict[str, ClientStats], round_idx: int,
            pop: int = 24, gens: int = 12, elite: int = 4) -> list[str]:
    """Black-box aggregator placement via a small genetic algorithm —
    the paper's §VII expansion.  Chromosome = permutation of clients
    (prefix become aggregator candidates); fitness = modeled round delay
    of a 30%-aggregator tree under that ranking (bandwidth-serialized
    receive at each head + slowest-trainer arrival).  Deterministic per
    (round, membership)."""
    import zlib

    import numpy as np

    ids = sorted(stats)
    n = len(ids)
    if n <= 2:
        return ids
    # stable across processes (python str hash is salted)
    seed = zlib.crc32(repr((round_idx, ids)).encode())
    rng = np.random.default_rng(seed)
    n_agg = max(1, int(round(n * 0.3)))

    def fitness(perm) -> float:
        heads = [ids[i] for i in perm[:n_agg]]
        rest = [ids[i] for i in perm[n_agg:]]
        share = -(-len(rest) // n_agg)
        total = 0.0
        worst_head = 0.0
        for hi, h in enumerate(heads):
            members = rest[hi * share:(hi + 1) * share]
            bw = stats[h].bandwidth_mbps + 1e-3
            recv = (len(members) + 1) / bw          # serialized inbound
            arrive = max([1.0 / max(stats[m].cpu_speed, 1e-3)
                          for m in members] or [0.0])
            head_t = (max(recv, arrive)
                      + 0.1 * stats[h].rounds_as_aggregator)
            total += head_t
            worst_head = max(worst_head, head_t)
        # Root fan-in: the elected root receives one model per OTHER
        # head, so a single-head tree pays nothing; the session elects
        # the best-connected head as root, so that is the one priced.
        root_bw = max(stats[h].bandwidth_mbps for h in heads) + 1e-3
        fan_in = (n_agg - 1) / root_bw
        # mean head load as a mild balance term: among placements with
        # the same critical path, prefer the one loading heads evenly
        return worst_head + fan_in + 0.05 * total / n_agg

    population = [rng.permutation(n) for _ in range(pop)]
    for _ in range(gens):
        scored = sorted(population, key=fitness)
        nxt = scored[:elite]
        while len(nxt) < pop:
            a, b = scored[rng.integers(0, max(elite * 2, 2))], \
                scored[rng.integers(0, max(elite * 2, 2))]
            cut = int(rng.integers(1, n))
            prefix = list(a[:cut])
            taken = set(prefix)         # O(n) crossover, not O(n^2) scans
            child = prefix + [g for g in b if g not in taken]
            if rng.random() < 0.3:                  # swap mutation
                i, j = rng.integers(0, n, 2)
                child[i], child[j] = child[j], child[i]
            nxt.append(np.asarray(child))
        population = nxt
    best = min(population, key=fitness)
    return [ids[i] for i in best]
