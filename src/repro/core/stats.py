"""Client system stats (paper: PSUtil/Tracemalloc readings drive the role
optimizer).  On the simulated fleet, heterogeneous per-client stats evolve
deterministically; on a real host, ``local_stats`` reads the process."""
from __future__ import annotations

import os
import resource
import zlib
from dataclasses import asdict, dataclass

import numpy as np


@dataclass
class ClientStats:
    client_id: str
    mem_total_mb: float = 1024.0
    mem_free_mb: float = 512.0
    bandwidth_mbps: float = 100.0
    cpu_speed: float = 1.0          # relative compute speed
    last_round_s: float = 0.0       # measured round latency
    rounds_as_aggregator: int = 0
    samples: int = 0                # local dataset size (FedAvg weight)
    reputation: float = 1.0         # coordinator trust score (defense)

    def to_dict(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "ClientStats":
        return ClientStats(**d)


class StatsSimulator:
    """Deterministic heterogeneous fleet: each client gets a capability draw
    plus slow drift + jitter per round (the paper's motivation: aggregator
    merit changes over time, so roles must move)."""

    def __init__(self, client_ids: list[str], seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.base: dict[str, ClientStats] = {}
        for cid in client_ids:
            self.base[cid] = ClientStats(
                client_id=cid,
                mem_total_mb=float(self.rng.choice([512, 1024, 2048, 4096])),
                bandwidth_mbps=float(self.rng.uniform(100, 1000)),
                cpu_speed=float(self.rng.uniform(0.25, 2.0)),
                samples=int(self.rng.integers(200, 2000)),
            )
            self.base[cid].mem_free_mb = self.base[cid].mem_total_mb * 0.7

    def sample(self, cid: str, round_idx: int) -> ClientStats:
        b = self.base[cid]
        # stable per-client phase: str hash() is randomized per process
        # (PYTHONHASHSEED), which would make fleets differ across runs
        phase = zlib.crc32(cid.encode()) % 13
        drift = 0.5 + 0.5 * np.sin(round_idx / 7.0 + phase)
        jitter = float(self.rng.uniform(0.8, 1.2))
        s = ClientStats(**b.to_dict())
        s.mem_free_mb = b.mem_total_mb * 0.4 * drift * jitter
        s.bandwidth_mbps = b.bandwidth_mbps * jitter
        return s


def local_stats(client_id: str) -> ClientStats:
    """Best-effort real process stats (no psutil in this environment)."""
    ru = resource.getrusage(resource.RUSAGE_SELF)
    rss_mb = ru.ru_maxrss / 1024.0
    total = os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_PHYS_PAGES") / 2**20
    return ClientStats(client_id=client_id, mem_total_mb=total,
                       mem_free_mb=max(total - rss_mb, 0.0),
                       bandwidth_mbps=1000.0, cpu_speed=1.0)
