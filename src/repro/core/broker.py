"""SimBroker — an in-process, deterministic MQTT-semantics message broker.

Implements the MQTT features SDFLMQ relies on:
  * topic trie with ``+`` (single-level) and ``#`` (multi-level) wildcards,
  * QoS 0 (fire-and-forget) and QoS 1 (at-least-once with acks + dedup),
  * retained messages (late subscribers immediately receive the last value),
  * last-will testament (published on abnormal disconnect -> the
    coordinator's failure detector),
  * ``$SYS``-style load counters (message/byte counts per topic class),
  * broker **bridging** (paper §III-F): brokers forward matching topics to
    each other with loop prevention via origin-broker tagging.

Delivery is a reentrancy-safe FIFO pump: handlers may publish from within
handlers; messages are processed in deterministic order.  This is the
control-plane transport; tensors never travel through it in the TPU
deployment (see DESIGN.md), though the host-side FedAvg path used by the
paper-replication benchmarks does move (small) model payloads here exactly
like the paper does over MQTT.
"""
from __future__ import annotations

import itertools
import random
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import msgpack


@dataclass
class Message:
    topic: str
    payload: bytes
    qos: int = 0
    retain: bool = False
    mid: int = 0
    origin_broker: str = ""
    duplicate: bool = False


@dataclass
class Subscription:
    client_id: str
    topic_filter: str
    qos: int = 0


def parse_share(topic_filter: str) -> tuple[Optional[str], str]:
    """Split an MQTT 5 shared-subscription filter.

    ``$share/<group>/<real filter>`` -> ``(group, real_filter)``; anything
    else -> ``(None, topic_filter)``.  Malformed ``$share`` filters (no
    group or no real filter) are treated as ordinary filters — they then
    fall under the ``$``-topic rule and simply never match."""
    if not topic_filter.startswith("$share/"):
        return None, topic_filter
    rest = topic_filter[len("$share/"):]
    group, sep, real = rest.partition("/")
    if not group or not sep or not real:
        return None, topic_filter
    return group, real


def topic_matches(topic_filter: str, topic: str) -> bool:
    """MQTT 3.1.1 wildcard matching: ``+`` one level, ``#`` trailing
    multi-level (also covering the parent level), and topics whose first
    level starts with ``$`` (e.g. ``$SYS``) are never matched by a filter
    that *starts* with a wildcard [MQTT-4.7.2-1]."""
    f_parts = topic_filter.split("/")
    t_parts = topic.split("/")
    if t_parts[0].startswith("$") and f_parts[0] in ("+", "#"):
        return False
    for i, f in enumerate(f_parts):
        if f == "#":
            return i == len(f_parts) - 1
        if i >= len(t_parts):
            return False
        if f != "+" and f != t_parts[i]:
            return False
    return len(f_parts) == len(t_parts)


class _TrieNode:
    __slots__ = ("children", "values", "hash_values")

    def __init__(self):
        self.children: dict[str, _TrieNode] = {}
        self.values: dict = {}       # value -> insertion seq (exact end)
        self.hash_values: dict = {}  # value -> seq ('#' at this level)


class TopicTrie:
    """Subscription trie with a per-topic match cache.

    ``insert``/``remove`` take a topic filter and an opaque hashable value;
    ``match(topic)`` returns matching values ordered by first insertion —
    the same tie-break a linear scan over insertion-ordered subscriptions
    produces.  Matches are memoized per concrete topic; any mutation
    invalidates the cache (subscribe/unsubscribe are rare, publishes are
    the hot path).  The MQTT-4.7.2-1 ``$``-topic rule is honored: filters
    beginning with a wildcard never match topics whose first level starts
    with ``$``.
    """

    __slots__ = ("_root", "_seq", "_cache", "size",
                 "cache_hits", "cache_misses")

    def __init__(self):
        self._root = _TrieNode()
        self._seq = itertools.count()
        self._cache: dict[str, tuple] = {}
        self.size = 0
        self.cache_hits = 0
        self.cache_misses = 0

    def insert(self, topic_filter: str, value) -> None:
        node = self._root
        for part in topic_filter.split("/"):
            if part == "#":
                if value not in node.hash_values:
                    node.hash_values[value] = next(self._seq)
                    self.size += 1
                self._cache.clear()
                return
            node = node.children.setdefault(part, _TrieNode())
        if value not in node.values:
            node.values[value] = next(self._seq)
            self.size += 1
        self._cache.clear()

    def remove(self, topic_filter: str, value) -> None:
        # walk down, then prune empty nodes on the way back up
        node = self._root
        path = []
        parts = topic_filter.split("/")
        for i, part in enumerate(parts):
            if part == "#":
                if node.hash_values.pop(value, None) is not None:
                    self.size -= 1
                    self._cache.clear()
                break
            nxt = node.children.get(part)
            if nxt is None:
                return
            path.append((node, part))
            node = nxt
        else:
            if node.values.pop(value, None) is not None:
                self.size -= 1
                self._cache.clear()
        for parent, part in reversed(path):
            child = parent.children[part]
            if child.children or child.values or child.hash_values:
                break
            del parent.children[part]

    def match(self, topic: str) -> tuple:
        """Values whose filter matches ``topic``, ordered by insertion."""
        hit = self._cache.get(topic)
        if hit is not None:
            self.cache_hits += 1
            return hit
        self.cache_misses += 1
        parts = topic.split("/")
        found: dict = {}          # value -> min seq
        sys_topic = parts[0].startswith("$")

        def _collect(vals):
            for v, s in vals.items():
                if v not in found or s < found[v]:
                    found[v] = s

        def _walk(node: _TrieNode, i: int, root_wild_ok: bool):
            if node.hash_values and (root_wild_ok or i > 0):
                _collect(node.hash_values)
            if i == len(parts):
                _collect(node.values)
                return
            nxt = node.children.get(parts[i])
            if nxt is not None:
                _walk(nxt, i + 1, root_wild_ok)
            if i > 0 or root_wild_ok:
                plus = node.children.get("+")
                if plus is not None:
                    _walk(plus, i + 1, root_wild_ok)

        # at the root level, wildcard branches ('+'/'#') are skipped for
        # $-topics; an exact first level starting with '$' still matches
        if sys_topic:
            nxt = self._root.children.get(parts[0])
            if nxt is not None:
                _walk(nxt, 1, False)
        else:
            _walk(self._root, 0, True)
        out = tuple(sorted(found, key=found.get))
        self._cache[topic] = out
        return out

    def invalidate(self) -> None:
        self._cache.clear()


def frame_part_info(payload) -> Optional[tuple]:
    """Best-effort sniff of an MQTTFC frame header: returns ``(sender,
    call_id, part_idx, n_parts)`` when ``payload`` looks like a fleet-
    control frame, ``None`` for opaque payloads.  Brokers use this to keep
    the FULL frame sequence of a retained multi-part message (one retained
    slot per topic holds every part of the latest call) instead of the
    classic single-slot behavior that would replay only the last frame."""
    try:
        mv = memoryview(payload)
        if len(mv) < 5:
            return None
        hlen = int.from_bytes(mv[:4], "big")
        if hlen <= 0 or hlen > 512 or 4 + hlen > len(mv):
            return None
        header = msgpack.unpackb(bytes(mv[4:4 + hlen]))
        if not isinstance(header, (list, tuple)) or len(header) < 6:
            return None
        sender, call_id, idx, n_parts = header[0], header[1], header[2], header[3]
        if not isinstance(sender, str):
            return None
        if not all(isinstance(x, int) and not isinstance(x, bool)
                   for x in (call_id, idx, n_parts)):
            return None
        if n_parts < 1 or not 0 <= idx < n_parts:
            return None
        return sender, call_id, idx, n_parts
    except Exception:
        return None


class RetainedSeq:
    """The retained state of one topic: either a single opaque message or
    the (possibly still accumulating) frame sequence of one multi-part
    fleet-control call, keyed by ``(sender, call_id)``."""

    __slots__ = ("key", "n_parts", "parts")

    def __init__(self, key: Optional[tuple], n_parts: int):
        self.key = key
        self.n_parts = n_parts
        self.parts: dict[int, Message] = {}

    def messages(self) -> list[Message]:
        return [self.parts[i] for i in sorted(self.parts)]


def retain_message(store: dict, msg: Message,
                   info: Optional[tuple] = None) -> None:
    """Shared retained-store update (SimBroker + MiniBroker semantics):
    opaque or single-part payloads replace the slot (last value wins); a
    part of a NEW multi-part call replaces the slot; further parts of the
    SAME call accumulate into it."""
    if info is None:
        info = frame_part_info(msg.payload)
    if info is None or info[3] <= 1:
        seq = RetainedSeq(None, 1)
        seq.parts[0] = msg
        store[msg.topic] = seq
        return
    sender, call_id, idx, n_parts = info
    key = (sender, call_id)
    cur = store.get(msg.topic)
    if cur is None or cur.key != key:
        cur = RetainedSeq(key, n_parts)
        store[msg.topic] = cur
    cur.parts[idx] = msg


@dataclass
class _ClientSession:
    client_id: str
    on_message: Callable[[Message], None]
    will: Optional[Message] = None
    subscriptions: dict[str, int] = field(default_factory=dict)
    connected: bool = True
    clean_session: bool = True
    # QoS-1 messages routed while a persistent session is offline, replayed
    # in order on resume: (msg, effective_qos)
    queued: deque = field(default_factory=deque)
    inflight_acks: set = field(default_factory=set)
    seen_mids: set = field(default_factory=set)


class SysStats:
    """$SYS-style counters."""

    def __init__(self):
        self.messages_received = 0
        self.messages_sent = 0
        self.bytes_received = 0
        self.bytes_sent = 0
        self.dropped_no_subscriber = 0
        self.per_topic_class: dict[str, int] = defaultdict(int)
        self.bridge_forwards = 0
        self.sessions_resumed = 0
        self.queued_offline = 0
        self.dropped_offline = 0
        self.shared_deliveries = 0

    def snapshot(self) -> dict:
        return {
            "messages_received": self.messages_received,
            "messages_sent": self.messages_sent,
            "bytes_received": self.bytes_received,
            "bytes_sent": self.bytes_sent,
            "dropped_no_subscriber": self.dropped_no_subscriber,
            "bridge_forwards": self.bridge_forwards,
            "sessions_resumed": self.sessions_resumed,
            "queued_offline": self.queued_offline,
            "dropped_offline": self.dropped_offline,
            "shared_deliveries": self.shared_deliveries,
            "per_topic_class": dict(self.per_topic_class),
        }


@dataclass
class _BridgeLink:
    """One directed broker-to-broker bridge with its own network model."""
    other: "SimBroker"
    filters: list[str]
    delay_s: float = 0.0
    jitter_s: float = 0.0
    drop_p: float = 0.0
    clock: Optional[object] = None         # SimClock-like: .now / .schedule
    rng: random.Random = field(default_factory=random.Random)
    forwarded: int = 0
    dropped: int = 0
    retransmitted: int = 0
    # inter-broker partition: while down, QoS>=1 / retained traffic is held
    # (the bridge's persistent session), QoS 0 is lost — healed bridges
    # release the backlog in original order
    down: bool = False
    held: list = field(default_factory=list)

    def release(self, src: "SimBroker") -> None:
        self.down = False
        backlog, self.held = self.held, []
        for msg in backlog:
            self.forward(src, msg)

    def forward(self, src: "SimBroker", msg: Message) -> None:
        if self.down:
            if msg.qos >= 1 or msg.retain:
                self.held.append(msg)
            else:
                self.dropped += 1
            return
        lat = self.delay_s + (self.rng.uniform(0.0, self.jitter_s)
                              if self.jitter_s else 0.0)
        if self.drop_p and self.rng.random() < self.drop_p:
            if msg.qos == 0:
                self.dropped += 1          # fire-and-forget: lost in transit
                return
            self.retransmitted += 1        # at-least-once across the bridge:
            lat *= 2.0                     # resend once, arriving late
        src.stats.bridge_forwards += 1
        self.forwarded += 1
        # re-originate per hop: the receiver sees the message as coming from
        # the broker that forwarded it (not the first broker on the path).
        # Each receiver then skips only its bridge back toward the sender,
        # which is loop-free on any TREE fabric (hub-and-spoke, chains —
        # the multi-broker shapes §III-F describes) of any size.  A cyclic
        # broker graph (full mesh of >= 3) would duplicate and is not
        # supported by this scheme.
        origin = src.name
        if self.clock is not None and lat > 0:
            self.clock.schedule(
                self.clock.now + lat,
                lambda: self.other.publish(msg.topic, msg.payload, msg.qos,
                                           msg.retain, _origin=origin))
        else:
            self.other.publish(msg.topic, msg.payload, msg.qos, msg.retain,
                               _origin=origin)


class SimBroker:
    """Reference implementation of the ``repro.api.transport.Transport``
    protocol (the surface MQTTFC, clients, and the coordinator depend on)."""

    def __init__(self, name: str = "broker0"):
        self.name = name
        # per-instance message-id counter: QoS-1 dedup and delivery logs are
        # isolated between brokers and deterministic across runs
        self._ids = itertools.count(1)
        self._clients: dict[str, _ClientSession] = {}
        self._retained: dict[str, RetainedSeq] = {}
        self._queue: deque = deque()
        self._pumping = False
        self._bridges: list[_BridgeLink] = []
        # subscription trie: value = (client_id, filter); match(topic) is
        # O(topic levels), memoized per topic, invalidated on sub changes
        self._trie = TopicTrie()
        # per-(group, real-filter) round-robin cursor for $share delivery
        self._share_rr: dict[tuple, int] = {}
        self.stats = SysStats()
        self.delivery_log: list[tuple[str, str, int]] = []  # (topic, client, size)
        self.log_deliveries = False

    # ---- connection lifecycle -------------------------------------------
    def connect(self, client_id: str, on_message: Callable[[Message], None],
                will: Optional[Message] = None,
                clean_session: Optional[bool] = None) -> _ClientSession:
        """``clean_session=False`` opts into MQTT persistent-session
        semantics: subscriptions survive a disconnect, and QoS-1 messages
        routed while the client is offline are queued and replayed in order
        when it reconnects with ``clean_session=False`` again.  ``None``
        (the default) means the backend default — a clean session."""
        clean = True if clean_session is None else bool(clean_session)
        old = self._clients.get(client_id)
        if old is not None and not clean and not old.clean_session:
            # resume the stored session: subscriptions stay in the trie
            was_offline = not old.connected
            old.on_message = on_message
            old.will = will
            old.connected = True
            if was_offline:
                self.stats.sessions_resumed += 1
                while old.queued:
                    msg, eff = old.queued.popleft()
                    self._deliver(old, msg, eff)
            return old
        if old is not None:        # clean reconnect: the old session's subs die
            for filt in old.subscriptions:
                self._trie.remove(parse_share(filt)[1], (client_id, filt))
        sess = _ClientSession(client_id, on_message, will, clean_session=clean)
        self._clients[client_id] = sess
        return sess

    def disconnect(self, client_id: str, graceful: bool = True) -> None:
        sess = self._clients.get(client_id)
        if sess is None:
            return
        will = sess.will
        if sess.clean_session:
            self._clients.pop(client_id, None)
            sess.connected = False
            for filt in sess.subscriptions:
                self._trie.remove(parse_share(filt)[1], (client_id, filt))
        else:
            # persistent session: keep subscriptions, start queueing QoS 1
            sess.connected = False
            sess.will = None       # the will belongs to the dead connection
        if not graceful and will is not None:
            self.publish(will.topic, will.payload,
                         qos=will.qos, retain=will.retain)

    # ---- subscriptions ---------------------------------------------------
    def subscribe(self, client_id: str, topic_filter: str, qos: int = 0) -> None:
        sess = self._clients[client_id]
        sess.subscriptions[topic_filter] = qos
        group, real = parse_share(topic_filter)
        self._trie.insert(real, (client_id, topic_filter))
        if group is not None:
            return      # retained messages are not sent to shared subs
        # retained delivery: the full frame sequence, in part order
        for topic, seq in list(self._retained.items()):
            if topic_matches(real, topic):
                for msg in seq.messages():
                    self._deliver(sess, msg)

    def unsubscribe(self, client_id: str, topic_filter: str) -> None:
        sess = self._clients.get(client_id)
        if sess is None:
            return
        if sess.subscriptions.pop(topic_filter, None) is not None:
            self._trie.remove(parse_share(topic_filter)[1],
                              (client_id, topic_filter))

    def subscriptions_of(self, client_id: str) -> list[str]:
        return list(self._clients[client_id].subscriptions)

    # ---- publishing ------------------------------------------------------
    def publish(self, topic: str, payload: bytes, qos: int = 0,
                retain: bool = False, sender: str = "",
                _origin: str = "") -> int:
        """``sender`` (the publishing client id) is accepted for Transport
        compatibility; decorators like LatencyTransport key per-link network
        models on it.  The sim broker itself only routes on the topic."""
        mid = next(self._ids)
        msg = Message(topic, payload, qos, retain, mid,
                      _origin or self.name)
        self.stats.messages_received += 1
        self.stats.bytes_received += len(payload)
        self.stats.per_topic_class[topic.split("/")[1] if "/" in topic else topic] += 1
        self._queue.append(msg)
        self._pump()
        return mid

    def _pump(self) -> None:
        if self._pumping:
            return
        self._pumping = True
        try:
            while self._queue:
                msg = self._queue.popleft()
                self._route(msg)
        finally:
            self._pumping = False

    def _route(self, msg: Message) -> None:
        if msg.retain:
            if msg.payload:
                retain_message(self._retained, msg)
            else:
                self._retained.pop(msg.topic, None)
        matched = False
        seen: set[str] = set()      # first matching filter per client wins
        shared: dict[tuple, list] = {}   # (group, real) -> [(sess, eff_qos)]
        for client_id, filt in self._trie.match(msg.topic):
            sess = self._clients.get(client_id)
            if sess is None:
                continue
            sub_qos = sess.subscriptions.get(filt)
            if sub_qos is None:
                continue
            eff_qos = min(msg.qos, sub_qos)
            group, real = parse_share(filt)
            if group is not None:
                shared.setdefault((group, real), []).append((sess, eff_qos))
                continue
            if client_id in seen:
                continue
            seen.add(client_id)
            if not sess.connected:
                if not sess.clean_session and eff_qos >= 1:
                    sess.queued.append((msg, eff_qos))
                    self.stats.queued_offline += 1
                    matched = True
                else:
                    self.stats.dropped_offline += 1
                continue
            self._deliver(sess, msg, eff_qos)
            matched = True
        for key, members in shared.items():
            if self._deliver_shared(key, members, msg):
                matched = True
        if not matched:
            self.stats.dropped_no_subscriber += 1
        # bridge forwarding with loop prevention
        for br in self._bridges:
            if msg.origin_broker == br.other.name:
                continue
            if any(topic_matches(f, msg.topic) for f in br.filters):
                br.forward(self, msg)

    def _deliver_shared(self, key: tuple, members: list,
                        msg: Message) -> bool:
        """One delivery per ``$share`` group: round-robin over the live
        members (in subscribe order); with every member offline, queue to
        the next persistent member instead so no QoS-1 message is lost."""
        live = [(s, q) for s, q in members if s.connected]
        if live:
            k = self._share_rr.get(key, 0)
            sess, eff_qos = live[k % len(live)]
            self._share_rr[key] = k + 1
            self.stats.shared_deliveries += 1
            self._deliver(sess, msg, eff_qos)
            return True
        durable = [(s, q) for s, q in members
                   if not s.clean_session and q >= 1]
        if durable:
            k = self._share_rr.get(key, 0)
            sess, eff_qos = durable[k % len(durable)]
            self._share_rr[key] = k + 1
            sess.queued.append((msg, eff_qos))
            self.stats.queued_offline += 1
            return True
        self.stats.dropped_offline += 1
        return False

    def _deliver(self, sess: _ClientSession, msg: Message, eff_qos: int = 0) -> None:
        if eff_qos >= 1:
            # at-least-once: dedup on (mid); ack bookkeeping
            if msg.mid in sess.seen_mids:
                return
            sess.seen_mids.add(msg.mid)
            sess.inflight_acks.add(msg.mid)
        self.stats.messages_sent += 1
        self.stats.bytes_sent += len(msg.payload)
        if self.log_deliveries:
            self.delivery_log.append((msg.topic, sess.client_id, len(msg.payload)))
        sess.on_message(msg)
        if eff_qos >= 1:
            sess.inflight_acks.discard(msg.mid)  # implicit PUBACK

    # ---- bridging --------------------------------------------------------
    def bridge(self, other: "SimBroker", topics: Optional[list[str]] = None,
               bidirectional: bool = True, delay_s: float = 0.0,
               jitter_s: float = 0.0, drop_p: float = 0.0,
               clock=None, seed: int = 0) -> None:
        """Forward matching topics to ``other`` (paper §III-F).  A bridge
        may carry its own link model: with a ``clock`` (a
        ``repro.api.transport.SimClock``, duck-typed — anything with
        ``now``/``schedule``) forwards are enqueued at their modeled
        cross-broker arrival time instead of pumping synchronously, so
        multi-broker federations see realistic inter-region lag."""
        filters = topics or ["#"]
        link = _BridgeLink(other, filters, delay_s, jitter_s, drop_p, clock,
                           random.Random(f"{seed}/{self.name}->{other.name}"))
        self._bridges.append(link)
        if bidirectional:
            back = _BridgeLink(self, filters, delay_s, jitter_s, drop_p,
                               clock,
                               random.Random(
                                   f"{seed}/{other.name}->{self.name}"))
            other._bridges.append(back)

    def set_bridge_down(self, other_name: Optional[str] = None,
                        down: bool = True) -> None:
        """Partition (or heal) this broker's bridges toward ``other_name``
        (all bridges when ``None``).  While down, reliable traffic queues on
        the bridge; healing replays the backlog in order."""
        for br in self._bridges:
            if other_name is not None and br.other.name != other_name:
                continue
            if down:
                br.down = True
            elif br.down:
                br.release(self)

    # ---- introspection ---------------------------------------------------
    def sys_stats(self) -> dict:
        out = self.stats.snapshot()
        out["trie_cache_hits"] = self._trie.cache_hits
        out["trie_cache_misses"] = self._trie.cache_misses
        out["subscriptions"] = self._trie.size
        out["retained_messages"] = len(self._retained)
        return out

    def retained_topics(self) -> list[str]:
        return sorted(self._retained)
