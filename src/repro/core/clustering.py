"""Clustering engine (paper §III-E2): hierarchical cluster construction.

The coordinator first selects aggregators (cluster heads) via the role-
optimization policy, then attaches trainers to heads level by level:
level 0 clusters hold trainers under a head; higher levels cluster the
heads themselves, up to a single root aggregator.  ``aggregator_ratio``
(paper Fig. 8 uses 30%) and ``levels`` control the shape; ``levels=1``
with one head is the centralized baseline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core.roles import ClientAssignment, Duty
from repro.core.stats import ClientStats


@dataclass
class Cluster:
    cluster_id: str                 # "<sid>:L<level>C<idx>"
    level: int
    head: str                       # aggregator client id
    members: list[str]              # clients publishing INTO this cluster
    parent: str | None = None       # cluster the head publishes to


@dataclass
class ClusterTree:
    session_id: str
    levels: list[list[Cluster]]     # levels[0] = leaf clusters
    client_order: list[str]         # stable participant ordering

    @property
    def root(self) -> Cluster:
        return self.levels[-1][0]

    def all_clusters(self) -> list[Cluster]:
        return [c for lvl in self.levels for c in lvl]

    def heads_at(self, level: int) -> list[str]:
        return [c.head for c in self.levels[level]]

    def assignments(self) -> dict[str, ClientAssignment]:
        """Per-client assignment: one leaf train-cluster + every aggregation
        duty the client heads (a client may head clusters at several levels,
        paper Fig. 5b)."""
        leaf_of = {}
        for c in self.levels[0]:
            for m in c.members:
                leaf_of[m] = c.cluster_id
        out = {cid: ClientAssignment(cid, leaf_of.get(cid))
               for cid in self.client_order}
        for c in self.all_clusters():
            out[c.head].duties.append(
                Duty(c.cluster_id, len(c.members), c.parent, c.level))
        for a in out.values():
            a.duties.sort(key=lambda d: d.level)
        return out

    def describe(self) -> dict:
        return {
            "session_id": self.session_id,
            "levels": [[{"id": c.cluster_id, "head": c.head,
                         "members": c.members, "parent": c.parent}
                        for c in lvl] for lvl in self.levels],
            "client_order": self.client_order,
        }

    @staticmethod
    def from_describe(d: dict) -> "ClusterTree":
        levels = [[Cluster(c["id"], li, c["head"], list(c["members"]),
                           c["parent"]) for c in lvl]
                  for li, lvl in enumerate(d["levels"])]
        return ClusterTree(d["session_id"], levels, list(d["client_order"]))


def _chunks(xs: list, n_groups: int) -> list[list]:
    """Split xs into n_groups contiguous, near-equal chunks."""
    n_groups = max(1, min(n_groups, len(xs)))
    size = math.ceil(len(xs) / n_groups)
    return [xs[i * size:(i + 1) * size] for i in range(n_groups)
            if xs[i * size:(i + 1) * size]]


def build_tree(session_id: str, clients: list[str], ranked_aggregators: list[str],
               aggregator_ratio: float = 0.3, levels: int = 3) -> ClusterTree:
    """clients: all participants; ranked_aggregators: aggregator candidates
    best-first (from the role optimizer).  levels counts aggregation levels
    including the root (paper's 3-layer = root + intermediates + trainers).
    """
    n = len(clients)
    assert n >= 1
    if levels <= 1 or n <= 2:
        head = ranked_aggregators[0]
        c = Cluster(f"{session_id}:L0C0", 0, head, list(clients))
        return ClusterTree(session_id, [[c]], list(clients))

    n_mid = max(1, min(int(round(n * aggregator_ratio)), n))
    heads0 = ranked_aggregators[:n_mid]
    # leaf level: each head anchors its own cluster (a head MUST be a member
    # of the cluster it aggregates — required by both the self-delivering
    # MQTT path and the collective mapping), trainers are spread across them
    head_set = set(heads0)                  # O(1) lookup at fleet scale
    rest = [c for c in clients if c not in head_set]
    shares = _chunks(rest, n_mid) if rest else []
    leaf = []
    for i, h in enumerate(heads0):
        members = [h] + (shares[i] if i < len(shares) else [])
        leaf.append(Cluster(f"{session_id}:L0C{i}", 0, h, members))
    tree_levels = [leaf]
    # intermediate levels cluster the heads of the previous level
    prev_heads = [c.head for c in leaf]
    lvl = 1
    while lvl < levels - 1 and len(prev_heads) > 2:
        n_h = max(1, len(prev_heads) // 3)
        hgroups = _chunks(prev_heads, n_h)
        cur = [Cluster(f"{session_id}:L{lvl}C{i}", lvl, grp[0], grp)
               for i, grp in enumerate(hgroups)]
        tree_levels.append(cur)
        prev_heads = [c.head for c in cur]
        lvl += 1
    # root
    root = Cluster(f"{session_id}:L{lvl}C0", lvl, prev_heads[0], prev_heads)
    tree_levels.append(root if isinstance(root, list) else [root])
    # wire parents
    for li in range(len(tree_levels) - 1):
        head_to_parent = {}
        for c in tree_levels[li + 1]:
            for m in c.members:
                head_to_parent[m] = c.cluster_id
        for c in tree_levels[li]:
            c.parent = head_to_parent.get(c.head)
    return ClusterTree(session_id, tree_levels, list(clients))


def validate_tree(tree: ClusterTree, clients: list[str]) -> list[str]:
    """Invariant checks (also used by hypothesis property tests).
    Returns list of violations (empty = valid)."""
    errs = []
    leaf_members = [m for c in tree.levels[0] for m in c.members]
    if sorted(leaf_members) != sorted(clients):
        errs.append("leaf clusters must partition the client set")
    if len(set(leaf_members)) != len(leaf_members):
        errs.append("client appears in more than one leaf cluster")
    for li in range(len(tree.levels) - 1):
        prev_heads = sorted(c.head for c in tree.levels[li])
        members = sorted(m for c in tree.levels[li + 1] for m in c.members)
        if prev_heads != members:
            errs.append(f"level {li + 1} members must equal level {li} heads")
    if len(tree.levels[-1]) != 1:
        errs.append("top level must be a single root cluster")
    for c in tree.all_clusters():
        if c.head not in c.members:
            errs.append(f"head {c.head} not in members of {c.cluster_id}")
    return errs
