"""Coordinator logic (paper §III-D/E): session management, clustering
engine, role (re)arrangement, role optimization, failure detection.

The coordinator never touches model tensors — it only consumes metadata
(client stats, readiness) and emits routing/placement metadata (role
assignments, cluster topology), exactly as in the paper.  Role
*rearrangement* messages go only to clients whose assignment changed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core import topics as T
from repro.core.clustering import ClusterTree, build_tree, validate_tree
from repro.core.defense import DefenseConfig, ReputationBook
from repro.core.mqttfc import MQTTFC
from repro.core.role_optimizer import get_policy
from repro.core.roles import ClientAssignment
from repro.core.session import FLSession, SessionState
from repro.core.stats import ClientStats


@dataclass
class CoordinatorConfig:
    role_policy: str = "memory_aware"
    aggregator_ratio: float = 0.3
    levels: int = 3
    round_deadline_s: float = 0.0
    # virtual seconds between per-level flush broadcasts on a deadline cut,
    # so level-l partials cross the (delayed) links before level-l+1 heads
    # see their own flush; 0 keeps the synchronous level-by-level pump
    flush_spacing_s: float = 0.0


class Coordinator:
    def __init__(self, broker, cfg: Optional[CoordinatorConfig] = None,
                 client_id: str = "coordinator", clock=None):
        # ``broker`` is any repro.api.transport.Transport implementation;
        # ``clock`` (a repro.api.transport.SimClock) arms waiting-time and
        # round-deadline timers on virtual time — without one, expiry stays
        # caller-driven (expire_waiting / force_round_end)
        self.cfg = cfg or CoordinatorConfig()
        self.clock = clock
        self.fc = MQTTFC(broker, client_id)
        self.sessions: dict[str, FLSession] = {}
        self.trees: dict[str, ClusterTree] = {}
        self.assignments: dict[str, dict[str, ClientAssignment]] = {}
        # wire-form assignment cache: avoids re-serializing 100k unchanged
        # assignments every rearrangement just to diff them
        self._assign_wire: dict[str, dict[str, dict]] = {}
        # cohort registry: one CohortClient endpoint fronts many logical
        # ids over a single connection — control traffic for a fronted id
        # routes to (and batches on) the cohort's own control topic
        self.cohort_members: dict[str, set[str]] = {}
        self._cohort_of: dict[str, str] = {}
        self.failed_clients: set[str] = set()
        self.on_round_complete: Optional[Callable] = None   # hook for driver
        self.rearrangement_messages = 0     # paper's "negligible cost" claim
        self.arrangement_messages = 0
        self.deadline_cuts = 0              # rounds ended by the deadline
        self.roles_rotations = 0            # aggregator-set changes (defense)
        self._pending_cut: dict[str, int] = {}   # sid -> round being cut
        # defense state: per-session reputation books + heartbeat bookkeeping
        self.books: dict[str, ReputationBook] = {}
        self._heartbeats: dict[str, dict[str, float]] = {}   # sid -> cid -> t
        # optional telemetry facade (repro.obs.Telemetry); set by
        # Federation(metrics=...).  None = zero-overhead default.
        self.obs = None
        self._round_wall: dict[str, float] = {}  # sid -> perf_counter stamp
        # RFC bindings
        self.fc.bind(T.coord("create_session"), self._create_session)
        self.fc.bind(T.coord("join_session"), self._join_session)
        self.fc.bind(T.coord("leave_session"), self._leave_session)
        self.fc.bind(T.coord("client_ready"), self._client_ready)
        self.fc.bind(T.coord("cohort_session"), self._cohort_session)
        self.fc.bind(T.coord("cohort_ready"), self._cohort_ready)
        self.fc.bind(T.coord("cohort_leave"), self._cohort_leave)
        self.fc.bind(T.coord("heartbeat"), self._heartbeat)
        self.fc.bind(T.coord("defense_report"), self._defense_report)
        self.fc.subscribe_raw(f"{T.ROOT}/will/+", self._on_will_raw)

    # ------------------------------------------------------------------
    # RFC endpoints
    # ------------------------------------------------------------------
    def _create_session(self, session_id: str, model_name: str, creator: str,
                        fl_rounds: int, capacity_min: int, capacity_max: int,
                        session_time_s: float = 3600.0,
                        waiting_time_s: float = 120.0,
                        preferred_role: str = "aggregator",
                        stats: Optional[dict] = None,
                        strategy: str = "fedavg",
                        async_cfg: Optional[dict] = None,
                        defense_cfg: Optional[dict] = None) -> None:
        if session_id in self.sessions:
            # paper: first create wins; later requests are dumped
            return
        s = FLSession(session_id, model_name, creator, fl_rounds,
                      capacity_min, capacity_max, session_time_s,
                      waiting_time_s, strategy=strategy,
                      round_deadline_s=self.cfg.round_deadline_s,
                      async_cfg=dict(async_cfg) if async_cfg else None,
                      defense_cfg=dict(defense_cfg) if defense_cfg else None)
        self.sessions[session_id] = s
        if s.defense_cfg is not None:
            self.books[session_id] = ReputationBook(
                DefenseConfig.from_wire(s.defense_cfg))
            self._heartbeats[session_id] = {}
        if self.clock is not None:
            s.created_at = self.clock.now
            if 0 < waiting_time_s < float("inf"):
                self.clock.schedule(self.clock.now + waiting_time_s,
                                    lambda: self.expire_waiting(session_id),
                                    timer=True)
        st = ClientStats.from_dict(stats) if stats else ClientStats(creator)
        s.join(creator, st, preferred_role)
        self._note_alive(session_id, creator)
        self._notify(creator, {"event": "session_created",
                               "session": s.describe()})
        self._maybe_start(session_id)

    def _join_session(self, session_id: str, client_id: str, model_name: str,
                      fl_rounds: int = 0, preferred_role: str = "trainer",
                      stats: Optional[dict] = None) -> None:
        s = self.sessions.get(session_id)
        if s is None or s.model_name != model_name:
            self._notify(client_id, {"event": "join_rejected",
                                     "session_id": session_id})
            return
        st = ClientStats.from_dict(stats) if stats else ClientStats(client_id)
        ok = s.join(client_id, st, preferred_role)
        if ok:
            self._note_alive(session_id, client_id)
        self._notify(client_id, {"event": "joined" if ok else "join_rejected",
                                 "session": s.describe()})
        if ok and s.state == SessionState.RUNNING:
            self._arrange(session_id, rearrange=True)   # elastic join
        else:
            self._maybe_start(session_id)

    def _leave_session(self, session_id: str, client_id: str) -> None:
        s = self.sessions.get(session_id)
        if s:
            s.leave(client_id)
            if s.state == SessionState.RUNNING:
                self._arrange(session_id, rearrange=True)

    def _client_ready(self, session_id: str, client_id: str,
                      stats: Optional[dict] = None,
                      metrics: Optional[dict] = None,
                      round_idx: Optional[int] = None) -> None:
        """Round-status update (paper §III-E4): client finished its role's
        work; carries fresh system stats for the optimizer.  ``round_idx``
        stamps which round the client reported for — a readiness signal
        held back by a partition (or riding a slow link) must not count
        toward a later round."""
        s = self.sessions.get(session_id)
        if s is None or s.state != SessionState.RUNNING:
            return
        if s.async_cfg is not None:
            return      # async sessions have no round barrier to report to
        if round_idx is not None and round_idx != s.round_idx:
            return                           # stale readiness: discard
        st = ClientStats.from_dict(stats) if stats else None
        first = not s.ready
        s.mark_ready(client_id, st)
        if first and s.ready:
            self._arm_deadline(session_id)
        if s.all_ready:
            if self.clock is not None:
                # everyone reported, but the aggregation cascade (partials
                # climbing the tree, the root's global publish) may still be
                # in flight on slower links — close the round only once the
                # delivery queue settles, so the new round's reset doesn't
                # orphan the old round's partials
                rnd = s.round_idx
                self.clock.call_when_idle(
                    lambda: self._finish_settled_round(session_id, rnd))
            else:
                self._finish_round(session_id)

    def _finish_settled_round(self, session_id: str, round_idx: int) -> None:
        s = self.sessions.get(session_id)
        if s is not None and s.state == SessionState.RUNNING \
                and s.round_idx == round_idx and s.all_ready:
            self._finish_round(session_id)

    # ------------------------------------------------------------------
    # Cohort endpoints: fleet-scale control-plane batching.  One
    # CohortClient connection fronts N logical ids; joins, readiness, and
    # leaves arrive as one message per cohort instead of one per device.
    # ------------------------------------------------------------------
    @staticmethod
    def _brief(s: FLSession) -> dict:
        """describe() without the contributor list — a fleet session's id
        roster is O(N) and cohorts already know their own members."""
        return {"session_id": s.session_id, "model_name": s.model_name,
                "state": s.state.value, "round": s.round_idx,
                "fl_rounds": s.fl_rounds, "strategy": s.strategy,
                "async": s.async_cfg,
                "n_contributors": len(s.contributors)}

    def _cohort_session(self, session_id: str, cohort_id: str,
                        client_ids: list, model_name: str,
                        fl_rounds: int = 0, capacity_min: int = 0,
                        capacity_max: int = 0,
                        session_time_s: float = 3600.0,
                        waiting_time_s: float = 120.0,
                        preferred_role: str = "trainer",
                        strategy: str = "fedavg",
                        stats_list: Optional[list] = None) -> None:
        """Create-or-join with a batch of logical ids.  The first cohort to
        name a session creates it (capacity from its parameters); every
        cohort's members join in one RPC.  One ack lands on the cohort's
        control topic."""
        ids = [str(c) for c in client_ids]
        mem = self.cohort_members.setdefault(cohort_id, set())
        for cid in ids:
            self._cohort_of[cid] = cohort_id    # route notifies BEFORE acks
        mem.update(ids)
        s = self.sessions.get(session_id)
        if s is None:
            if not ids:
                return
            s = FLSession(session_id, model_name, ids[0], fl_rounds,
                          capacity_min or len(ids),
                          capacity_max or len(ids),
                          session_time_s, waiting_time_s, strategy=strategy,
                          round_deadline_s=self.cfg.round_deadline_s)
            self.sessions[session_id] = s
            if self.clock is not None:
                s.created_at = self.clock.now
                if 0 < waiting_time_s < float("inf"):
                    self.clock.schedule(
                        self.clock.now + waiting_time_s,
                        lambda: self.expire_waiting(session_id), timer=True)
        elif s.model_name != model_name:
            self._notify(cohort_id, {"event": "join_rejected",
                                     "session_id": session_id})
            return
        accepted, rejected = [], []
        for i, cid in enumerate(ids):
            st = (ClientStats.from_dict(stats_list[i])
                  if stats_list else ClientStats(cid))
            if s.join(cid, st, preferred_role):
                accepted.append(cid)
                self._note_alive(session_id, cid)
            else:
                rejected.append(cid)
        self._notify(cohort_id, {"event": "cohort_joined",
                                 "cohort_id": cohort_id,
                                 "accepted": accepted, "rejected": rejected,
                                 "session": self._brief(s)})
        if accepted and s.state == SessionState.RUNNING:
            self._arrange(session_id, rearrange=True)   # one elastic re-plan
        else:
            self._maybe_start(session_id)

    def _cohort_ready(self, session_id: str, cohort_id: str,
                      client_ids: list,
                      round_idx: Optional[int] = None,
                      stats_list: Optional[list] = None) -> None:
        """Batched ``client_ready``: the whole cohort reports in one
        message; the round barrier is checked once, after the batch."""
        s = self.sessions.get(session_id)
        if s is None or s.state != SessionState.RUNNING \
                or s.async_cfg is not None:
            return
        if round_idx is not None and round_idx != s.round_idx:
            return                           # stale readiness: discard
        first = not s.ready
        for i, cid in enumerate(client_ids):
            st = ClientStats.from_dict(stats_list[i]) if stats_list else None
            s.mark_ready(cid, st)
        if first and s.ready:
            self._arm_deadline(session_id)
        if s.all_ready:
            if self.clock is not None:
                rnd = s.round_idx
                self.clock.call_when_idle(
                    lambda: self._finish_settled_round(session_id, rnd))
            else:
                self._finish_round(session_id)

    def _cohort_leave(self, session_id: str, cohort_id: str,
                      client_ids: list) -> None:
        """Batched leave (member-level churn inside a cohort): one
        rearrangement for the whole batch."""
        s = self.sessions.get(session_id)
        if s is None:
            return
        mem = self.cohort_members.get(cohort_id)
        left = False
        for cid in client_ids:
            if cid in s.contributors:
                s.leave(cid)
                left = True
            if mem is not None:
                mem.discard(cid)
        if left and s.state == SessionState.RUNNING:
            self._arrange(session_id, rearrange=True)
            if s.contributors and s.all_ready:
                self._finish_round(session_id)

    # ------------------------------------------------------------------
    # Defense: heartbeat liveness + outlier reports -> reputation
    # ------------------------------------------------------------------
    def _note_alive(self, session_id: str, client_id: str) -> None:
        hb = self._heartbeats.get(session_id)
        if hb is not None:
            hb[client_id] = self.clock.now if self.clock is not None else 0.0

    def _heartbeat(self, session_id: str, client_id: str) -> None:
        """Per-client liveness beat on the shared clock (metadata only)."""
        self._note_alive(session_id, client_id)

    def _defense_report(self, session_id: str, client_id: str,
                        reason: str = "norm_outlier",
                        reporter: str = "") -> None:
        """An aggregator rejected ``client_id``'s update.  The coordinator
        only sees the *metadata* (who, why) — never the tensors — and turns
        it into a reputation penalty; crossing ``demote_below`` while the
        client holds aggregator duty triggers an immediate rearrangement
        (the moving-target demotion)."""
        book = self.books.get(session_id)
        s = self.sessions.get(session_id)
        if book is None or s is None or client_id not in s.contributors:
            return
        amount = (book.cfg.stale_penalty if reason == "stale"
                  else book.cfg.outlier_penalty)
        score = book.penalize(client_id, amount)
        if self.obs is not None:
            self.obs.trace("reputation_penalty", session=session_id,
                           client=client_id, reason=reason,
                           score=round(score, 4), reporter=reporter)
        if book.quarantined(client_id) and s.state == SessionState.RUNNING:
            asg = self.assignments.get(session_id, {}).get(client_id)
            if asg is not None and asg.duties:
                self._arrange(session_id, rearrange=True)  # demote now

    def _arm_liveness(self, session_id: str) -> None:
        """Periodic heartbeat sweep on the virtual clock: a contributor not
        heard from for ``liveness_misses`` beats takes a miss penalty per
        sweep.  Cancels itself when the session ends."""
        book = self.books.get(session_id)
        if book is None or self.clock is None:
            return
        cfg = book.cfg
        window = cfg.heartbeat_period_s * cfg.liveness_misses

        def sweep():
            s = self.sessions.get(session_id)
            if s is None or s.state == SessionState.TERMINATED:
                return False
            if s.state != SessionState.RUNNING:
                return True
            now = self.clock.now
            hb = self._heartbeats.setdefault(session_id, {})
            for cid in list(s.contributors):
                if now - hb.get(cid, 0.0) > window:
                    score = book.penalize(cid, cfg.miss_penalty)
                    if self.obs is not None:
                        self.obs.trace("heartbeat_miss", session=session_id,
                                       client=cid, score=round(score, 4))
            return True

        self.clock.schedule_periodic(window, sweep)

    def _on_will_raw(self, topic: str, payload) -> None:
        """Failure detector: LWT fired for a dead client."""
        args = payload["a"] if isinstance(payload, dict) else [payload]
        client_id = args[0] if args else topic.rsplit("/", 1)[-1]
        self.client_failed(client_id)

    def _on_global_raw(self, topic: str, payload) -> None:
        sid = topic.split("/")[2]
        if sid not in self._pending_cut:
            return
        body = payload["a"][0] if isinstance(payload, dict) and "a" in payload \
            else payload
        rnd = body.get("round") if isinstance(body, dict) else None
        if rnd == self._pending_cut[sid]:
            self._close_cut_round(sid, rnd)

    def _on_async_global(self, topic: str, payload) -> None:
        """Async-session bookkeeping: every minted global bumps the
        session's version counter; at ``fl_rounds`` versions the session
        terminates (the async analogue of the round budget)."""
        sid = topic.split("/")[2]
        s = self.sessions.get(sid)
        if s is None or s.async_cfg is None \
                or s.state != SessionState.RUNNING:
            return
        body = payload["a"][0] if isinstance(payload, dict) and "a" in payload \
            else payload
        ver = body.get("version", 0) if isinstance(body, dict) else 0
        if ver > s.round_idx:
            s.round_idx = ver
            s.history.append({"round": ver, "participants":
                              sorted(s.contributors)})
            if self.obs is not None:
                self.obs.trace("round_complete", session=sid, version=ver)
            if self.on_round_complete:
                self.on_round_complete(sid, ver)
        if 0 < s.fl_rounds <= ver:
            s.state = SessionState.TERMINATED
            self.fc.unbind(T.global_model(sid))
            if self.obs is not None:
                self.obs.trace("session_end", session=sid, rounds=ver)
            self._broadcast_status(sid, {"event": "session_terminated",
                                         "rounds": ver})

    # ------------------------------------------------------------------
    # Orchestration
    # ------------------------------------------------------------------
    def _maybe_start(self, session_id: str) -> None:
        s = self.sessions[session_id]
        if s.state == SessionState.WAITING and s.full:
            self.start_session(session_id)

    def expire_waiting(self, session_id: str) -> bool:
        """Waiting time elapsed (paper §III-E1): start at quorum even if not
        full.  Returns whether the session started."""
        s = self.sessions[session_id]
        if s.state == SessionState.WAITING and s.quorum:
            self.start_session(session_id)
            return True
        return False

    def start_session(self, session_id: str) -> None:
        """Quorum reached (or waiting time expired): cluster + arrange."""
        s = self.sessions[session_id]
        assert s.quorum, "cannot start below capacity_min"
        s.state = SessionState.CLUSTERING
        self._arrange(session_id, rearrange=False)
        s.state = SessionState.RUNNING
        if s.defense_cfg is not None:
            self._arm_liveness(session_id)
        if s.async_cfg is not None:
            # K-of-N mode: no round barrier.  The coordinator only watches
            # the global topic to track minted versions and terminate the
            # session once the version budget (fl_rounds) is spent.
            self.fc.subscribe_raw(T.global_model(session_id),
                                  self._on_async_global)
            return
        if self.obs is not None:
            self.obs.trace("round_start", session=session_id,
                           round=s.round_idx)
        self._broadcast_status(session_id, {"event": "round_start",
                                            "round": s.round_idx})
        self._arm_round(session_id)

    def _rank_aggregators(self, s: FLSession) -> list[str]:
        pol = get_policy(self.cfg.role_policy)
        ranked = pol(s.contributors, s.round_idx)
        # respect stated preferences: aggregator-volunteers first (paper:
        # clients notify preference; coordinator decides suitability) — but
        # a quarantined client cannot volunteer its way into head duty
        book = self.books.get(s.session_id)
        vols = [c for c in ranked
                if (s.preferred_roles.get(c, "").startswith("agg")
                    or s.preferred_roles.get(c) == "trainer_aggregator")
                and (book is None or not book.quarantined(c))]
        if not vols:
            return ranked
        vset = set(vols)                    # O(1) lookup at fleet scale
        return vols + [c for c in ranked if c not in vset]

    def _arrange(self, session_id: str, rearrange: bool) -> None:
        """(Re)build the cluster tree and send role assignments.  Initial
        arrangement informs everyone; rearrangement only the changed."""
        s = self.sessions[session_id]
        clients = sorted(s.contributors)
        if not clients:
            s.state = SessionState.TERMINATED
            return
        book = self.books.get(session_id)
        if book is not None:
            # live trust scores ride the stats the policies rank on
            for cid, st in s.contributors.items():
                st.reputation = book.score(cid)
        ranked = self._rank_aggregators(s)
        tree = build_tree(session_id, clients, ranked,
                          self.cfg.aggregator_ratio, self.cfg.levels)
        errs = validate_tree(tree, clients)
        assert not errs, errs
        new_assign = tree.assignments()
        old_assign = self.assignments.get(session_id, {})
        old_wire = self._assign_wire.get(session_id, {})
        new_wire = {cid: a.to_dict() for cid, a in new_assign.items()}
        self.trees[session_id] = tree
        self.assignments[session_id] = new_assign
        self._assign_wire[session_id] = new_wire
        if rearrange and old_assign:
            # moving-target bookkeeping: the aggregator set changing hands
            # IS a rotation (reputation demotions, policy rotation, churn)
            old_heads = {c for c, a in old_assign.items() if a.duties}
            new_heads = {c for c, a in new_assign.items() if a.duties}
            if old_heads != new_heads:
                self.roles_rotations += 1
                if self.obs is not None:
                    self.obs.trace(
                        "role_rotated", session=session_id,
                        round=s.round_idx,
                        promoted=sorted(new_heads - old_heads),
                        demoted=sorted(old_heads - new_heads))
        batches: dict[str, list] = {}       # cohort -> changed assignments
        for cid, wire in new_wire.items():
            if rearrange and old_wire.get(cid) == wire:
                continue  # unchanged: not a single message (paper's point)
            co = self._cohort_of.get(cid)
            if co is not None:
                batches.setdefault(co, []).append(wire)
                continue
            payload = {"event": "role_assignment", "assignment": wire,
                       "round": s.round_idx}
            self._notify(cid, payload)
            if rearrange:
                self.rearrangement_messages += 1
            else:
                self.arrangement_messages += 1
        for co, asgs in batches.items():
            # one batched assignment message per cohort endpoint — the
            # fronted ids share a connection, so per-device messages would
            # all ride the same link anyway
            self.fc.call(T.client_ctrl(co),
                         {"event": "role_assignment_batch",
                          "assignments": asgs, "round": s.round_idx})
            if rearrange:
                self.rearrangement_messages += 1
            else:
                self.arrangement_messages += 1
        # publish the topology on the session topic (paper Fig. 5a); the
        # session's aggregation strategy rides along (retained), so late
        # joiners and every aggregator agree on the reduction semantics
        status = {"event": "topology", "tree": tree.describe(),
                  "strategy": s.strategy, "round": s.round_idx}
        if s.async_cfg is not None:
            # admission rules + live cohort size for every async aggregator
            status["async"] = {**s.async_cfg,
                               "cohort": len(s.contributors)}
        if s.defense_cfg is not None:
            # screening rules + live reputation map for every aggregator
            # (retained: late joiners screen with the same scores)
            status["defense"] = {
                **s.defense_cfg,
                "reputation": book.snapshot() if book is not None else {}}
        self.fc.call(T.session_status(session_id), status, retain=True)
        for cid, st in s.contributors.items():
            if cid in new_assign and new_assign[cid].duties:
                st.rounds_as_aggregator += 1

    def _finish_round(self, session_id: str) -> None:
        s = self.sessions[session_id]
        if self._pending_cut.pop(session_id, None) is not None:
            self.fc.unbind(T.global_model(session_id))
        if self.obs is not None:
            virtual_s = (self.clock.now - s.round_started_at
                         if self.clock is not None else None)
            wall0 = self._round_wall.pop(session_id, None)
            wall_s = (time.perf_counter() - wall0
                      if wall0 is not None else None)
            self.obs.observe_round(session_id, virtual_s, wall_s)
            self.obs.trace("round_complete", session=session_id,
                           round=s.round_idx,
                           contributors=len(s.contributors))
        book = self.books.get(session_id)
        if book is not None:
            # clean completed round heals reputation slowly (penalties for
            # fresh misbehavior outweigh the drip, so healing never races
            # an active attacker back into head duty)
            for cid in s.ready:
                book.heal(cid)
        s.next_round()
        if self.on_round_complete:
            self.on_round_complete(session_id, s.round_idx)
        if s.state == SessionState.TERMINATED:
            if self.obs is not None:
                self.obs.trace("session_end", session=session_id,
                               rounds=s.round_idx)
            self._broadcast_status(session_id, {"event": "session_terminated",
                                                "rounds": s.round_idx})
            return
        # role optimization + rearrangement for the new round
        self._arrange(session_id, rearrange=True)
        if self.obs is not None:
            self.obs.trace("round_start", session=session_id,
                           round=s.round_idx)
        self._broadcast_status(session_id, {"event": "round_start",
                                            "round": s.round_idx})
        self._arm_round(session_id)

    def _arm_round(self, session_id: str) -> None:
        """New round began: stamp the shared clock.  The straggler deadline
        is *relative*: it arms when the round's first readiness report
        lands (``_arm_deadline``), so a round whose training simply hasn't
        started yet is never cut with zero contributions."""
        if self.clock is not None:
            self.sessions[session_id].round_started_at = self.clock.now
        if self.obs is not None:
            self._round_wall[session_id] = time.perf_counter()

    def _arm_deadline(self, session_id: str) -> None:
        """First readiness of the round observed: every other participant
        has ``round_deadline_s`` virtual seconds to report before the
        coordinator cuts the round (paper §II exhaustion avoidance /
        partial aggregation)."""
        s = self.sessions[session_id]
        if self.clock is None or s.round_deadline_s <= 0:
            return
        rnd = s.round_idx
        self.clock.schedule(
            self.clock.now + s.round_deadline_s,
            lambda: self._deadline_hit(session_id, rnd), timer=True)

    def _deadline_hit(self, session_id: str, round_idx: int) -> None:
        """Round deadline elapsed on the virtual clock with stragglers still
        missing: flush partial aggregates, then close the round once the
        flush cascade has fully drained."""
        s = self.sessions.get(session_id)
        if s is None or s.state != SessionState.RUNNING \
                or s.round_idx != round_idx or s.all_ready:
            return
        self.deadline_cuts += 1
        if self.obs is not None:
            self.obs.trace("deadline_cut", session=session_id,
                           round=round_idx)
        if session_id not in self._pending_cut:
            # observe this session's global publishes only while a cut is
            # pending — the cut round closes the moment its (partial)
            # global lands, and the coordinator doesn't pay for model
            # traffic the rest of the time
            self.fc.subscribe_raw(T.global_model(session_id),
                                  self._on_global_raw)
        self._pending_cut[session_id] = round_idx
        self.force_round_end(session_id)
        # primary close: the flushed (partial) global landing for this round
        # (_on_global_raw); fallback: the delivery queue going fully idle —
        # covers a cut where nothing reached the root at all
        self.clock.call_when_idle(
            lambda: self._close_cut_round(session_id, round_idx))

    def _close_cut_round(self, session_id: str, round_idx: int) -> None:
        s = self.sessions.get(session_id)
        if s is not None and s.state == SessionState.RUNNING \
                and s.round_idx == round_idx:
            self._finish_round(session_id)

    def force_round_end(self, session_id: str) -> None:
        """Straggler deadline hit: flush aggregators LEVEL BY LEVEL.  With
        no clock (or zero spacing) each publish fully drains the broker
        queue, so level-l partials reach level-l+1 heads before their own
        flush arrives; under a held clock with modeled latency, space the
        levels by ``flush_spacing_s`` virtual seconds instead."""
        tree = self.trees.get(session_id)
        n_levels = len(tree.levels) if tree else 1
        spacing = self.cfg.flush_spacing_s
        for lvl in range(n_levels):
            if self.clock is not None and spacing > 0:
                self.clock.schedule(
                    self.clock.now + lvl * spacing,
                    lambda l=lvl: self.fc.call(
                        T.session_status(session_id),
                        {"event": "flush", "level": l}))
            else:
                self.fc.call(T.session_status(session_id),
                             {"event": "flush", "level": lvl})

    def client_failed(self, client_id: str) -> None:
        members = self.cohort_members.pop(client_id, None)
        if members:
            # a cohort endpoint died: every logical id it fronted is gone
            self.failed_clients.update(members)
            for m in members:
                self._cohort_of.pop(m, None)
            for sid, s in self.sessions.items():
                hit = [m for m in members if m in s.contributors]
                if hit and s.state == SessionState.RUNNING:
                    for m in hit:
                        s.leave(m)
                    if s.contributors:
                        self._arrange(sid, rearrange=True)
                        if s.all_ready:
                            self._finish_round(sid)
                    else:
                        s.state = SessionState.TERMINATED
            return
        self.failed_clients.add(client_id)
        for sid, s in self.sessions.items():
            if client_id in s.contributors and s.state == SessionState.RUNNING:
                s.leave(client_id)
                self._arrange(sid, rearrange=True)
                if s.all_ready and s.contributors:
                    self._finish_round(sid)

    # ------------------------------------------------------------------
    def _notify(self, client_id: str, payload: dict) -> None:
        # control traffic for a cohort-fronted id lands on the cohort's
        # own control topic (the fronted ids have no connection of their own)
        self.fc.call(T.client_ctrl(self._cohort_of.get(client_id, client_id)),
                     payload)

    def _broadcast_status(self, session_id: str, payload: dict) -> None:
        self.fc.call(T.session_status(session_id), payload)

    def tree_of(self, session_id: str) -> ClusterTree:
        return self.trees[session_id]
