"""FL session state machine (paper §III-E1, Fig. 4).

Lifecycle: CREATED -> WAITING (for contributors) -> CLUSTERING -> RUNNING
(round loop) -> TERMINATED (round budget or wall-clock expiry).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

from repro.core.stats import ClientStats


class SessionState(str, enum.Enum):
    CREATED = "created"
    WAITING = "waiting"
    CLUSTERING = "clustering"
    RUNNING = "running"
    TERMINATED = "terminated"


@dataclass
class FLSession:
    session_id: str
    model_name: str
    creator: str
    fl_rounds: int
    capacity_min: int
    capacity_max: int
    session_time_s: float = 3600.0
    waiting_time_s: float = 120.0
    strategy: str = "fedavg"           # aggregation strategy (repro.api)
    state: SessionState = SessionState.CREATED
    round_idx: int = 0
    contributors: dict[str, ClientStats] = field(default_factory=dict)
    preferred_roles: dict[str, str] = field(default_factory=dict)
    ready: set = field(default_factory=set)
    created_at: float = 0.0            # SimClock stamp at creation
    round_started_at: float = 0.0      # SimClock stamp of the current round
    round_deadline_s: float = 0.0      # straggler deadline (0 = none)
    async_cfg: Optional[dict] = None   # async admission rules (None = sync)
    defense_cfg: Optional[dict] = None  # adversarial defense knobs (None = off)
    history: list[dict] = field(default_factory=list)

    def join(self, client_id: str, stats: ClientStats,
             preferred_role: str = "trainer") -> bool:
        if self.state not in (SessionState.CREATED, SessionState.WAITING,
                              SessionState.RUNNING):
            return False   # elastic join mid-session is allowed (RUNNING)
        if len(self.contributors) >= self.capacity_max:
            return False
        self.contributors[client_id] = stats
        self.preferred_roles[client_id] = preferred_role
        if self.state != SessionState.RUNNING:
            self.state = SessionState.WAITING
        return True

    def leave(self, client_id: str) -> None:
        self.contributors.pop(client_id, None)
        self.preferred_roles.pop(client_id, None)
        self.ready.discard(client_id)

    @property
    def full(self) -> bool:
        return len(self.contributors) >= self.capacity_max

    @property
    def quorum(self) -> bool:
        return len(self.contributors) >= self.capacity_min

    def mark_ready(self, client_id: str, stats: Optional[ClientStats] = None) -> None:
        if client_id in self.contributors:
            self.ready.add(client_id)
            if stats is not None:
                self.contributors[client_id] = stats

    @property
    def all_ready(self) -> bool:
        # mark_ready keeps ready ⊆ contributors, so a length check short-
        # circuits the O(n) set build on every non-final readiness ping
        if len(self.ready) < len(self.contributors):
            return False
        return self.ready >= set(self.contributors)

    def next_round(self) -> None:
        self.history.append({"round": self.round_idx,
                             "participants": sorted(self.ready)})
        self.round_idx += 1
        self.ready.clear()
        if self.round_idx >= self.fl_rounds:
            self.state = SessionState.TERMINATED

    def describe(self) -> dict:
        return {
            "session_id": self.session_id, "model_name": self.model_name,
            "state": self.state.value, "round": self.round_idx,
            "fl_rounds": self.fl_rounds, "strategy": self.strategy,
            "async": self.async_cfg,
            "contributors": sorted(self.contributors),
        }
