"""Aggregation data plane: pluggable aggregation over an FL-client mesh
axis, executed as compiled collectives inside the FL round step.

The aggregation *strategy* (repro.api.strategies) decides the math; the
*schedule* decides the collective shape.  "sum"-reduction strategies
(fedavg, fedprox) run any schedule:

  * ``tree``       — paper-faithful hierarchical aggregation: one grouped
                     psum per cluster level; non-participants contribute 0.
  * ``flat``       — centralized baseline: one global psum.
  * ``rs_ag``      — beyond-paper: reduce-scatter + all-gather on the
                     largest divisible dim (bandwidth-optimal form).
  * ``compressed`` — beyond-paper: int8 block-quantized all-gather (used on
                     the DCN/pod hop where bandwidth is scarcest) with
                     local weighted combine; introduces bounded error.

"stack"-reduction strategies (trimmed_mean, coordinate_median) are not
decomposable into partial sums, so every schedule lowers to one all-gather
over the client axis followed by a local (replicated) robust combine — the
exact collective analogue of the host path forwarding stacked contributions
up the MQTT tree.  The combine is churn-aware (``combine_masked``): mesh
rows carried with zero FedAvg weight (dead/vacant client slots) are sorted
behind a sentinel and the trim/median window is computed over the *live*
count, so a departed client's stale row cannot shift the robust statistics
— matching the host path's churn-exact behavior with static shapes.

All run under shard_map; the client axis is ``axis`` ("data" in replica
mode, "pod" in shared mode).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:      # jax < 0.6 experimental API (pinned range in pyproject)
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
except ImportError:  # pragma: no cover — modern jax: top-level shard_map
    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

from repro.api.strategies import AggregationStrategy, get_strategy
from repro.core.topology import AggSchedule
from repro.dist.compression import dequantize_int8, quantize_int8


def _weighted(p, w):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) * w.astype(jnp.float32), p)


def _tree_psum(contrib, w, axis, schedule: AggSchedule):
    """Hierarchical: grouped psum per level, masking non-heads above L0."""
    total_w = w
    for lvl, groups in enumerate(schedule.level_groups):
        groups_l = [list(g) for g in groups]
        if lvl > 0:
            mask_arr = jnp.asarray(schedule.head_masks[lvl - 1], jnp.float32)
            my = mask_arr[jax.lax.axis_index(axis)]
            contrib = jax.tree_util.tree_map(lambda x: x * my, contrib)
            total_w = total_w * my
        contrib = jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x, axis, axis_index_groups=groups_l), contrib)
        total_w = jax.lax.psum(total_w, axis, axis_index_groups=groups_l)
    return contrib, total_w


def _flat_psum(contrib, w, axis):
    return (jax.tree_util.tree_map(lambda x: jax.lax.psum(x, axis), contrib),
            jax.lax.psum(w, axis))


def _rs_ag(contrib, w, axis, axis_size):
    """reduce_scatter + all_gather on the largest divisible dimension;
    falls back to psum for small/indivisible leaves."""
    def one(x):
        dims = [d for d in range(x.ndim) if x.shape[d] % axis_size == 0
                and x.shape[d] >= axis_size]
        if not dims or x.size < 4 * axis_size:
            return jax.lax.psum(x, axis)
        d = max(dims, key=lambda i: x.shape[i])
        scat = jax.lax.psum_scatter(x, axis, scatter_dimension=d, tiled=True)
        return jax.lax.all_gather(scat, axis, axis=d, tiled=True)
    return (jax.tree_util.tree_map(one, contrib), jax.lax.psum(w, axis))


def _compressed(contrib, w, axis, axis_size):
    """int8-quantized all-gather + fused local combine (DCN hop
    compression).  Only the int8 payload + per-row scales cross the slow
    hop; the dequantize+sum runs as one fused kernel (``qagg`` — Pallas on
    TPU, bit-identical jnp oracle elsewhere) so the gathered (A, ...) f32
    upcast is never materialized in HBM.  Contributions arrive pre-weighted,
    hence weights of 1.0 into the kernel."""
    from repro.kernels.fedavg.ops import qagg

    def one(x):
        q, scale = quantize_int8(x)
        qs = jax.lax.all_gather(q, axis)            # (A, ...) int8
        ss = jax.lax.all_gather(scale, axis)        # (A, ...) f32 scales
        return qagg(qs, ss, jnp.ones((axis_size,), jnp.float32))
    return (jax.tree_util.tree_map(one, contrib), jax.lax.psum(w, axis))


def aggregate_params(params, weights, mesh: Mesh, axis: str,
                     schedule: AggSchedule, param_specs,
                     strategy: Union[str, AggregationStrategy] = "fedavg",
                     ref_params=None):
    """params: client-stacked pytree (leading dim = n_clients, sharded over
    ``axis``); weights: (n_clients,).  Returns the same structure with every
    client's slot holding the identical strategy-aggregated global.

    ``ref_params`` (same structure as ``params``) is the pre-round model for
    strategies with ``needs_ref`` (fedprox): each client's pre-round params
    equal the previous global, so the reference is shard-local — no extra
    collectives."""
    strat = get_strategy(strategy)
    if not strat.compiled:
        raise ValueError(
            f"strategy {strat.name!r} has no compiled collective form "
            "(host path / Federation facade only)")
    axis_size = mesh.shape[axis]
    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    spec_leaves = tuple(treedef.flatten_up_to(param_specs))
    n_p = len(p_leaves)
    ref_leaves = ()
    if strat.needs_ref and ref_params is not None:
        ref_leaves = tuple(jax.tree_util.tree_leaves(ref_params))
        assert len(ref_leaves) == n_p

    def body(w_local, *leaves):
        p_local = jax.tree_util.tree_unflatten(treedef, leaves[:n_p])
        w = w_local.reshape(())                      # this client's weight

        if strat.reduction == "stack":
            # robust combine needs every contribution: one all-gather, then
            # a replicated local combine (identical result on every shard).
            # The combine is churn-aware: rows carried with zero weight
            # (dead/vacant mesh slots) are masked out of the robust
            # statistics instead of feeding them stale parameters.
            # Defense premaps (norm clipping) apply shard-locally BEFORE the
            # gather — client i owns mesh index i, so the local slice is one
            # client's contribution, exactly like a leaf on the host path.
            if ref_leaves:
                ref_local = jax.tree_util.tree_unflatten(treedef, leaves[n_p:])
                p_local = strat.premap(p_local, ref_local, jnp)
            elif type(strat).premap is not AggregationStrategy.premap:
                p_local = strat.premap(p_local, None, jnp)
            stacked = jax.tree_util.tree_map(
                lambda x: jax.lax.all_gather(x, axis, axis=0, tiled=True),
                p_local)
            w_full = jax.lax.all_gather(w_local, axis, axis=0, tiled=True)
            combined = strat.combine_masked(stacked, w_full, jnp)
            out = jax.tree_util.tree_map(
                lambda m, p: m[None].astype(p.dtype), combined, p_local)
            return tuple(jax.tree_util.tree_leaves(out))

        if ref_leaves:
            ref_local = jax.tree_util.tree_unflatten(treedef, leaves[n_p:])
            base = strat.premap(p_local, ref_local, jnp)
        else:
            base = p_local
        contrib = _weighted(base, w)
        if schedule.kind == "tree":
            summed, tw = _tree_psum(contrib, w, axis, schedule)
        elif schedule.kind == "rs_ag":
            summed, tw = _rs_ag(contrib, w, axis, axis_size)
        elif schedule.kind == "compressed":
            summed, tw = _compressed(contrib, w, axis, axis_size)
        else:
            summed, tw = _flat_psum(contrib, w, axis)
        mean = jax.tree_util.tree_map(lambda x: x / tw, summed)
        out = jax.tree_util.tree_map(
            lambda m, p: m.astype(p.dtype), mean, p_local)
        return tuple(jax.tree_util.tree_leaves(out))

    out_leaves = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis),) + spec_leaves + (spec_leaves if ref_leaves else ()),
        out_specs=spec_leaves,
    )(weights, *(p_leaves + list(ref_leaves)))
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
