"""Aggregation data plane: weighted FedAvg over an FL-client mesh axis,
executed as compiled collectives inside the FL round step.

Schedules (all mathematically identical to flat weighted FedAvg —
property-tested against the oracle in tests/test_aggregation.py):

  * ``tree``       — paper-faithful hierarchical aggregation: one grouped
                     psum per cluster level; non-participants contribute 0.
  * ``flat``       — centralized baseline: one global psum.
  * ``rs_ag``      — beyond-paper: reduce-scatter + all-gather on the
                     largest divisible dim (bandwidth-optimal form).
  * ``compressed`` — beyond-paper: int8 block-quantized all-gather (used on
                     the DCN/pod hop where bandwidth is scarcest) with
                     local weighted combine; introduces bounded error.

All run under shard_map; the client axis is ``axis`` ("data" in replica
mode, "pod" in shared mode).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.topology import AggSchedule
from repro.dist.compression import dequantize_int8, quantize_int8


def _weighted(p, w):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) * w.astype(jnp.float32), p)


def _tree_psum(contrib, w, axis, schedule: AggSchedule):
    """Hierarchical: grouped psum per level, masking non-heads above L0."""
    total_w = w
    for lvl, groups in enumerate(schedule.level_groups):
        groups_l = [list(g) for g in groups]
        if lvl > 0:
            mask_arr = jnp.asarray(schedule.head_masks[lvl - 1], jnp.float32)
            my = mask_arr[jax.lax.axis_index(axis)]
            contrib = jax.tree_util.tree_map(lambda x: x * my, contrib)
            total_w = total_w * my
        contrib = jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x, axis, axis_index_groups=groups_l), contrib)
        total_w = jax.lax.psum(total_w, axis, axis_index_groups=groups_l)
    return contrib, total_w


def _flat_psum(contrib, w, axis):
    return (jax.tree_util.tree_map(lambda x: jax.lax.psum(x, axis), contrib),
            jax.lax.psum(w, axis))


def _rs_ag(contrib, w, axis, axis_size):
    """reduce_scatter + all_gather on the largest divisible dimension;
    falls back to psum for small/indivisible leaves."""
    def one(x):
        dims = [d for d in range(x.ndim) if x.shape[d] % axis_size == 0
                and x.shape[d] >= axis_size]
        if not dims or x.size < 4 * axis_size:
            return jax.lax.psum(x, axis)
        d = max(dims, key=lambda i: x.shape[i])
        scat = jax.lax.psum_scatter(x, axis, scatter_dimension=d, tiled=True)
        return jax.lax.all_gather(scat, axis, axis=d, tiled=True)
    return (jax.tree_util.tree_map(one, contrib), jax.lax.psum(w, axis))


def _compressed(contrib, w, axis, axis_size):
    """int8-quantized all-gather + local combine (DCN hop compression)."""
    def one(x):
        q, scale = quantize_int8(x)
        qs = jax.lax.all_gather(q, axis)            # (A, ...) int8
        ss = jax.lax.all_gather(scale, axis)        # (A, ...) f32 scales
        deq = dequantize_int8(qs, ss)
        return jnp.sum(deq, axis=0)
    return (jax.tree_util.tree_map(one, contrib), jax.lax.psum(w, axis))


def aggregate_params(params, weights, mesh: Mesh, axis: str,
                     schedule: AggSchedule, param_specs):
    """params: client-stacked pytree (leading dim = n_clients, sharded over
    ``axis``); weights: (n_clients,).  Returns the same structure with every
    client's slot holding the identical weighted global mean."""
    axis_size = mesh.shape[axis]

    def body(w_local, *p_leaves):
        p_local = jax.tree_util.tree_unflatten(treedef, p_leaves)
        w = w_local.reshape(())                      # this client's weight
        contrib = _weighted(p_local, w)
        if schedule.kind == "tree":
            summed, tw = _tree_psum(contrib, w, axis, schedule)
        elif schedule.kind == "rs_ag":
            summed, tw = _rs_ag(contrib, w, axis, axis_size)
        elif schedule.kind == "compressed":
            summed, tw = _compressed(contrib, w, axis, axis_size)
        else:
            summed, tw = _flat_psum(contrib, w, axis)
        mean = jax.tree_util.tree_map(lambda x: x / tw, summed)
        out = jax.tree_util.tree_map(
            lambda m, p: m.astype(p.dtype), mean, p_local)
        return tuple(jax.tree_util.tree_leaves(out))

    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    spec_leaves = treedef.flatten_up_to(param_specs)
    out_leaves = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(axis),) + tuple(spec_leaves),
        out_specs=tuple(spec_leaves),
        check_vma=False,
    )(weights, *p_leaves)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
