"""Topology compiler: ClusterTree (control plane) -> collective schedule
(data plane).

The coordinator's cluster tree is compiled into per-level
``axis_index_groups`` over the FL client mesh axis.  Level-0 groups are the
leaf clusters; at level l>0 only the previous level's heads contribute
(everyone else is masked to zero), so each psum level reproduces exactly
the paper's hierarchical aggregation — and the lowered HLO shows one
(grouped) all-reduce per level instead of one global all-reduce.

Because ``axis_index_groups`` must partition the axis, clients that do not
participate at a level are assigned to the group of their level-0 head and
contribute zeros.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.clustering import ClusterTree


@dataclass(frozen=True)
class AggSchedule:
    """Static description of one aggregation schedule (hashable: usable as
    a compiled-step cache key)."""
    kind: str                                   # tree | flat | rs_ag | compressed
    n_clients: int
    level_groups: tuple = ()                    # per level: tuple of tuples
    head_masks: tuple = ()                      # per level>0: tuple of 0/1

    def signature(self) -> str:
        return f"{self.kind}/{self.n_clients}/{hash((self.level_groups, self.head_masks)) & 0xffffffff:x}"


def _groups_partition(assign: dict[int, int], n: int) -> tuple:
    """Client-index -> group-id mapping into sorted tuple-of-tuples."""
    groups: dict[int, list[int]] = {}
    for idx in range(n):
        groups.setdefault(assign[idx], []).append(idx)
    return tuple(tuple(g) for _, g in sorted(groups.items()))


def compile_tree(tree: ClusterTree, kind: str = "tree",
                 axis_size: int = 0, index_of: dict | None = None) -> AggSchedule:
    """Map a cluster tree onto mesh-axis collective groups.

    ``index_of`` maps client id -> mesh-axis index (default: enumeration
    order); ``axis_size`` >= #clients pads the groups with dead/vacant rows
    (they ride in group 0 at every level — the FL round step gives them
    zero weight, so sums are unaffected, but axis_index_groups must
    partition the full axis)."""
    if index_of is None:
        index_of = {cid: i for i, cid in enumerate(tree.client_order)}
    order = index_of
    n = max(axis_size, len(tree.client_order),
            max(order.values(), default=-1) + 1)
    if kind != "tree":
        return AggSchedule(kind, n)

    level_groups = []
    head_masks = []
    # level 0: leaf clusters partition everyone; vacant rows ride in group 0
    leaf_of = {i: 0 for i in range(n)}
    for gi, c in enumerate(tree.levels[0]):
        for m in c.members:
            leaf_of[order[m]] = gi
    level_groups.append(_groups_partition(leaf_of, n))

    # parent chain: every client -> head of the cluster it feeds into
    # (a multi-level head keeps the highest-level parent; walks stop as soon
    # as the current node participates at the target level)
    parent: dict[int, int] = {}
    for lvl_clusters in tree.levels:
        for c in lvl_clusters:
            for m in c.members:
                if order[m] != order[c.head]:
                    parent[order[m]] = order[c.head]

    # higher levels: heads of the previous level carry partial sums;
    # everyone else rides along in its head's group with zero contribution
    for lvl in range(1, len(tree.levels)):
        head_to_gid = {}
        for gi, c in enumerate(tree.levels[lvl]):
            for m in c.members:
                head_to_gid[order[m]] = gi
        mask = tuple(1 if idx in head_to_gid else 0 for idx in range(n))

        def gid_for(idx: int) -> int:
            cur = idx
            for _ in range(n + 1):
                if cur in head_to_gid:
                    return head_to_gid[cur]
                nxt = parent.get(cur, cur)
                if nxt == cur:
                    return 0
                cur = nxt
            return 0

        assign = {idx: gid_for(idx) for idx in range(n)}
        level_groups.append(_groups_partition(assign, n))
        head_masks.append(mask)

    return AggSchedule("tree", n, tuple(level_groups), tuple(head_masks))


def flat_schedule(n_clients: int) -> AggSchedule:
    """Centralized baseline: one global all-reduce."""
    return AggSchedule("flat", n_clients)


def validate_schedule(s: AggSchedule) -> list[str]:
    errs = []
    for lvl, groups in enumerate(s.level_groups):
        flat = sorted(i for g in groups for i in g)
        if flat != list(range(s.n_clients)):
            errs.append(f"level {lvl} groups do not partition the axis")
    for mask in s.head_masks:
        if len(mask) != s.n_clients:
            errs.append("mask length mismatch")
    return errs
