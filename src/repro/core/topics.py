"""SDFLMQ topic grammar (paper §III-E: roles and functions bound to topics).

Layout:
    sdflmq/coord/<function>                 coordinator RFC endpoints
    sdflmq/client/<client_id>/ctrl          per-client private control channel
    sdflmq/session/<sid>/status             session status broadcasts
    sdflmq/session/<sid>/cluster/<cid>/agg  trainers publish weights to the
                                            cluster head subscribed here
    sdflmq/session/<sid>/global             parameter-server global model
                                            (retained so late joiners sync)
    sdflmq/session/<sid>/gossip/<cid>       async-mode head gossip: cluster
                                            heads exchange model views so
                                            partitioned sites keep converging
"""
from __future__ import annotations

ROOT = "sdflmq"


def coord(function: str) -> str:
    return f"{ROOT}/coord/{function}"


def client_ctrl(client_id: str) -> str:
    return f"{ROOT}/client/{client_id}/ctrl"


def session_status(sid: str) -> str:
    return f"{ROOT}/session/{sid}/status"


def cluster_agg(sid: str, cluster_id: str) -> str:
    return f"{ROOT}/session/{sid}/cluster/{cluster_id}/agg"


def global_model(sid: str) -> str:
    return f"{ROOT}/session/{sid}/global"


def gossip(sid: str, client_id: str) -> str:
    return f"{ROOT}/session/{sid}/gossip/{client_id}"


def gossip_all(sid: str) -> str:
    return f"{ROOT}/session/{sid}/gossip/+"


def will(client_id: str) -> str:
    return f"{ROOT}/will/{client_id}"
