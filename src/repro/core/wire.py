"""TensorBundle — the zero-copy model wire format (SDFLMQ data plane).

The legacy msgpack path copies every model ~5x per tree hop: ExtType
``tobytes()`` per array, whole-body compression, per-part chunk slicing,
``frombuffer().copy()`` on receive, and fresh float64 dicts in the
aggregator.  This module replaces that with a flatten-once layout:

  * ``TensorBundle.from_params`` flattens a params dict into ONE contiguous
    buffer + a compact schema (name/dtype/shape/offset per tensor).  Each
    source array is copied exactly once, into its slot.
  * ``TensorStack`` is n bundle-rows laid out back to back (one schema),
    the unit "stack"-reduction strategies gather up the tree.  Heads
    forward collected rows as a single memoryview slice — leaves are never
    re-serialized.
  * ``encode_body``/``decode_body`` carry arbitrary msgpack-able call
    payloads whose tensors live in a trailing data region; encode writes
    everything into one preallocated buffer, decode returns zero-copy
    ``np.frombuffer`` views over the received body.

Layout of an encoded body::

    [4B table len][msgpack tensor table][4B meta len][msgpack meta][data]

where the meta is the payload with each tensor replaced by an ExtType
placeholder indexing the table, and table entries hold (kind, dtype/schema,
shape/n, offset, nbytes) with offsets relative to the data region.
Dtype strings keep their byte order (e.g. ``<f4``/``>f4``), so a decoded
view is correct on any endianness.
"""
from __future__ import annotations

from typing import Any, Iterable, Optional

import msgpack
import numpy as np

# ExtType codes in the meta document
_EXT_ARRAY = 43
_EXT_BUNDLE = 44
_EXT_STACK = 45


def _dtype_str(dt: np.dtype) -> str:
    # '|' (not applicable) stays; native '=' is resolved to an explicit
    # byte order so the wire is unambiguous between hosts
    return dt.str


class TensorBundle:
    """A params dict flattened once into one contiguous buffer.

    ``schema`` is a tuple of ``(name, dtype_str, shape, offset, nbytes)``;
    ``buffer`` is any contiguous bytes-like (bytes/bytearray/memoryview).
    ``views()`` returns zero-copy ndarray views over the buffer.
    """

    __slots__ = ("schema", "buffer", "_views")

    def __init__(self, schema, buffer):
        self.schema = tuple(
            (n, d, tuple(s), o, b) for n, d, s, o, b in schema)
        self.buffer = buffer
        self._views: Optional[dict[str, np.ndarray]] = None

    # ---- construction ----------------------------------------------------
    @classmethod
    def from_params(cls, params: dict) -> "TensorBundle":
        """Flatten once: one allocation, one memcpy per tensor."""
        schema = []
        off = 0
        arrs = []
        for name in params:
            # asarray(order="C"), not ascontiguousarray: the latter
            # promotes 0-d arrays to 1-d and would corrupt the schema
            a = np.asarray(params[name], order="C")
            if a.dtype.hasobject:
                raise TypeError(f"cannot wire-encode object dtype: {name!r}")
            schema.append((name, _dtype_str(a.dtype), a.shape, off, a.nbytes))
            arrs.append(a)
            off += a.nbytes
        buf = bytearray(off)
        mv = memoryview(buf)
        for (name, _d, _s, o, nb), a in zip(schema, arrs):
            if nb:
                mv[o:o + nb] = memoryview(a).cast("B")
        return cls(schema, buf)

    # ---- access ----------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return sum(b for *_x, b in self.schema)

    def keys(self):
        return [n for n, *_x in self.schema]

    def views(self) -> dict[str, np.ndarray]:
        """Zero-copy ndarray views over the underlying buffer."""
        if self._views is None:
            mv = memoryview(self.buffer)
            out = {}
            for name, dstr, shape, off, nb in self.schema:
                dt = np.dtype(dstr)
                n = nb // dt.itemsize if dt.itemsize else 0
                out[name] = np.frombuffer(mv, dtype=dt, count=n,
                                          offset=off).reshape(shape)
            self._views = out
        return self._views

    def view(self, name: str) -> np.ndarray:
        return self.views()[name]

    def to_params(self) -> dict[str, np.ndarray]:
        return dict(self.views())

    def layout_matches(self, other: "TensorBundle") -> bool:
        return self.schema == other.schema


class TensorStack:
    """``n`` TensorBundle rows (one shared ``schema``) laid out back to
    back in one buffer — the forwarding unit for stack-reduction
    strategies.  ``stacked_views()`` exposes per-tensor ``(n, *shape)``
    strided views without copying a byte."""

    __slots__ = ("schema", "n", "buffer")

    def __init__(self, schema, n: int, buffer):
        self.schema = tuple((nm, d, tuple(s), o, b) for nm, d, s, o, b in schema)
        self.n = int(n)
        self.buffer = buffer

    @property
    def row_nbytes(self) -> int:
        return sum(b for *_x, b in self.schema)

    @property
    def nbytes(self) -> int:
        return self.n * self.row_nbytes

    def stacked_views(self) -> dict[str, np.ndarray]:
        """Per-tensor zero-copy views of shape ``(n, *shape)``: one strided
        view over the row-major buffer per key — no per-key np.stack."""
        stride = self.row_nbytes
        mv = memoryview(self.buffer).cast("B")
        out = {}
        for name, dstr, shape, off, nb in self.schema:
            dt = np.dtype(dstr)
            if self.n == 0 or nb == 0:
                out[name] = np.empty((self.n,) + shape, dtype=dt)
                continue
            # row stride = whole-row bytes; within a row, the tensor is
            # C-contiguous at its schema offset
            elem_strides = tuple(
                np.empty(shape, dtype=dt).strides) if shape else ()
            out[name] = np.ndarray(shape=(self.n,) + shape, dtype=dt,
                                   buffer=mv, offset=off,
                                   strides=(stride,) + elem_strides)
        return out


# ---------------------------------------------------------------------------
# Body codec
# ---------------------------------------------------------------------------

class FrameArena:
    """Grow-only reusable encode buffer.

    ``take(n)`` hands out a writable ``memoryview`` over a per-instance
    bytearray, growing it only when ``n`` exceeds the current capacity —
    so steady-state encodes (the common FL case: same model, every round)
    stop allocating entirely.  The arena is single-checkout: while a view
    is outstanding (``release()`` not yet called), a nested ``take``
    falls back to a fresh allocation instead of corrupting the in-flight
    frame (re-entrant encodes happen when a broker delivers synchronously
    and the handler publishes through the same endpoint).  Pass that view
    back to ``release(view)`` to make the release ownership-checked: a
    re-entrant caller releasing its fallback buffer is then a no-op, so
    the outer checkout stays protected.
    """

    __slots__ = ("_buf", "_in_use", "reuse_hits", "grows", "busy_allocs")

    def __init__(self, initial: int = 0) -> None:
        self._buf = bytearray(initial)
        self._in_use = False
        self.reuse_hits = 0      # takes served from the existing buffer
        self.grows = 0           # takes that had to reallocate larger
        self.busy_allocs = 0     # re-entrant takes served off-arena

    def __len__(self) -> int:
        return len(self._buf)

    def take(self, n: int):
        if self._in_use:
            self.busy_allocs += 1
            return memoryview(bytearray(n))
        if len(self._buf) < n:
            self._buf = bytearray(n)
            self.grows += 1
        else:
            self.reuse_hits += 1
        self._in_use = True
        return memoryview(self._buf)[:n]

    def release(self, view=None) -> None:
        if view is None or getattr(view, "obj", None) is self._buf:
            self._in_use = False


def encode_body(obj: Any, arena: "FrameArena | None" = None) -> bytearray:
    """Encode a call payload into ONE preallocated buffer.  Tensors
    (ndarray / TensorBundle / TensorStack) are copied exactly once, into
    the trailing data region; everything else is msgpack.

    With ``arena`` the buffer is checked out of a reusable
    :class:`FrameArena` (returned as a writable memoryview; the caller
    must ``arena.release()`` once the frame bytes have been copied out)
    instead of freshly allocated.  Every byte of the returned buffer is
    written either way, so arena reuse cannot leak stale data."""
    table: list = []
    segments: list = []          # contiguous bytes-like per table entry
    data_len = 0

    def _hook(o):
        nonlocal data_len
        if isinstance(o, TensorBundle):
            idx = len(table)
            table.append(("b", list(o.schema), data_len, o.nbytes))
            segments.append(memoryview(o.buffer).cast("B"))
            data_len += o.nbytes
            return msgpack.ExtType(_EXT_BUNDLE, msgpack.packb(idx))
        if isinstance(o, TensorStack):
            idx = len(table)
            table.append(("s", list(o.schema), o.n, data_len, o.nbytes))
            segments.append(memoryview(o.buffer).cast("B"))
            data_len += o.nbytes
            return msgpack.ExtType(_EXT_STACK, msgpack.packb(idx))
        if isinstance(o, np.ndarray):
            a = np.asarray(o, order="C")
            if a.dtype.hasobject:
                raise TypeError("cannot wire-encode object dtype array")
            idx = len(table)
            table.append(("a", _dtype_str(a.dtype), list(a.shape),
                          data_len, a.nbytes))
            segments.append(memoryview(a).cast("B") if a.nbytes else b"")
            data_len += a.nbytes
            return msgpack.ExtType(_EXT_ARRAY, msgpack.packb(idx))
        if isinstance(o, np.integer):
            return int(o)
        if isinstance(o, (np.floating, np.float16)):
            return float(o)
        if isinstance(o, np.bool_):
            return bool(o)
        raise TypeError(f"cannot serialize {type(o)}")

    meta = msgpack.packb(obj, default=_hook, use_bin_type=True)
    tbl = msgpack.packb(table, use_bin_type=True)
    head_len = 4 + len(tbl) + 4 + len(meta)
    total = head_len + data_len
    out = arena.take(total) if arena is not None else bytearray(total)
    out[0:4] = len(tbl).to_bytes(4, "big")
    out[4:4 + len(tbl)] = tbl
    mo = 4 + len(tbl)
    out[mo:mo + 4] = len(meta).to_bytes(4, "big")
    out[mo + 4:head_len] = meta
    mv = memoryview(out)
    off = head_len
    for seg in segments:
        n = len(seg)
        if n:
            mv[off:off + n] = seg
        off += n
    return out


def decode_body(body) -> Any:
    """Decode an ``encode_body`` buffer; tensor leaves come back as
    zero-copy views (ndarray) / view-holding TensorBundle / TensorStack
    over ``body`` — nothing in the data region is copied."""
    mv = memoryview(body)
    tlen = int.from_bytes(mv[0:4], "big")
    table = msgpack.unpackb(mv[4:4 + tlen], raw=False)
    mo = 4 + tlen
    mlen = int.from_bytes(mv[mo:mo + 4], "big")
    meta = mv[mo + 4:mo + 4 + mlen]
    # read-only data region: an uncompressed single-part frame is SHARED
    # by every subscriber (and the retained-message store) — a writable
    # view would let one receiver silently corrupt the others
    data = mv[mo + 4 + mlen:].toreadonly()

    def _resolve(code, payload):
        idx = msgpack.unpackb(payload)
        ent = table[idx]
        if code == _EXT_ARRAY:
            _k, dstr, shape, off, nb = ent
            dt = np.dtype(dstr)
            n = nb // dt.itemsize if dt.itemsize else 0
            return np.frombuffer(data, dtype=dt, count=n,
                                 offset=off).reshape(shape)
        if code == _EXT_BUNDLE:
            _k, schema, off, nb = ent
            return TensorBundle(schema, data[off:off + nb])
        if code == _EXT_STACK:
            _k, schema, n, off, nb = ent
            return TensorStack(schema, n, data[off:off + nb])
        return msgpack.ExtType(code, payload)

    return msgpack.unpackb(meta, ext_hook=_resolve, raw=False,
                           strict_map_key=False)


def is_wire_payload(obj: Any) -> bool:
    """Does ``obj`` contain tensors that want the TensorBundle format?"""
    if isinstance(obj, (TensorBundle, TensorStack, np.ndarray)):
        return True
    if isinstance(obj, dict):
        return any(is_wire_payload(v) for v in obj.values())
    if isinstance(obj, (list, tuple)):
        return any(is_wire_payload(v) for v in obj)
    return False
