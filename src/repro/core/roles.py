"""FL roles and the client-side Role Arbiter (paper §III-C).

A client may hold several duties at once (paper Fig. 5b: A/T5 heads a leaf
cluster AND the root): it trains into exactly one leaf cluster and may
aggregate any number of clusters at different levels.  The arbiter owns the
mapping between duties and MQTT subscriptions: a role change is exactly the
subscription delta — nobody else is touched (the paper's key property).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class Role(str, enum.Enum):
    TRAINER = "trainer"
    AGGREGATOR = "aggregator"
    TRAINER_AGGREGATOR = "trainer_aggregator"


@dataclass
class Duty:
    """One aggregation duty: collect ``expected`` inputs for ``cluster_id``
    and forward the weighted partial sum to ``parent`` (None = root)."""
    cluster_id: str
    expected: int
    parent: Optional[str]
    level: int

    def to_dict(self) -> dict:
        return {"cluster_id": self.cluster_id, "expected": self.expected,
                "parent": self.parent, "level": self.level}

    @staticmethod
    def from_dict(d: dict) -> "Duty":
        return Duty(d["cluster_id"], d["expected"], d["parent"], d["level"])


@dataclass
class ClientAssignment:
    client_id: str
    train_cluster: Optional[str]           # leaf cluster to publish into
    duties: list[Duty] = field(default_factory=list)

    @property
    def role(self) -> Role:
        if self.duties and self.train_cluster:
            return Role.TRAINER_AGGREGATOR
        if self.duties:
            return Role.AGGREGATOR
        return Role.TRAINER

    def to_dict(self) -> dict:
        return {"client_id": self.client_id, "train_cluster": self.train_cluster,
                "duties": [d.to_dict() for d in self.duties]}

    @staticmethod
    def from_dict(d: dict) -> "ClientAssignment":
        return ClientAssignment(d["client_id"], d["train_cluster"],
                                [Duty.from_dict(x) for x in d["duties"]])


@dataclass
class RoleArbiter:
    client_id: str
    assignment: Optional[ClientAssignment] = None
    subscribed_topics: list[str] = field(default_factory=list)
    role_changes: int = 0

    @property
    def is_aggregator(self) -> bool:
        return self.assignment is not None and bool(self.assignment.duties)

    @property
    def is_trainer(self) -> bool:
        return self.assignment is None or self.assignment.train_cluster is not None

    def duty_for(self, cluster_id: str) -> Optional[Duty]:
        if self.assignment is None:
            return None
        for d in self.assignment.duties:
            if d.cluster_id == cluster_id:
                return d
        return None

    def update(self, new: ClientAssignment) -> tuple[list[str], list[str]]:
        """Returns (topics_to_unsubscribe, topics_to_subscribe): only the
        delta against the current subscriptions (paper §III-E5, Fig. 6)."""
        from repro.core import topics as T
        sid = (new.duties[0].cluster_id if new.duties
               else new.train_cluster or "").split(":")[0]
        old_topics = set(self.subscribed_topics)
        new_topics = {T.cluster_agg(sid, d.cluster_id) for d in new.duties}
        to_unsub = sorted(old_topics - new_topics)
        to_sub = sorted(new_topics - old_topics)
        if self.assignment is None or self.assignment.to_dict() != new.to_dict():
            self.role_changes += 1
        self.assignment = new
        self.subscribed_topics = sorted(new_topics)
        return to_unsub, to_sub
