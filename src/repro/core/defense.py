"""Self-defending control plane: defense configuration + reputation book.

The SDFL pitch — *any* edge node can take aggregation duty — cuts both
ways: any compromised node can poison a cluster's partial or squat on a
head role.  This module holds the control-plane side of the defense:

* :class:`DefenseConfig` — the knobs, serialized onto the wire exactly
  like the async config (``create_session`` carries it; the retained
  topology broadcast re-distributes it plus the live reputation map, so
  every aggregator — including late joiners — screens with the same
  rules).
* :class:`ReputationBook` — per-client trust scores in ``[0, 1]`` kept by
  the coordinator.  Update-norm outliers, heartbeat misses, and staleness
  *penalize*; clean completed rounds *heal*.  Scores feed three places:
  aggregators scale a sender's combine weight by its reputation (and
  reject below ``reject_below``), the volunteer boost in aggregator
  ranking excludes clients below ``demote_below``, and the
  ``reputation_aware`` role policy rotates head duty across the trusted
  set (fedstellar-style moving-target defense) so a poisoned head cannot
  own a cluster indefinitely.

The coordinator never touches model tensors — norm screening happens at
the aggregators (core/client.py), which report outliers back over
``sdflmq/coord/defense_report`` metadata only, keeping the paper's
coordinator-sees-no-models property intact.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass


@dataclass
class DefenseConfig:
    """Knobs for the self-defending control plane (all virtual-time)."""
    # -- heartbeat liveness -------------------------------------------------
    heartbeat_period_s: float = 1.0     # per-client heartbeat cadence
    liveness_misses: int = 3            # missed beats before a penalty
    # -- update-norm outlier gate (at aggregators) --------------------------
    norm_gate_mult: float = 4.0         # reject when norm/weight > mult*EWMA
    norm_warmup: int = 3                # observations before the gate arms
    norm_alpha: float = 0.3             # EWMA step for the norm baseline
    # -- reputation dynamics ------------------------------------------------
    outlier_penalty: float = 0.3        # norm-gate rejection
    miss_penalty: float = 0.2           # heartbeat-liveness miss
    stale_penalty: float = 0.05         # repeated stale contributions
    heal_rate: float = 0.05             # per clean completed round
    reject_below: float = 0.2           # drop the sender's updates entirely
    demote_below: float = 0.5           # no aggregator duty below this

    def to_wire(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_wire(d: "DefenseConfig | dict | bool | None"):
        if d is None or d is False:
            return None
        if isinstance(d, DefenseConfig):
            return d
        if d is True:
            return DefenseConfig()
        known = {f for f in DefenseConfig.__dataclass_fields__}
        return DefenseConfig(**{k: v for k, v in dict(d).items()
                                if k in known})


class ReputationBook:
    """Per-client trust scores in ``[0, 1]``; every client starts at 1.0."""

    def __init__(self, cfg: DefenseConfig):
        self.cfg = cfg
        self.scores: dict[str, float] = {}
        self.penalties = 0
        self.heals = 0

    def score(self, client_id: str) -> float:
        return self.scores.get(client_id, 1.0)

    def penalize(self, client_id: str, amount: float) -> float:
        s = max(0.0, self.score(client_id) - amount)
        self.scores[client_id] = s
        self.penalties += 1
        return s

    def heal(self, client_id: str) -> float:
        s = min(1.0, self.score(client_id) + self.cfg.heal_rate)
        self.scores[client_id] = s
        self.heals += 1
        return s

    def quarantined(self, client_id: str) -> bool:
        return self.score(client_id) < self.cfg.demote_below

    def snapshot(self) -> dict[str, float]:
        """Wire-ready map (only clients that ever diverged from 1.0)."""
        return {c: round(s, 6) for c, s in self.scores.items()}
