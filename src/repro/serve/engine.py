"""Batched serving engine: continuous-batching style loop over a fixed
batch of slots (prefill on admit, decode every step, evict on EOS/length).
Used by examples/serve_lm.py and the serving smoke tests; the decode/prefill
functions are the exact ones the dry-run lowers for the inference cells.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model_api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-batch decode engine with prompt prefill.

    For simplicity every admitted batch prefills together (left-padded to
    the longest prompt); decode then proceeds one token per step for all
    live slots.  greedy sampling."""

    def __init__(self, cfg: ArchConfig, params, batch_size: int = 4,
                 max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.model = model_api.get_model(cfg)
        self._decode = jax.jit(
            lambda p, c, b: self.model.decode_step(cfg, p, c, b))
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(cfg, p, b))
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "requests": 0, "decode_s": 0.0, "prefill_s": 0.0}

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        r = Request(self.stats["requests"], np.asarray(prompt, np.int32),
                    max_new)
        self.stats["requests"] += 1
        self.queue.append(r)
        return r

    def _extra_inputs(self, B, S):
        fe = self.cfg.frontend
        out = {}
        if self.cfg.family == "encdec":
            out["frames"] = jnp.zeros((B, fe.n_tokens, fe.feat_dim),
                                      jnp.bfloat16)
        elif self.cfg.family == "vlm":
            out["patches"] = jnp.zeros((B, min(fe.n_tokens, S), fe.feat_dim),
                                       jnp.bfloat16)
        return out

    def run(self) -> list[Request]:
        """Drain the queue; returns completed requests."""
        done = []
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.B, len(self.queue)))]
            done.extend(self._run_batch(batch))
        return done

    def _run_batch(self, reqs: list[Request]) -> list[Request]:
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        batch.update(self._extra_inputs(B, S))
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        if self.cfg.window is None and self.cfg.family != "rwkv":
            from repro.models.kvcache import pad_cache
            max_new = max(r.max_new for r in reqs)
            cache = pad_cache(cache, min(S + max_new + 1, self.max_seq))
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += B * S
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        max_new = max(r.max_new for r in reqs)
        t0 = time.perf_counter()
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if len(r.out) < r.max_new:
                    r.out.append(int(cur[i]))
            pos = jnp.full((B,), S + step, jnp.int32)
            dbatch = {"token": cur[:, None], "pos": pos}
            logits, cache = self._decode(self.params, cache, dbatch)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            self.stats["decode_steps"] += 1
        self.stats["decode_s"] += time.perf_counter() - t0
        for r in reqs:
            r.done = True
        return reqs
