"""Fault tolerance: failure injection + detection, straggler mitigation,
elastic membership.  The detection path IS the paper's mechanism: a dead
client's MQTT last-will fires -> coordinator drops it and rearranges roles
(only affected clients receive messages); the data plane recompiles (and
caches) the aggregation schedule for the surviving membership.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class FailurePlan:
    """Deterministic failure/straggle schedule for tests and benchmarks."""
    fail_at: dict[int, list[str]] = field(default_factory=dict)     # round -> clients
    straggle_at: dict[int, dict[str, float]] = field(default_factory=dict)
    join_at: dict[int, list[str]] = field(default_factory=dict)

    @staticmethod
    def random(client_ids: list[str], rounds: int, p_fail: float = 0.02,
               p_straggle: float = 0.1, seed: int = 0) -> "FailurePlan":
        rng = np.random.default_rng(seed)
        plan = FailurePlan()
        alive = list(client_ids)
        for r in range(rounds):
            dead = [c for c in alive if rng.random() < p_fail]
            if dead and len(alive) - len(dead) >= 2:
                plan.fail_at[r] = dead
                alive = [c for c in alive if c not in dead]
            slow = {c: float(rng.uniform(2, 10)) for c in alive
                    if rng.random() < p_straggle}
            if slow:
                plan.straggle_at[r] = slow
        return plan


class StragglerPolicy:
    """Deadline-based partial aggregation: after ``deadline_s`` (or a
    quantile of observed latencies), the coordinator flushes aggregators;
    FedAvg weights renormalize over the responsive subset — the update
    stays an unbiased weighted mean of received contributions.

    Attach a shared ``repro.api.transport.SimClock`` to read waits from
    virtual time instead of counting them: ``round_started()`` stamps the
    round's start and ``should_cut(got=…, expected=…)`` (no explicit
    ``waited_s``) measures the wait on the clock."""

    def __init__(self, deadline_s: float = 0.0, quantile: float = 0.9,
                 min_fraction: float = 0.5, clock=None):
        self.deadline_s = deadline_s
        self.quantile = quantile
        self.min_fraction = min_fraction
        self.clock = clock                  # SimClock-like: .now
        self.round_started_at = 0.0
        self.history: list[float] = []

    def attach_clock(self, clock) -> "StragglerPolicy":
        self.clock = clock
        return self

    def round_started(self, now: float | None = None) -> None:
        self.round_started_at = (now if now is not None
                                 else self.clock.now if self.clock else 0.0)

    def waited(self) -> float:
        if self.clock is None:
            return 0.0
        return self.clock.now - self.round_started_at

    def observe(self, latency_s: float) -> None:
        self.history.append(latency_s)
        self.history = self.history[-256:]

    def deadline(self) -> float:
        if self.deadline_s > 0:
            return self.deadline_s
        if not self.history:
            return float("inf")
        return 1.5 * float(np.quantile(self.history, self.quantile))

    def should_cut(self, waited_s: float | None = None, got: int = 0,
                   expected: int = 0) -> bool:
        if waited_s is None:
            waited_s = self.waited()        # read the shared virtual clock
        if got >= expected:
            return True
        if got < self.min_fraction * expected:
            return False
        return waited_s >= self.deadline()


def demote_stragglers(latencies: dict[str, float], ranked: list[str],
                      factor: float = 2.0) -> list[str]:
    """Aggregator candidates persistently slower than the median get pushed
    to the back of the ranking (exhaustion avoidance, paper §II)."""
    if not latencies:
        return ranked
    med = float(np.median(list(latencies.values())))
    slow = {c for c, l in latencies.items() if l > factor * med}
    return [c for c in ranked if c not in slow] + \
           [c for c in ranked if c in slow]
