"""Whisper-small — enc-dec, 12L encoder + 12L decoder, d=768, 12H MHA,
d_ff=3072, vocab 51865.  Conv audio frontend is a STUB: input_specs feeds
precomputed frame embeddings (1500 x 768).  [arXiv:2212.04356]
"""
from repro.configs.base import ArchConfig, FLConfig, FrontendConfig, register

CONFIG = register(ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    frontend=FrontendConfig(kind="audio", n_tokens=1500, feat_dim=768),
    fl=FLConfig(mode="replica", schedule="tree"),
    notes="enc-dec, conv frontend stub [arXiv:2212.04356; unverified]",
))
