"""Hymba-1.5B — hybrid parallel attention + SSM heads: 32L, d=1600,
25H GQA kv=5, d_ff=5504, ssm_state=16, sliding window.
SSM branch uses SSD form (scalar per-head decay) — TPU adaptation noted in
DESIGN.md.  [arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig, FLConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    window=1024,
    ssm_state=16,
    fl=FLConfig(mode="replica", schedule="tree"),
    notes="parallel attn+mamba heads [arXiv:2411.13676; hf]",
))
