"""InternVL2-2B — InternViT + InternLM2 backbone: 24L, d=2048, 16H GQA kv=8,
d_ff=8192, vocab 92553.  The ViT frontend is a STUB: input_specs feeds 256
precomputed patch embeddings that fill the leading sequence positions.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig, FLConfig, FrontendConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    frontend=FrontendConfig(kind="vision", n_tokens=256, feat_dim=2048),
    fl=FLConfig(mode="replica", schedule="tree"),
    notes="InternViT + InternLM2 [arXiv:2404.16821; hf]",
))
