"""InternLM2-20B — dense, 48L, d=6144, 48H GQA kv=8, d_ff=16384,
vocab 92544.  [arXiv:2403.17297; hf]"""
from repro.configs.base import ArchConfig, FLConfig, register

CONFIG = register(ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    optimizer="adafactor",   # replica-mode Adam moments for 20B x 16 clients
                             # would exceed v5e HBM; see EXPERIMENTS.md
    fl=FLConfig(mode="replica", schedule="tree"),
    notes="GQA [arXiv:2403.17297; hf]",
))
