"""Kimi K2 — trillion-param MoE (61L, d=7168, 64H GQA kv=8, 384 experts
top-8, 1 shared expert, first layer dense).  [arXiv:2501.kimi2]

Deployment notes: FL mode is ``shared`` (one client per pod; 1T params are
FSDP-sharded over data x model within the pod).  Adafactor — Adam moments
for 1T params cannot fit a 256-chip v5e pod (documented in EXPERIMENTS.md).
Experts shard over ``model`` (384/16 = 24 per chip: expert parallelism).
"""
from repro.configs.base import ArchConfig, FLConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    moe=MoEConfig(
        n_experts=384,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        first_k_dense=1,
        d_ff_dense=18432,   # (top_k + shared) x 2048 — matches K2's dense ff
        capacity_factor=1.25,
    ),
    optimizer="adafactor",
    fl=FLConfig(mode="shared", schedule="tree", compress_pod_axis=True),
    notes="paper-table config [arXiv:2501.kimi2; unverified]",
))
