"""H2O-Danube-3-4B — dense llama+mistral mix, 24L, d=3840, 32H GQA kv=8,
d_ff=10240, vocab 32000, sliding-window attention.  [arXiv:2401.16818]"""
from repro.configs.base import ArchConfig, FLConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    window=4096,
    fl=FLConfig(mode="replica", schedule="tree"),
    notes="llama+mistral mix, SWA [arXiv:2401.16818; unverified]",
))
