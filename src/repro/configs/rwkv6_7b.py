"""RWKV6-7B ("Finch") — attention-free, 32L, d=4096, d_ff=14336,
vocab 65536, data-dependent decay.  64 heads of dim 64.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig, FLConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    rwkv_head_dim=64,
    rwkv_chunk=128,
    fl=FLConfig(mode="replica", schedule="tree"),
    notes="Finch — data-dependent decay [arXiv:2404.05892; hf]",
))
