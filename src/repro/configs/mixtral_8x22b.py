"""Mixtral 8x22B — 56L, d=6144, 48H GQA kv=8, d_ff=16384, 8 experts top-2,
sliding-window attention.  [arXiv:2401.04088; hf]

8 experts do not divide the 16-way model axis, so the logical-axis resolver
falls through to intra-expert TP (d_ff sharded over ``model``).  SWA makes
long_500k decode well-defined (window-bounded KV).
"""
from repro.configs.base import ArchConfig, FLConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    window=4096,
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        d_ff_expert=16384,
        capacity_factor=1.25,
    ),
    optimizer="adafactor",
    fl=FLConfig(mode="shared", schedule="tree", compress_pod_axis=True),
    notes="8 experts top-2, SWA [arXiv:2401.04088; hf]",
))
