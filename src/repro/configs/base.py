"""Config system: architecture + shape + FL deployment configuration.

Every assigned architecture is a frozen ``ArchConfig`` registered under its
public id (``--arch <id>``).  Shapes are the four assigned input-shape
presets.  ``FLConfig`` carries the SDFLMQ deployment knobs (client mapping,
cluster topology policy, aggregation schedule).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

# --------------------------------------------------------------------------
# Architecture configuration
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    first_k_dense: int = 0           # leading dense (non-MoE) layers
    d_ff_dense: int = 0              # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_coef: float = 0.01           # load-balancing auxiliary loss weight
    impl: str = "auto"               # "auto" (pjit einsum) | "ep_a2a" (shard_map)


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub: input_specs() provides precomputed embeddings."""
    kind: str                        # "audio" | "vision"
    n_tokens: int                    # frames / patches
    feat_dim: int                    # embedding dim delivered by the stub


@dataclass(frozen=True)
class FLConfig:
    """SDFLMQ deployment configuration (paper §III)."""
    mode: str = "replica"            # "replica": client per data-row;
                                     # "shared": FSDP params, client per pod
    local_steps: int = 1             # local epochs per FL round (E)
    aggregator_ratio: float = 0.3    # paper Fig.8: 30% of clients aggregate
    levels: int = 3                  # hierarchy depth incl. root (paper: 3)
    schedule: str = "tree"           # "tree" (paper) | "flat" (centralized
                                     # baseline) | "rs_ag" (beyond-paper)
    compress_pod_axis: bool = False  # int8 compression on DCN hop
    role_policy: str = "memory_aware"  # load-balancer policy name


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | encdec | rwkv | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    window: Optional[int] = None     # sliding-window attention size
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm_state: int = 0               # hybrid (hymba) SSM state size
    ssm_conv: int = 3                # depthwise conv width for SSM branch
    n_enc_layers: int = 0            # encdec: encoder depth
    frontend: Optional[FrontendConfig] = None
    attn_chunk: int = 1024           # kv-chunk for memory-efficient attention
    attn_chunk_threshold: int = 1024 # use chunked attention for seq > this
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 128
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"         # adamw | adafactor | sgdm
    remat: bool = True
    fl: FLConfig = field(default_factory=FLConfig)
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with O(1)-in-seq state (window / SSM / linear)?"""
        return (self.window is not None) or self.family in ("rwkv", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Input shapes (assigned)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether the (arch, shape) cell is well-defined (assignment rules)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % arch.name
    return True, ""


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    # import side-effect registers every assigned architecture
    from repro.configs import (  # noqa: F401
        kimi_k2_1t_a32b, mixtral_8x22b, whisper_small, internlm2_20b,
        qwen1_5_4b, h2o_danube_3_4b, qwen2_7b, rwkv6_7b, internvl2_2b,
        hymba_1_5b,
    )
    _LOADED = True


def smoke_config(arch: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw: dict[str, Any] = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=min(arch.n_kv_heads, 2) or 2,
        d_ff=128, vocab=256, head_dim=16, attn_chunk=32, attn_chunk_threshold=64,
        remat=False, rwkv_chunk=8,
    )
    if arch.family == "rwkv":
        kw.update(rwkv_head_dim=16, n_heads=4, n_kv_heads=4)
    if arch.moe is not None:
        kw["moe"] = dataclasses.replace(
            arch.moe, n_experts=4, top_k=2, d_ff_expert=32,
            n_shared_experts=min(arch.moe.n_shared_experts, 1),
            first_k_dense=min(arch.moe.first_k_dense, 1), d_ff_dense=64)
    if arch.n_enc_layers:
        kw["n_enc_layers"] = 2
    if arch.frontend is not None:
        kw["frontend"] = dataclasses.replace(arch.frontend, n_tokens=8, feat_dim=64)
    if arch.window is not None:
        kw["window"] = 32
    if arch.ssm_state:
        kw["ssm_state"] = 4
    return arch.replace(name=arch.name + "-smoke", **kw)
