"""Qwen1.5-4B — dense, 40L, d=2560, 20H MHA (kv=20), d_ff=6912,
vocab 151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.configs.base import ArchConfig, FLConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    fl=FLConfig(mode="replica", schedule="tree"),
    notes="QKV bias [hf:Qwen/Qwen1.5; hf]",
))
