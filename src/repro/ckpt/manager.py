"""Checkpoint manager: keep-N rotation, latest-committed discovery,
auto-resume — the restart half of fault tolerance."""
from __future__ import annotations

import os
import re
import shutil

from repro.ckpt.checkpoint import is_committed, load_checkpoint, \
    save_checkpoint

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, every: int = 1):
        self.dir = directory
        self.keep = keep
        self.every = every
        os.makedirs(directory, exist_ok=True)

    def _steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and is_committed(os.path.join(self.dir, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def should_save(self, step: int) -> bool:
        return step % self.every == 0

    def save(self, step: int, state, meta: dict | None = None) -> str:
        meta = dict(meta or {}, step=step)
        path = save_checkpoint(os.path.join(self.dir, f"step_{step}"),
                               state, meta)
        self._gc()
        return path

    def _gc(self):
        steps = self._steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore_latest(self, like=None):
        """Returns (state, meta) or (None, None) when nothing committed."""
        s = self.latest_step()
        if s is None:
            return None, None
        return load_checkpoint(os.path.join(self.dir, f"step_{s}"), like=like)
