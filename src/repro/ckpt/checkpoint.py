"""Sharded checkpointing: msgpack + zstd, atomic rename, keep-N manager,
exact-resume (params, opt state, FL session state, round counter, RNG).

Layout:
    <dir>/step_<n>/manifest.json        tree structure + shapes/dtypes
    <dir>/step_<n>/shard_<i>.bin        zstd(msgpack) leaf payloads
    <dir>/step_<n>/COMMITTED            written last (atomicity marker)

On a multi-host deployment each host writes its addressable shards; here
the single process writes everything.  Restore validates shapes/dtypes
against the target abstract state so an incompatible resume fails loudly.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np

try:
    import zstandard as zstd
    _C = zstd.ZstdCompressor(level=3)
    _D = zstd.ZstdDecompressor()
    def _comp(b): return _C.compress(b)
    def _decomp(b): return _D.decompress(b)
except Exception:  # pragma: no cover
    import zlib
    def _comp(b): return zlib.compress(b, 3)
    def _decomp(b): return zlib.decompress(b)

import msgpack

SHARD_BYTES = 64 * 1024 * 1024


def _leaf_to_np(x):
    a = np.asarray(x)
    if a.dtype == np.dtype("bfloat16") if hasattr(np, "bfloat16") else False:
        return a
    return a


def save_checkpoint(path: str, state, meta: dict | None = None) -> str:
    """state: pytree of arrays.  Returns the committed directory."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(path) or ".",
                           prefix=".ckpt_tmp_")
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                "meta": meta or {}, "leaves": [], "shards": []}
    shard, shard_size, shard_idx = [], 0, 0

    def flush():
        nonlocal shard, shard_size, shard_idx
        if not shard:
            return
        blob = _comp(msgpack.packb(shard, use_bin_type=True))
        fn = f"shard_{shard_idx}.bin"
        with open(os.path.join(tmp, fn), "wb") as f:
            f.write(blob)
        manifest["shards"].append(fn)
        shard, shard_size, shard_idx = [], 0, shard_idx + 1

    for i, leaf in enumerate(leaves):
        a = np.asarray(leaf)
        dt = str(a.dtype)
        raw = a.tobytes()
        manifest["leaves"].append({"i": i, "shape": list(a.shape),
                                   "dtype": dt, "shard": shard_idx})
        shard.append({"i": i, "data": raw})
        shard_size += len(raw)
        if shard_size >= SHARD_BYTES:
            flush()
    flush()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def is_committed(path: str) -> bool:
    return os.path.exists(os.path.join(path, "COMMITTED"))


def load_checkpoint(path: str, like=None):
    """Returns (state, meta).  ``like``: optional abstract pytree to
    validate and to rebuild the exact tree structure."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if not is_committed(path):
        raise IOError(f"checkpoint {path} not committed")
    blobs = {}
    for fn in manifest["shards"]:
        with open(os.path.join(path, fn), "rb") as f:
            for item in msgpack.unpackb(_decomp(f.read()), raw=False):
                blobs[item["i"]] = item["data"]
    leaves = []
    for spec in manifest["leaves"]:
        dt = np.dtype("uint16") if spec["dtype"] == "bfloat16" \
            else np.dtype(spec["dtype"])
        a = np.frombuffer(blobs[spec["i"]], dtype=dt).reshape(spec["shape"])
        if spec["dtype"] == "bfloat16":
            import jax.numpy as jnp
            a = jax.lax.bitcast_convert_type(jnp.asarray(a), jnp.bfloat16)
        leaves.append(a)
    if like is not None:
        like_leaves, treedef = jax.tree_util.tree_flatten(like)
        assert len(like_leaves) == len(leaves), "leaf count mismatch"
        for l, ref in zip(leaves, like_leaves):
            assert tuple(l.shape) == tuple(ref.shape), \
                f"shape mismatch {l.shape} vs {ref.shape}"
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"]
    return leaves, manifest["meta"]
