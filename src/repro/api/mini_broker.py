"""Hermetic in-process MQTT 3.1.1 mini-broker (asyncio TCP).

The paper's deployment story assumes a real MQTT broker (Mosquitto, EMQX,
HiveMQ, ...) between the coordinator and the fleet.  CI can't assume
external infrastructure, so this module bundles a small broker speaking
actual MQTT 3.1.1 over TCP — enough of the spec for everything SDFLMQ
exercises, so ``repro.api.mqtt_transport.PahoTransport`` (and any stock
MQTT client) is testable with zero setup:

  * CONNECT / CONNACK (protocol level 4, clean-session, client takeover),
  * PUBLISH QoS 0 and QoS 1 (+ PUBACK both directions),
  * SUBSCRIBE / SUBACK, UNSUBSCRIBE / UNSUBACK with ``+``/``#`` wildcards
    and the MQTT-4.7.2-1 ``$``-topic exclusion rule,
  * retained messages (replayed to late subscribers, cleared by an empty
    retained publish),
  * last-will testament, published when a connection dies without a
    DISCONNECT packet (and on session takeover, per [MQTT-3.1.4-2]),
  * PINGREQ / PINGRESP, DISCONNECT.

Topic dispatch reuses :class:`repro.core.broker.TopicTrie` — the same
routing structure (and therefore the same wildcard semantics) as
``SimBroker``, so the two backends can be certified against one
conformance contract (``tests/transport_conformance.py``).

Persistent sessions are supported: a CONNECT with ``clean_session=0``
stores the session — subscriptions survive the connection, QoS-1 messages
routed while the client is offline are queued (bounded), unacked PUBLISHes
are redelivered with the DUP flag (same packet ids) on resume, and the
CONNACK reports ``session present``.  MQTT 5-style shared subscriptions
(``$share/<group>/<filter>``) round-robin each message across the group.
Session state lives in process memory only — a broker restart starts
empty, exactly like an unpersisted Mosquitto.

Not implemented (rejected or degraded cleanly): QoS 2 (granted as QoS 1)
and authentication (username/password bytes are parsed and ignored).

The broker runs its asyncio loop on a daemon thread; ``start()`` returns
once the socket is bound (``port=0`` picks a free port, exposed as
``.port``)::

    from repro.api.mini_broker import MiniBroker

    broker = MiniBroker(port=0).start()
    ...  # point any MQTT client at 127.0.0.1:broker.port
    broker.stop()

Or standalone, for a `mosquitto`-style workflow::

    python -m repro.api.mini_broker --port 1883
"""
from __future__ import annotations

import argparse
import asyncio
import threading
from collections import OrderedDict, defaultdict, deque
from typing import Optional

from repro.core.broker import (Message, RetainedSeq, TopicTrie, parse_share,
                               retain_message, topic_matches)

# MQTT 3.1.1 control-packet types (spec §2.2.1)
CONNECT, CONNACK, PUBLISH, PUBACK = 1, 2, 3, 4
SUBSCRIBE, SUBACK, UNSUBSCRIBE, UNSUBACK = 8, 9, 10, 11
PINGREQ, PINGRESP, DISCONNECT = 12, 13, 14

_MAX_REMAINING_LEN = 268_435_455      # spec §2.2.3: 4 varint bytes


class ProtocolError(Exception):
    """Malformed or unsupported MQTT packet — the connection is closed."""


# ---------------------------------------------------------------------------
# wire encoding helpers
# ---------------------------------------------------------------------------

def encode_varint(n: int) -> bytes:
    """MQTT remaining-length varint (7 bits per byte, LSB first)."""
    if not 0 <= n <= _MAX_REMAINING_LEN:
        raise ProtocolError(f"remaining length out of range: {n}")
    out = bytearray()
    while True:
        n, b = divmod(n, 128)
        out.append(b | (0x80 if n else 0))
        if not n:
            return bytes(out)


def encode_utf8(s: str) -> bytes:
    raw = s.encode("utf-8")
    if len(raw) > 0xFFFF:
        raise ProtocolError("utf-8 string too long")
    return len(raw).to_bytes(2, "big") + raw


def packet(ptype: int, flags: int, body: bytes = b"") -> bytes:
    return bytes(((ptype << 4) | flags,)) + encode_varint(len(body)) + body


def publish_packet(topic: str, payload: bytes, qos: int = 0,
                   retain: bool = False, mid: int = 0,
                   dup: bool = False) -> bytes:
    flags = (0x08 if dup else 0) | (qos << 1) | (0x01 if retain else 0)
    body = encode_utf8(topic)
    if qos > 0:
        body += mid.to_bytes(2, "big")
    return packet(PUBLISH, flags, body + payload)


class _Cursor:
    """Sequential reader over a packet body."""

    __slots__ = ("data", "pos")

    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.data):
            raise ProtocolError("truncated packet")
        out = self.data[self.pos:self.pos + n]
        self.pos += n
        return out

    def u8(self) -> int:
        return self.take(1)[0]

    def u16(self) -> int:
        return int.from_bytes(self.take(2), "big")

    def utf8(self) -> str:
        return self.take(self.u16()).decode("utf-8")

    def rest(self) -> bytes:
        out = self.data[self.pos:]
        self.pos = len(self.data)
        return out

    @property
    def exhausted(self) -> bool:
        return self.pos >= len(self.data)


# ---------------------------------------------------------------------------
# broker
# ---------------------------------------------------------------------------

class _Conn:
    """One live client connection (all state touched only on the broker's
    event loop)."""

    __slots__ = ("client_id", "writer", "session", "will_topic",
                 "will_payload", "will_qos", "will_retain", "graceful",
                 "closed")

    def __init__(self, writer: asyncio.StreamWriter):
        self.client_id = ""
        self.writer = writer
        self.session: Optional["_Session"] = None
        self.will_topic: Optional[str] = None
        self.will_payload = b""
        self.will_qos = 0
        self.will_retain = False
        self.graceful = False                   # DISCONNECT packet seen
        self.closed = False

    def send(self, frame: bytes) -> None:
        if not self.closed:
            try:
                self.writer.write(frame)
            except Exception:       # peer vanished mid-write
                self.closed = True


class _Session:
    """Per-client-id broker session state.  Clean sessions die with their
    connection; persistent ones (CONNECT clean_session=0) keep their
    subscriptions, queue QoS-1 traffic while offline, and track unacked
    PUBLISHes for DUP redelivery on resume [MQTT-3.1.2-4..7]."""

    __slots__ = ("client_id", "clean", "subs", "queued", "inflight",
                 "next_mid", "conn")

    def __init__(self, client_id: str, clean: bool):
        self.client_id = client_id
        self.clean = clean
        self.subs: dict[str, int] = {}          # topic filter -> granted qos
        # (topic, payload, qos, retain) routed while offline
        self.queued: deque = deque()
        # mid -> (topic, payload, qos, retain): sent but not PUBACKed
        self.inflight: "OrderedDict[int, tuple]" = OrderedDict()
        self.next_mid = 0
        self.conn: Optional[_Conn] = None

    @property
    def online(self) -> bool:
        return self.conn is not None and not self.conn.closed


class MiniBroker:
    """In-process MQTT 3.1.1 broker on a background asyncio thread.

    Routing mirrors ``SimBroker``: a :class:`TopicTrie` keyed on
    ``(client_id, filter)``, first matching filter per client wins, an
    effective QoS of ``min(publish qos, subscription qos)``, and
    ``$``-topics invisible to wildcard-rooted filters.

    >>> from repro.api.mini_broker import MiniBroker
    >>> from repro.api.mqtt_transport import PahoTransport
    >>> broker = MiniBroker(port=0).start()      # real TCP, ephemeral port
    >>> t = PahoTransport(port=broker.port, backend="builtin")
    >>> got = []
    >>> _ = t.connect("sub", lambda m: got.append(bytes(m.payload)))
    >>> t.subscribe("sub", "fleet/#", qos=1)
    >>> _ = t.publish("fleet/telemetry", b"42", qos=1, sender="sub")
    >>> _ = t.settle()                           # flush-barrier quiescence
    >>> got
    [b'42']
    >>> t.close(); broker.stop()
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 name: str = "mini0", offline_queue_limit: int = 10_000):
        self.name = name
        self.host = host
        self.port = port
        self.offline_queue_limit = offline_queue_limit
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._thread: Optional[threading.Thread] = None
        self._sessions: dict[str, _Session] = {}
        self._retained: dict[str, RetainedSeq] = {}
        self._trie = TopicTrie()
        # per-(group, real-filter) round-robin cursor for $share routing
        self._share_rr: dict[tuple, int] = {}
        # $SYS-style counters (same keys as SimBroker's SysStats snapshot)
        self.messages_received = 0
        self.messages_sent = 0
        self.bytes_received = 0
        self.bytes_sent = 0
        self.dropped_no_subscriber = 0
        self.pings = 0
        self.sessions_resumed = 0
        self.queued_offline = 0
        self.dropped_offline = 0
        self.redeliveries = 0
        self.shared_deliveries = 0
        self.queue_overflow = 0
        self.per_topic_class: dict[str, int] = defaultdict(int)

    # ---- lifecycle -------------------------------------------------------
    def start(self) -> "MiniBroker":
        """Bind and serve on a daemon thread; returns once listening."""
        assert self._thread is None, "broker already started"
        ready = threading.Event()
        self._thread = threading.Thread(target=self._run, args=(ready,),
                                        name=f"mini-broker-{self.name}",
                                        daemon=True)
        self._thread.start()
        if not ready.wait(timeout=10.0):
            raise RuntimeError("mini-broker failed to start")
        return self

    def _run(self, ready: threading.Event) -> None:
        loop = self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)

        async def serve():
            self._server = await asyncio.start_server(
                self._handle, self.host, self.port)
            self.port = self._server.sockets[0].getsockname()[1]
            ready.set()

        loop.run_until_complete(serve())
        try:
            loop.run_forever()
        finally:
            loop.close()

    def stop(self) -> None:
        """Close every connection and stop the loop (idempotent)."""
        loop, self._loop = self._loop, None
        if loop is None or not loop.is_running():
            return

        async def _shutdown():
            for sess in list(self._sessions.values()):
                if sess.conn is not None:
                    sess.conn.graceful = True   # shutdown fires no wills
                    self._drop(sess.conn)
            if self._server is not None:
                self._server.close()
                await self._server.wait_closed()
            me = asyncio.current_task()
            handlers = [t for t in asyncio.all_tasks() if t is not me]
            for t in handlers:
                t.cancel()
            await asyncio.gather(*handlers, return_exceptions=True)
            loop.stop()

        asyncio.run_coroutine_threadsafe(_shutdown(), loop)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def kill(self) -> None:
        """Abrupt broker death (SIGKILL semantics): every socket is aborted
        mid-flight — no DISCONNECTs, no wills, no graceful teardown.
        Clients observe a dead TCP connection, exactly as if the broker
        process was killed.  The broker object can be ``start()``-ed again
        afterwards; in-memory session state does NOT survive the kill
        (sessions/retained are wiped), matching an unpersisted broker."""
        loop, self._loop = self._loop, None
        if loop is None or not loop.is_running():
            return

        async def _die():
            for sess in list(self._sessions.values()):
                conn = sess.conn
                if conn is not None and not conn.closed:
                    conn.closed = True      # suppress _drop bookkeeping
                    try:
                        conn.writer.transport.abort()
                    except Exception:
                        pass
            if self._server is not None:
                self._server.close()
                await self._server.wait_closed()
            me = asyncio.current_task()
            handlers = [t for t in asyncio.all_tasks() if t is not me]
            for t in handlers:
                t.cancel()
            await asyncio.gather(*handlers, return_exceptions=True)
            loop.stop()

        asyncio.run_coroutine_threadsafe(_die(), loop)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        # a killed broker lost its RAM: fresh state for any restart
        self._sessions.clear()
        self._retained.clear()
        self._trie = TopicTrie()
        self._share_rr.clear()
        self._server = None

    def __enter__(self) -> "MiniBroker":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    @property
    def address(self) -> tuple[str, int]:
        return (self.host, self.port)

    # ---- connection handling --------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        conn = _Conn(writer)
        try:
            ptype, flags, body = await self._read_packet(reader)
            if ptype != CONNECT:
                raise ProtocolError("first packet must be CONNECT")
            self._on_connect(conn, _Cursor(body))
            while True:
                ptype, flags, body = await self._read_packet(reader)
                if ptype == DISCONNECT:
                    conn.graceful = True
                    break
                self._dispatch(conn, ptype, flags, _Cursor(body))
                await writer.drain()    # backpressure on this client's acks
        except (asyncio.IncompleteReadError, ConnectionError,
                ProtocolError, OSError):
            pass
        finally:
            self._drop(conn)

    async def _read_packet(self, reader) -> tuple[int, int, bytes]:
        first = (await reader.readexactly(1))[0]
        length, mult = 0, 1
        for _ in range(4):
            b = (await reader.readexactly(1))[0]
            length += (b & 0x7F) * mult
            if not b & 0x80:
                break
            mult *= 128
        else:
            raise ProtocolError("remaining-length varint too long")
        body = await reader.readexactly(length) if length else b""
        return first >> 4, first & 0x0F, body

    def _dispatch(self, conn: _Conn, ptype: int, flags: int,
                  cur: _Cursor) -> None:
        if ptype == PUBLISH:
            self._on_publish(conn, flags, cur)
        elif ptype == SUBSCRIBE:
            self._on_subscribe(conn, cur)
        elif ptype == UNSUBSCRIBE:
            self._on_unsubscribe(conn, cur)
        elif ptype == PINGREQ:
            self.pings += 1
            conn.send(packet(PINGRESP, 0))
        elif ptype == PUBACK:
            mid = cur.u16()
            if conn.session is not None:            # settles DUP redelivery
                conn.session.inflight.pop(mid, None)
        elif ptype == CONNECT:
            raise ProtocolError("duplicate CONNECT")
        else:
            raise ProtocolError(f"unsupported packet type {ptype}")

    # ---- packet handlers -------------------------------------------------
    def _on_connect(self, conn: _Conn, cur: _Cursor) -> None:
        proto = cur.utf8()
        level = cur.u8()
        if proto not in ("MQTT", "MQIsdp") or level not in (3, 4):
            conn.send(packet(CONNACK, 0, bytes((0, 0x01))))  # bad proto
            raise ProtocolError(f"unsupported protocol {proto!r} v{level}")
        cflags = cur.u8()
        clean = bool(cflags & 0x02)
        cur.u16()                                   # keepalive: not enforced
        conn.client_id = cur.utf8() or f"anon-{id(conn):x}"
        if cflags & 0x04:                           # will flag
            conn.will_topic = cur.utf8()
            conn.will_payload = cur.take(cur.u16())
            conn.will_qos = (cflags >> 3) & 0x03
            conn.will_retain = bool(cflags & 0x20)
        if cflags & 0x80:
            cur.utf8()                              # username: ignored
        if cflags & 0x40:
            cur.take(cur.u16())                     # password: ignored
        sess = self._sessions.get(conn.client_id)
        if sess is not None and sess.conn is not None:
            # session takeover [MQTT-3.1.4-2]: the old connection is closed
            # as a network failure, so its will (if any) IS published
            self._drop(sess.conn)
            sess = self._sessions.get(conn.client_id)  # _drop may forget it
        session_present = False
        if clean or sess is None or sess.clean:
            if sess is not None:
                self._forget_session(sess)
            sess = _Session(conn.client_id, clean)
            self._sessions[conn.client_id] = sess
        else:
            session_present = True
            self.sessions_resumed += 1
        sess.conn = conn
        conn.session = sess
        conn.send(packet(CONNACK, 0,
                         bytes((0x01 if session_present else 0x00, 0))))
        if session_present:
            # unacked QoS-1 publishes first — same packet ids, DUP set
            # [MQTT-4.4.0-1] — then traffic queued while offline
            for mid, (topic, payload, qos, retain) in list(
                    sess.inflight.items()):
                self.redeliveries += 1
                self.messages_sent += 1
                self.bytes_sent += len(payload)
                conn.send(publish_packet(topic, payload, qos, retain,
                                         mid=mid, dup=True))
            queued, sess.queued = sess.queued, deque()
            for topic, payload, qos, retain in queued:
                self._send_to(sess, topic, payload, qos, retain)

    def _on_publish(self, conn: _Conn, flags: int, cur: _Cursor) -> None:
        qos = (flags >> 1) & 0x03
        retain = bool(flags & 0x01)
        if qos > 1:
            raise ProtocolError("QoS 2 not supported")
        topic = cur.utf8()
        if "+" in topic or "#" in topic:
            raise ProtocolError("wildcards are not allowed in topic names")
        mid = cur.u16() if qos > 0 else 0
        payload = cur.rest()
        self.messages_received += 1
        self.bytes_received += len(payload)
        self.per_topic_class[
            topic.split("/")[1] if "/" in topic else topic] += 1
        if qos == 1:
            conn.send(packet(PUBACK, 0, mid.to_bytes(2, "big")))
        self._route(topic, payload, qos, retain)

    def _on_subscribe(self, conn: _Conn, cur: _Cursor) -> None:
        sess = conn.session
        mid = cur.u16()
        granted = bytearray()
        fresh: list[tuple[str, str, Optional[str]]] = []
        while not cur.exhausted:
            filt = cur.utf8()
            qos = min(cur.u8() & 0x03, 1)           # QoS 2 granted as QoS 1
            group, real = parse_share(filt)
            sess.subs[filt] = qos
            self._trie.insert(real, (sess.client_id, filt))
            granted.append(qos)
            fresh.append((filt, real, group))
        conn.send(packet(SUBACK, 0, mid.to_bytes(2, "big") + bytes(granted)))
        # retained replay — after the SUBACK, with the retain bit set, for
        # the filters of THIS packet only [MQTT-3.3.1-6]: earlier
        # subscriptions already received their replay.  Shared
        # subscriptions get NO retained replay (MQTT 5 §4.8.2).
        for filt, real, group in fresh:
            if group is not None:
                continue
            for topic, seq in list(self._retained.items()):
                if topic_matches(real, topic):
                    # full frame sequence, in part order (multi-part
                    # fleet-control calls retain every frame, not just
                    # the last one)
                    for m in seq.messages():
                        self._send_to(sess, topic, m.payload,
                                      min(m.qos, sess.subs[filt]),
                                      retain=True)

    def _on_unsubscribe(self, conn: _Conn, cur: _Cursor) -> None:
        sess = conn.session
        mid = cur.u16()
        while not cur.exhausted:
            filt = cur.utf8()
            if sess.subs.pop(filt, None) is not None:
                self._trie.remove(parse_share(filt)[1],
                                  (sess.client_id, filt))
        conn.send(packet(UNSUBACK, 0, mid.to_bytes(2, "big")))

    # ---- routing ---------------------------------------------------------
    def _route(self, topic: str, payload: bytes, qos: int,
               retain: bool) -> None:
        if retain:
            if payload:
                retain_message(self._retained,
                               Message(topic, payload, qos, retain=True))
            else:
                self._retained.pop(topic, None)     # empty payload clears
        matched = False
        seen: set[str] = set()
        shared: dict[tuple, list] = {}
        for client_id, filt in self._trie.match(topic):
            sess = self._sessions.get(client_id)
            if sess is None:
                continue
            sub_qos = sess.subs.get(filt)
            if sub_qos is None:
                continue
            group, real = parse_share(filt)
            eff = min(qos, sub_qos)
            if group is not None:
                shared.setdefault((group, real), []).append((sess, eff))
                continue
            if client_id in seen:           # first matching filter wins
                continue
            seen.add(client_id)
            if sess.online:
                # [MQTT-3.3.1-9]: the retain flag is 0 on routed
                # (non-replay) deliveries — only retained replay at
                # subscribe time sets it
                self._send_to(sess, topic, payload, eff)
                matched = True
            elif not sess.clean and eff >= 1:
                self._queue_offline(sess, topic, payload, eff)
                matched = True
            else:
                self.dropped_offline += 1
        for key, members in shared.items():
            if self._deliver_shared(key, members, topic, payload):
                matched = True
        if not matched:
            self.dropped_no_subscriber += 1

    def _deliver_shared(self, key: tuple, members: list, topic: str,
                        payload: bytes) -> bool:
        """Deliver one message to exactly one member of a $share group,
        round-robin over live members; if the whole group is offline, a
        durable member (persistent session, effective QoS >= 1) queues it."""
        live = [(s, q) for s, q in members if s.online]
        if live:
            k = self._share_rr.get(key, 0)
            self._share_rr[key] = k + 1
            sess, eff = live[k % len(live)]
            self.shared_deliveries += 1
            self._send_to(sess, topic, payload, eff)
            return True
        durable = [(s, q) for s, q in members if not s.clean and q >= 1]
        if durable:
            k = self._share_rr.get(key, 0)
            self._share_rr[key] = k + 1
            sess, eff = durable[k % len(durable)]
            self._queue_offline(sess, topic, payload, eff)
            return True
        self.dropped_offline += 1
        return False

    def _queue_offline(self, sess: _Session, topic: str, payload: bytes,
                       qos: int) -> None:
        if len(sess.queued) >= self.offline_queue_limit:
            sess.queued.popleft()           # bounded: oldest message loses
            self.queue_overflow += 1
        sess.queued.append((topic, payload, qos, False))
        self.queued_offline += 1

    def _send_to(self, sess: _Session, topic: str, payload: bytes, qos: int,
                 retain: bool = False) -> None:
        mid = 0
        if qos:
            sess.next_mid = (sess.next_mid % 0xFFFF) + 1
            while sess.next_mid in sess.inflight:   # ids still unacked
                sess.next_mid = (sess.next_mid % 0xFFFF) + 1
            mid = sess.next_mid
            if not sess.clean:
                sess.inflight[mid] = (topic, payload, qos, retain)
        frame = publish_packet(topic, payload, qos, retain, mid=mid)
        self.messages_sent += 1
        self.bytes_sent += len(payload)
        if sess.conn is not None:
            sess.conn.send(frame)

    def _drop(self, conn: _Conn) -> None:
        if conn.closed:
            return
        conn.closed = True
        sess = conn.session
        if sess is not None and sess.conn is conn:
            sess.conn = None
        if not conn.graceful and conn.will_topic is not None:
            self._route(conn.will_topic, conn.will_payload,
                        conn.will_qos, conn.will_retain)
        if sess is not None and sess.clean and sess.conn is None \
                and self._sessions.get(sess.client_id) is sess:
            self._forget_session(sess)
        try:
            conn.writer.close()
        except Exception:
            pass

    def _forget_session(self, sess: _Session) -> None:
        for filt in sess.subs:
            self._trie.remove(parse_share(filt)[1], (sess.client_id, filt))
        self._sessions.pop(sess.client_id, None)

    # ---- introspection (thread-safe reads of loop-owned counters) --------
    def sys_stats(self) -> dict:
        return {
            "messages_received": self.messages_received,
            "messages_sent": self.messages_sent,
            "bytes_received": self.bytes_received,
            "bytes_sent": self.bytes_sent,
            "dropped_no_subscriber": self.dropped_no_subscriber,
            "pings": self.pings,
            "per_topic_class": dict(self.per_topic_class),
            "connected_clients": sum(
                1 for s in self._sessions.values() if s.online),
            "persistent_sessions": sum(
                1 for s in self._sessions.values() if not s.clean),
            "sessions_resumed": self.sessions_resumed,
            "queued_offline": self.queued_offline,
            "dropped_offline": self.dropped_offline,
            "redeliveries": self.redeliveries,
            "shared_deliveries": self.shared_deliveries,
            "queue_overflow": self.queue_overflow,
            "retained_messages": len(self._retained),
            "trie_cache_hits": self._trie.cache_hits,
            "trie_cache_misses": self._trie.cache_misses,
            "subscriptions": self._trie.size,
        }

    def retained_topics(self) -> list[str]:
        return sorted(self._retained)


def main(argv: Optional[list[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="SDFLMQ bundled MQTT 3.1.1 mini-broker")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=1883)
    args = ap.parse_args(argv)
    broker = MiniBroker(args.host, args.port).start()
    print(f"mini-broker listening on {broker.host}:{broker.port} "
          f"(ctrl-c to stop)")
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        broker.stop()


if __name__ == "__main__":
    main()
