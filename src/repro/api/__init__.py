"""repro.api — the single entry point for running SDFLMQ federations.

    from repro.api import Federation
    fed = Federation()                       # broker + coordinator + PS
    clients = [fed.client(f"c{i}") for i in range(5)]
    session = fed.create_session("s1", model_name="mlp", rounds=3,
                                 participants=clients, strategy="fedavg")
    session.run(train_fn, initial_params=init)

Submodules:
    federation — Federation / FederatedSession facade
    strategies — pluggable AggregationStrategy registry (fedavg, fedprox,
                 trimmed_mean, coordinate_median, fedadam, *_poly staleness
                 variants); one surface for both the host MQTT path and the
                 compiled collective path
    transport  — Transport protocol + LatencyTransport edge-network model
    async_fl   — AsyncFederatedSession: bounded-staleness FedBuff buffers,
                 per-client pacing, head gossip under partitions
    mqtt_transport — PahoTransport: the Transport protocol over a real
                 MQTT broker (paho-mqtt or the bundled stdlib client)
    mini_broker — hermetic in-process MQTT 3.1.1 broker for CI/dev

Observability lives in the sibling package ``repro.obs`` (re-exported
here): ``Federation(metrics=True)`` + ``serve_metrics(fed.metrics)``
gives a Prometheus ``/metrics`` endpoint and JSON round timelines.

Heavy imports are lazy (PEP 562) so core modules can import
``repro.api.strategies`` without dragging in the full facade.
"""
from __future__ import annotations

_EXPORTS = {
    "Federation": ("repro.api.federation", "Federation"),
    "FederatedSession": ("repro.api.federation", "FederatedSession"),
    "AggregationStrategy": ("repro.api.strategies", "AggregationStrategy"),
    "get_strategy": ("repro.api.strategies", "get_strategy"),
    "register_strategy": ("repro.api.strategies", "register_strategy"),
    "list_strategies": ("repro.api.strategies", "list_strategies"),
    "Transport": ("repro.api.transport", "Transport"),
    "LatencyTransport": ("repro.api.transport", "LatencyTransport"),
    "LinkModel": ("repro.api.transport", "LinkModel"),
    "SimClock": ("repro.api.transport", "SimClock"),
    "PahoTransport": ("repro.api.mqtt_transport", "PahoTransport"),
    "MiniBroker": ("repro.api.mini_broker", "MiniBroker"),
    "AsyncConfig": ("repro.api.async_fl", "AsyncConfig"),
    "AsyncFederatedSession": ("repro.api.async_fl", "AsyncFederatedSession"),
    "AsyncReport": ("repro.api.async_fl", "AsyncReport"),
    "scenarios": ("repro.api.scenarios", None),   # submodule, not attribute
    "async_fl": ("repro.api.async_fl", None),     # submodule
    "MetricsRegistry": ("repro.obs", "MetricsRegistry"),
    "Telemetry": ("repro.obs", "Telemetry"),
    "Tracer": ("repro.obs", "Tracer"),
    "serve_metrics": ("repro.obs", "serve_metrics"),
    "obs": ("repro.obs", None),                   # telemetry subpackage
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    mod = importlib.import_module(mod_name)
    return mod if attr is None else getattr(mod, attr)


def __dir__():
    return __all__
