"""The SDFLMQ facade: one entry point for running federations.

``Federation`` wires the infrastructure (transport/broker(s), coordinator,
parameter server) once; ``FederatedSession`` handles run the paper's round
protocol (create/join, local train, send, global update, readiness) so that
examples, benchmarks, and drivers stop hand-rolling the loop::

    from repro.api import Federation

    fed = Federation()
    clients = [fed.client(f"c{i}") for i in range(5)]
    session = fed.create_session("s1", model_name="mlp", rounds=3,
                                 participants=clients,
                                 strategy="trimmed_mean")

    def train(client_id, global_params, round_idx):
        local = my_local_training(global_params)
        return local, n_samples

    session.run(train, initial_params=init)
    final = session.global_params()

Edge-network scenarios: pass ``latency=dict(delay_s=..., jitter_s=...,
drop_p=...)`` (or a prebuilt LatencyTransport) to model per-link delay and
loss on the control/model plane.

Virtual time: every federation owns a ``SimClock`` shared by its transport
and coordinator.  By default the clock auto-drains (each publish delivers
to idle — identical to a synchronous pump); inside ``fed.clock.hold()``
deliveries queue at their modeled arrival times and ``session.step_time``
(or ``repro.api.scenarios.play``) releases them in timestamp order, so
reordering, partitions, straggler deadlines, and churn become exercisable.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional, Union

from repro.api.strategies import AggregationStrategy, get_strategy
from repro.api.transport import LatencyTransport, SimClock, Transport
from repro.core.broker import SimBroker
from repro.core.client import Params, SDFLMQClient
from repro.core.coordinator import Coordinator, CoordinatorConfig
from repro.core.parameter_server import ParameterServer
from repro.core.stats import ClientStats

TrainFn = Callable[[str, Optional[Params], int], tuple[Params, int]]


class Federation:
    """Owns the infrastructure of one federation: a transport, the
    coordinator service, and the parameter server.

    The default transport is an in-process ``SimBroker`` (deterministic,
    synchronous); pass ``transport=PahoTransport(...)`` to run the same
    federation over a real MQTT broker, or ``latency=dict(...)`` to model
    per-link edge networks on virtual time — the session code is
    identical on all three.

    >>> import numpy as np
    >>> from repro.api import Federation
    >>> fed = Federation()
    >>> clients = [fed.client(f"c{i}") for i in range(3)]
    >>> session = fed.create_session("demo", model_name="m", rounds=1,
    ...                              participants=clients)
    >>> def train(client_id, global_params, round_idx):
    ...     value = float(client_id[1:]) + 1.0     # c0 -> 1.0, c1 -> 2.0 ...
    ...     return {"w": np.full(2, value, np.float32)}, 1
    >>> _ = session.run(train, initial_params={"w": np.zeros(2, np.float32)})
    >>> session.global_params()["w"]               # fedavg mean of 1, 2, 3
    array([2., 2.], dtype=float32)
    >>> session.state, session.global_version()
    ('terminated', 1)
    """

    def __init__(self, transport: Optional[Transport] = None,
                 latency: Optional[dict] = None,
                 role_policy: str = "memory_aware",
                 aggregator_ratio: float = 0.3,
                 levels: int = 3,
                 round_deadline_s: float = 0.0,
                 flush_spacing_s: float = 0.0,
                 clock: Optional[SimClock] = None,
                 coordinator_cfg: Optional[CoordinatorConfig] = None,
                 wire_format: str = "tb",
                 uplink_codec: Optional[str] = None,
                 downlink_codec: Optional[str] = None,
                 update_filter=None,
                 topk_density: float = 0.01,
                 topk_warmup_rounds: int = 0,
                 metrics=None):
        #: model-plane wire format for clients created via ``client()``:
        #: "tb" = zero-copy TensorBundle (default), "legacy" = msgpack
        #: ExtType (bit-identity fallback).  ``uplink_codec="int8_ef"``
        #: turns on int8+error-feedback quantized leaf uplinks;
        #: ``uplink_codec="topk_int8_ef"`` adds magnitude top-k
        #: sparsification at ``topk_density`` (EF residual carries the
        #: un-sent mass; ``topk_warmup_rounds`` early rounds ship dense
        #: int8 so the first globals aren't starved to k coordinates).
        #: ``downlink_codec="int8"`` quantizes the retained
        #: global broadcast.  ``update_filter`` (ParamFilter or comma
        #: pattern string) ships only matching leaves — the LoRA-style
        #: partial-update path for large models.
        self.wire_format = wire_format
        self.uplink_codec = uplink_codec
        self.downlink_codec = downlink_codec
        self.update_filter = update_filter
        self.topk_density = topk_density
        self.topk_warmup_rounds = topk_warmup_rounds
        transport = transport if transport is not None else SimBroker()
        if not isinstance(transport, LatencyTransport):
            transport = LatencyTransport(transport, clock=clock or SimClock(),
                                         **(latency or {}))
        elif latency:
            transport = LatencyTransport(transport,
                                         clock=clock or transport.clock,
                                         **latency)
        elif clock is not None:
            # prebuilt LatencyTransport + explicit clock: rebase the (still
            # fresh) transport onto the caller's clock rather than silently
            # ignoring it (re-attaching any real-network inner transport)
            transport.clock = clock
            attach = getattr(transport.inner, "attach_clock", None)
            if attach is not None:
                attach(clock)
        self.transport = transport
        self.clock = transport.clock
        self.coordinator = Coordinator(
            transport,
            coordinator_cfg or CoordinatorConfig(
                role_policy=role_policy, aggregator_ratio=aggregator_ratio,
                levels=levels, round_deadline_s=round_deadline_s,
                flush_spacing_s=flush_spacing_s),
            clock=self.clock)
        self.param_server = ParameterServer(transport)
        self.clients: dict[str, SDFLMQClient] = {}
        self.cohorts: dict = {}          # cohort_id -> CohortClient
        self.sessions: dict[str, "FederatedSession"] = {}
        #: opt-in telemetry (repro.obs).  ``metrics`` accepts ``None``/
        #: ``False`` (off — the zero-overhead, bit-identical default),
        #: ``True`` (fresh registry), a ``MetricsRegistry`` to mirror
        #: into, or a prebuilt ``Telemetry``.  Trace timestamps ride the
        #: federation's virtual clock.
        self.obs = None
        if metrics is not None and metrics is not False:
            from repro.obs import MetricsRegistry, Telemetry
            if isinstance(metrics, Telemetry):
                self.obs = metrics
            else:
                reg = metrics if isinstance(metrics, MetricsRegistry) else None
                self.obs = Telemetry(registry=reg, clock=self.clock)
            self.obs.bind_federation(self)
            self.transport.obs = self.obs
            # a wrapped transport (LatencyTransport over PahoTransport)
            # traces reconnect/backoff events from the inner layer
            inner = getattr(self.transport, "inner", None)
            if inner is not None:
                inner.obs = self.obs
            self.coordinator.obs = self.obs

    def deliver(self) -> None:
        """Drain every in-flight delivery (no-op while the clock is held —
        then ``clock.advance_to``/``session.step_time`` controls release)."""
        if not self.clock.held:
            self.clock.run_until_idle()

    def close(self) -> None:
        """Tear down the federation's transport connections.  A no-op for
        the in-process simulators; against a real MQTT backend
        (``PahoTransport``) this gracefully disconnects the pooled client
        connections so the broker drops their sessions without firing
        LWTs."""
        close = getattr(self.transport, "close", None)
        if close is not None:
            close()

    # alias: the transport of a single-broker federation IS the broker
    @property
    def broker(self) -> Transport:
        return self.transport

    @property
    def metrics(self):
        """The federation's ``MetricsRegistry`` (None when metrics are off)."""
        return self.obs.registry if self.obs is not None else None

    @property
    def tracer(self):
        """The federation's ``Tracer`` (None when metrics are off)."""
        return self.obs.tracer if self.obs is not None else None

    def client(self, client_id: str, preferred_role: str = "trainer",
               stats: Optional[ClientStats] = None) -> SDFLMQClient:
        """Create (or return) a client endpoint attached to this federation."""
        if client_id not in self.clients:
            cl = SDFLMQClient(
                client_id, self.transport, preferred_role=preferred_role,
                stats=stats, wire_format=self.wire_format,
                uplink_codec=self.uplink_codec,
                downlink_codec=self.downlink_codec,
                update_filter=self.update_filter,
                topk_density=self.topk_density,
                topk_warmup_rounds=self.topk_warmup_rounds)
            cl.obs = self.obs
            self.clients[client_id] = cl
        return self.clients[client_id]

    def cohort(self, cohort_id: str, member_ids: Iterable[str],
               stats: Optional[ClientStats] = None,
               transport: Optional[Transport] = None):
        """Create (or return) a ``CohortClient`` endpoint fronting
        ``member_ids`` as logical clients over ONE connection (fleet-scale
        mode).  ``transport`` attaches the cohort to a different transport
        than the federation's own — e.g. a per-site broker shard in a
        multi-broker fabric (``repro.api.fleet``) — as long as it shares
        the federation's clock."""
        if cohort_id not in self.cohorts:
            from repro.core.cohort import CohortClient
            co = CohortClient(cohort_id, transport or self.transport,
                              list(member_ids), wire_format=self.wire_format,
                              stats=stats)
            co.obs = self.obs
            self.cohorts[cohort_id] = co
        return self.cohorts[cohort_id]

    def create_fleet_session(self, session_id: str, model_name: str,
                             rounds: int, cohorts: Iterable,
                             strategy: Union[str, AggregationStrategy] = "fedavg",
                             session_time_s: float = 3600.0,
                             waiting_time_s: float = 120.0,
                             initial_params: Optional[Params] = None,
                             ) -> "FleetSession":
        """Fleet-scale session over ``CohortClient`` endpoints: each cohort
        joins all of its fronted members in one RPC; capacity is the total
        member count, so the session starts once every cohort has joined.
        ``initial_params`` seeds round 0 (before any global exists)."""
        cohorts = list(cohorts)
        assert cohorts, "a fleet session needs at least one cohort"
        strat = get_strategy(strategy)
        total = sum(len(co.active) for co in cohorts)
        session = FleetSession(self, session_id, model_name, strat)
        if initial_params is not None:
            session._initial = initial_params
        self.sessions[session_id] = session
        for co in cohorts:
            co.join_fleet_session(session_id, model_name, fl_rounds=rounds,
                                  capacity_min=total, capacity_max=total,
                                  session_time_s=session_time_s,
                                  waiting_time_s=waiting_time_s,
                                  strategy=strat.name)
            session._admit_cohort(co)
        self.deliver()
        return session

    def create_session(self, session_id: str, model_name: str, rounds: int,
                       participants: Iterable[Union[str, SDFLMQClient]],
                       strategy: Union[str, AggregationStrategy] = "fedavg",
                       capacity: Optional[tuple[int, int]] = None,
                       session_time_s: float = 3600.0,
                       waiting_time_s: float = 120.0,
                       async_mode=None,
                       defense=None) -> "FederatedSession":
        """First participant creates the session, the rest join.  ``capacity``
        defaults to exactly the participant set (session starts immediately
        once everyone has joined); pass ``(min, max)`` to leave headroom for
        elastic joins — then call ``session.start()`` once quorum suffices.

        ``async_mode`` switches the session to asynchronous K-of-N
        federation (bounded-staleness FedBuff buffers, per-client pacing,
        optional head gossip): pass a ``repro.api.async_fl.AsyncConfig``, a
        dict of its fields, or ``True`` for the defaults — the handle is
        then an ``AsyncFederatedSession`` driven by ``run_async`` and
        ``rounds`` becomes the global-version budget.

        ``defense`` switches on the self-defending control plane (heartbeat
        liveness, update-norm screening, reputation-weighted combines, and
        reputation-driven role rotation when the federation runs the
        ``reputation_aware`` role policy): pass a
        ``repro.core.defense.DefenseConfig``, a dict of its fields, or
        ``True`` for the defaults.

        A client endpoint can hold aggregation *roles* in only one session
        at a time (the RoleArbiter tracks a single assignment, as in the
        paper); run concurrent sessions with disjoint client sets."""
        members = [p if isinstance(p, SDFLMQClient) else self.client(p)
                   for p in participants]
        assert members, "a session needs at least one participant"
        cap_min, cap_max = capacity or (len(members), len(members))
        # names pass through untouched (resolve from the shared registry);
        # tuned instances get a session-scoped registration in the client
        async_wire = None
        if async_mode:
            from repro.api.async_fl import (AsyncConfig,
                                            AsyncFederatedSession)
            acfg = (async_mode if isinstance(async_mode, AsyncConfig)
                    else AsyncConfig() if async_mode is True
                    else AsyncConfig(**dict(async_mode)))
            session = AsyncFederatedSession(self, session_id, model_name,
                                            get_strategy(strategy), acfg)
            async_wire = acfg.to_wire()
        else:
            session = FederatedSession(self, session_id, model_name,
                                       get_strategy(strategy))
        defense_wire = None
        if defense:
            from repro.core.defense import DefenseConfig
            defense_wire = DefenseConfig.from_wire(defense).to_wire()
            session._defense = defense_wire
        self.sessions[session_id] = session
        members[0].create_fl_session(
            session_id, model_name, fl_rounds=rounds,
            session_capacity_min=cap_min, session_capacity_max=cap_max,
            session_time_s=session_time_s, waiting_time_s=waiting_time_s,
            strategy=strategy, async_cfg=async_wire,
            defense_cfg=defense_wire)
        session._admit(members[0])
        for m in members[1:]:
            session.join(m, rounds=rounds)
        return session


class FederatedSession:
    """Handle to one FL session: the round loop, membership, callbacks."""

    def __init__(self, federation: Federation, session_id: str,
                 model_name: str, strategy: AggregationStrategy):
        self.federation = federation
        self.session_id = session_id
        self.model_name = model_name
        self.strategy = strategy
        self.participants: dict[str, SDFLMQClient] = {}
        self.on_global_update: Optional[Callable] = None
        self._on_round_start: Optional[Callable] = None
        self._initial: Optional[Params] = None
        self._seen_version = 0          # dedupe fan-in from many clients
        self._seen_round = -1
        self._defense: Optional[dict] = None   # defense wire cfg (or None)

    # ------------------------------------------------------------------
    # Callbacks
    # ------------------------------------------------------------------
    @property
    def on_round_start(self) -> Optional[Callable]:
        return self._on_round_start

    @on_round_start.setter
    def on_round_start(self, fn: Optional[Callable]) -> None:
        """Round 0 starts while create_session is still executing, before
        the caller can possibly assign this hook — replay the last seen
        round_start on assignment so round 0 is observable."""
        self._on_round_start = fn
        if fn is not None and self._seen_round >= 0:
            fn(self._seen_round)

    # ------------------------------------------------------------------
    # Membership
    # ------------------------------------------------------------------
    def _admit(self, client: SDFLMQClient) -> None:
        if client.client_id in self.participants:
            return
        self.participants[client.client_id] = client
        if self._defense is not None:
            self._arm_heartbeat(client)
        # chain, don't clobber: a client may deliver events for several
        # sessions (each hook filters on its own session id)
        prev_g, prev_r = client.on_global_update, client.on_round_start

        def g_hook(sid, params, version):
            if prev_g:
                prev_g(sid, params, version)
            self._client_global_update(sid, params, version)

        def r_hook(sid, round_idx):
            if prev_r:
                prev_r(sid, round_idx)
            self._client_round_start(sid, round_idx)

        client.on_global_update = g_hook
        client.on_round_start = r_hook

    def _arm_heartbeat(self, client: SDFLMQClient) -> None:
        """Defense: every participant beats the coordinator's liveness
        endpoint on the shared clock.  The series self-cancels when the
        client leaves/fails or the session ends — a silently-dead (or
        deliberately mute) client stops beating and takes reputation
        penalties from the coordinator's sweep."""
        period = float(self._defense.get("heartbeat_period_s", 1.0))
        if period <= 0:
            return
        cid = client.client_id

        def beat():
            if self.state != "running" and self.state != "waiting":
                return False
            cl = self.participants.get(cid)
            if cl is None:
                return False
            cl.heartbeat(self.session_id)
            return True

        self.federation.clock.schedule_periodic(period, beat)

    def join(self, client: Union[str, SDFLMQClient], rounds: int = 0,
             preferred_role: Optional[str] = None) -> bool:
        """Join (also mid-run: the coordinator rearranges roles).  Returns
        whether the coordinator admitted the client.  The admission
        handshake is synchronous: even on a held clock, queued deliveries
        are drained so the answer reflects the coordinator's decision."""
        cl = (client if isinstance(client, SDFLMQClient)
              else self.federation.client(client))
        cl.join_fl_session(self.session_id, self.model_name, fl_rounds=rounds,
                           preferred_role=preferred_role)
        self.federation.clock.run_until_idle()
        ok = cl.client_id in self._session.contributors
        if ok:
            self._admit(cl)
        return ok

    def leave(self, client_id: str) -> None:
        """Graceful leave: the coordinator rearranges the remaining tree."""
        cl = self.participants.pop(client_id, None)
        if cl is not None:
            cl.leave(self.session_id)

    def fail(self, client_id: str) -> None:
        """Abnormal death: the broker fires the LWT, the coordinator's
        failure detector removes the client and rearranges."""
        cl = self.participants.pop(client_id, None)
        if cl is not None:
            cl.fail()
            self.federation.clients.pop(client_id, None)

    def start(self) -> bool:
        """Waiting time elapsed: start at quorum even if not full."""
        return self.federation.coordinator.expire_waiting(self.session_id)

    # ------------------------------------------------------------------
    # Round loop
    # ------------------------------------------------------------------
    def run_round_async(self, train_fn: TrainFn,
                        stats_fn: Optional[Callable] = None) -> int:
        """Local training on every participant, models up the cluster tree,
        readiness signals (round-status updates, paper §III-E4) — without
        waiting for delivery.  With the clock held, every message sits in
        the delivery queue at its modeled arrival time; drive it with
        ``step_time``/``clock.advance_to`` (or ``scenarios.play``).
        Returns the round index the work was published for."""
        rnd = self.round_idx
        base = self.global_params()
        if base is None:
            base = self._initial
        obs = self.federation.obs
        for cid, cl in sorted(self.participants.items()):
            if obs is not None:
                obs.trace("train", session=self.session_id, client=cid,
                          round=rnd)
            params, n_samples = train_fn(cid, base, rnd)
            cl.set_model(self.session_id, params, n_samples=n_samples)
        for cid, cl in sorted(self.participants.items()):
            cl.send_local(self.session_id)
        for cid, cl in sorted(self.participants.items()):
            cl.signal_ready(self.session_id,
                            stats=stats_fn(cid, rnd) if stats_fn else None)
        return rnd

    def run_round(self, train_fn: TrainFn,
                  stats_fn: Optional[Callable] = None) -> Optional[Params]:
        """One federated round: ``run_round_async`` + drain all deliveries.
        ``stats_fn(client_id, round_idx) -> ClientStats`` feeds fresh system
        stats to the role optimizer.  Returns the new global."""
        self.run_round_async(train_fn, stats_fn=stats_fn)
        self.federation.deliver()
        return self.global_params()

    def step_time(self, dt: Optional[float] = None) -> float:
        """Advance the federation's virtual clock — firing queued deliveries
        AND timers (round deadlines, scenario triggers) in timestamp order.
        ``dt=None`` steps to the next pending event.  Returns ``clock.now``."""
        clock = self.federation.clock
        if dt is None:
            nxt = clock.next_event_time()
            if nxt is not None:
                clock.advance_to(nxt)
            return clock.now
        return clock.advance(dt)

    def run(self, train_fn: TrainFn, rounds: Optional[int] = None,
            initial_params: Optional[Params] = None,
            stats_fn: Optional[Callable] = None) -> list[Params]:
        """Round loop until the session terminates (or ``rounds`` done).
        ``initial_params`` seeds round 0 (before any global exists)."""
        if initial_params is not None:
            self._initial = initial_params
        globals_seen: list[Params] = []
        while self.state == "running" and (rounds is None
                                           or len(globals_seen) < rounds):
            g = self.run_round(train_fn, stats_fn=stats_fn)
            if g is not None:
                globals_seen.append(g)
        return globals_seen

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def _session(self):
        return self.federation.coordinator.sessions[self.session_id]

    @property
    def state(self) -> str:
        return self._session.state.value

    @property
    def round_idx(self) -> int:
        return self._session.round_idx

    def global_params(self) -> Optional[Params]:
        g = self.federation.param_server.get_global(self.session_id)
        return g["params"] if g else None

    def global_version(self) -> int:
        g = self.federation.param_server.get_global(self.session_id)
        return g["version"] if g else 0

    def tree(self):
        return self.federation.coordinator.tree_of(self.session_id)

    def contributors(self) -> list[str]:
        return sorted(self._session.contributors)

    # ------------------------------------------------------------------
    def _client_global_update(self, sid: str, params: Params,
                              version: int) -> None:
        # every participant's client fires this; emit once per version
        if sid == self.session_id and version > self._seen_version:
            self._seen_version = version
            if self.on_global_update:
                self.on_global_update(params, version)

    def _client_round_start(self, sid: str, round_idx: int) -> None:
        if sid == self.session_id and round_idx > self._seen_round:
            self._seen_round = round_idx
            if self.on_round_start:
                self.on_round_start(round_idx)


class FleetSession(FederatedSession):
    """Round loop over ``CohortClient`` endpoints (fleet-scale mode).

    The handle keeps the ``FederatedSession`` surface (state/round
    introspection, ``run``, scenario compatibility: cohorts register in
    ``participants`` so partitions/flaky links key on cohort ids), but the
    round loop trains struct-of-arrays parameter banks and publishes
    through each cohort's batched data plane.  Per-cohort member order is
    globally sorted, so a single-cohort fleet replays an individual-client
    federation bit-for-bit (see core/cohort.py).
    """

    def __init__(self, federation: Federation, session_id: str,
                 model_name: str, strategy: AggregationStrategy):
        super().__init__(federation, session_id, model_name, strategy)
        self.cohorts: dict = {}          # cohort_id -> CohortClient

    def _admit_cohort(self, co) -> None:
        if co.client_id in self.cohorts:
            return
        self.cohorts[co.client_id] = co
        # scenario events and report plumbing see the cohort endpoint as a
        # participant (it IS an SDFLMQClient); the overridden round loop
        # never iterates participants, so the two views don't collide
        self._admit(co)

    def member_count(self) -> int:
        return sum(len(co.active) for co in self.cohorts.values())

    def drop_members(self, cohort_id: str, member_ids) -> None:
        """Member-level churn: fronted logical ids leave mid-run (one
        batched RPC + one coordinator rearrangement per cohort)."""
        self.cohorts[cohort_id].drop_members(self.session_id, member_ids)
        self.federation.deliver()

    def run_round_async(self, train_fn: TrainFn,
                        stats_fn: Optional[Callable] = None) -> int:
        """Train every cohort's bank, replay the aggregation schedule, and
        report readiness — one batched message per cohort.  ``train_fn``
        keeps the individual-session signature ``(member_id, start_params,
        round_idx) -> (params, n_samples)``."""
        rnd = self.round_idx
        base = self.global_params()
        if base is None:
            base = self._initial
        sid = self.session_id
        for co_id, co in sorted(self.cohorts.items()):
            if sid not in co.banks:
                assert base is not None, "fleet round 0 needs initial_params"
                co.set_bank(sid, base)
            co.train_members(sid,
                             lambda cid, start: train_fn(cid, start, rnd))
        for co_id, co in sorted(self.cohorts.items()):
            co.run_local_round(sid)
        for co_id, co in sorted(self.cohorts.items()):
            co.signal_ready_all(sid)
        return rnd

    def run_round_vectorized(self, train_fn: Callable,
                             stats_fn: Optional[Callable] = None) -> int:
        """Fleet-scale round: ``train_fn(bank_data, weights, global_params)
        -> (bank_data, weights)`` updates a cohort's whole struct-of-arrays
        bank in ONE call (feed it ``fl_step.build_cohort_local_step`` output
        or plain numpy ufuncs over the leading member axis) — no per-member
        Python dispatch.  Aggregation/readiness are identical to
        ``run_round_async``; drain with ``federation.deliver()``."""
        rnd = self.round_idx
        base = self.global_params()
        if base is None:
            base = self._initial
        sid = self.session_id
        for co_id, co in sorted(self.cohorts.items()):
            if sid not in co.banks:
                assert base is not None, "fleet round 0 needs initial_params"
                co.set_bank(sid, base)
            co.train_vectorized(sid, train_fn)
        for co_id, co in sorted(self.cohorts.items()):
            co.run_local_round(sid)
        for co_id, co in sorted(self.cohorts.items()):
            co.signal_ready_all(sid)
        return rnd
