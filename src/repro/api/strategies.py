"""Pluggable aggregation strategies — ONE implementation surface consumed by
both SDFLMQ data paths:

  * the host-side accumulator path (core/client.py): weighted partial sums /
    stacked contributions travel up the cluster tree over MQTT;
  * the compiled tree-collective path (core/aggregation.py): the same math
    runs as grouped psums / all-gathers under shard_map on the mesh.

A strategy is three small hooks over parameter pytrees, written against an
array namespace ``xp`` (numpy on the host path, jax.numpy when compiled):

  * ``premap(params, ref, xp)``       — transform one client's raw model
    before weighting/summation (fedprox mixes toward the previous global).
    Applied exactly once, at the leaf; partial sums are never re-premapped.
  * ``finalize(mean, ref, state, xp)``— turn the weighted mean into the new
    global (+ new server state).  fedavg returns the mean untouched, so the
    fedavg fast path is bit-identical to plain weighted averaging.
  * ``combine(stacked, weights, xp)`` — for ``reduction == "stack"``
    strategies (trimmed mean, coordinate median): full client-stacked
    parameters (leading dim = contributors) -> global.  These are not
    decomposable into partial sums, so the tree forwards the stacked
    contributions unchanged; permutation invariance (sorting) makes the
    tree result bit-identical to the flat reference.

``reduction`` is "sum" (partial sums up the tree) or "stack" (gather up the
tree).  ``stateful`` strategies (fedadam) thread server state through
``finalize``; on the host path the root aggregator publishes the state with
the global model (retained), so whichever client becomes next round's root
resumes it — MQTT retained-message sync doubling as optimizer-state
replication.
"""
from __future__ import annotations

from typing import Callable, Optional, Union


def _live_mask(weights, xp):
    """(alive bool mask, live count) for churn-aware masked combines."""
    alive = xp.asarray(weights) > 0
    return alive, xp.sum(alive.astype(xp.int32))


def _sort_dead_last(s, alive, xp):
    """Sort rows ascending with dead rows pushed behind a +big sentinel —
    the shared scaffolding of the masked robust combines (static shapes:
    works identically for numpy and traced jax)."""
    s = xp.asarray(s, xp.float32)
    amask = alive.reshape((s.shape[0],) + (1,) * (s.ndim - 1))
    return xp.sort(xp.where(amask, s, xp.float32(3.0e38)), axis=0)


def _tmap(fn, *trees):
    """Map over matching pytrees of dict/list/tuple containers.  Pure
    Python: the host MQTT path (flat numpy dicts) must not pay the jax
    import; the compiled path's nested param dicts map the same way."""
    t0 = trees[0]
    if isinstance(t0, dict):
        return {k: _tmap(fn, *(t[k] for t in trees)) for k in t0}
    if isinstance(t0, (list, tuple)):
        return type(t0)(_tmap(fn, *xs) for xs in zip(*trees))
    return fn(*trees)


class AggregationStrategy:
    """Base: plain weighted FedAvg semantics."""

    name = "fedavg"
    reduction = "sum"          # "sum" | "stack"
    compiled = True            # supported by the compiled collective path
    stateful = False
    needs_ref = False          # premap/finalize reads the previous global

    # -- sum-reduction hooks ------------------------------------------------
    def premap(self, params, ref, xp):
        """One client's raw model -> contribution (pre-weighting).  ``ref``
        is the previous global model (None on the first round)."""
        return params

    def finalize(self, mean, ref, state, xp):
        """Weighted mean -> (global, new_server_state)."""
        return mean, None

    # -- stack-reduction hook ----------------------------------------------
    def combine(self, stacked, weights, xp):
        """Client-stacked params (leading dim = n) + weights (n,) -> global."""
        raise NotImplementedError(f"{self.name} is not a stack strategy")

    def combine_masked(self, stacked, weights, xp):
        """Churn-aware variant used by the compiled collective path: rows
        whose weight is <= 0 (dead/vacant mesh slots) must not shift the
        statistic.  The default delegates to ``combine`` (correct for
        weighted sums, overridden by the robust stack strategies)."""
        return self.combine(stacked, weights, xp)

    # -- asynchronous-FL hook ----------------------------------------------
    def staleness_discount(self, staleness: int) -> float:
        """Weight multiplier for a contribution trained ``staleness`` global
        versions ago (bounded-staleness FedBuff buffers, repro.api.async_fl).
        The base semantics are *constant*: staleness does not change the
        weight — which keeps the async path bit-identical to the synchronous
        one when every contribution is fresh."""
        return 1.0

    def init_state(self, params):
        return None

    def describe(self) -> str:
        return (self.__doc__ or "").strip().split("\n")[0]


class FedAvg(AggregationStrategy):
    """Weighted federated averaging (McMahan et al.) — the paper's default."""


class FedProx(AggregationStrategy):
    """Proximal aggregation: each contribution is shrunk toward the previous
    global before averaging, damping client drift on non-IID data
    (aggregation-side analogue of the FedProx proximal term)."""

    name = "fedprox"
    needs_ref = True

    def __init__(self, mu: float = 0.1):
        assert 0.0 <= mu < 1.0, mu
        self.mu = float(mu)

    def premap(self, params, ref, xp):
        if ref is None:
            return params
        mu = self.mu
        return _tmap(lambda p, g: (1.0 - mu) * xp.asarray(p, xp.float32)
                     + mu * xp.asarray(g, xp.float32), params, ref)


class _PolyStaleness:
    """Mixin: polynomial staleness discount ``(1 + s) ** -a`` (Xie et al.,
    "Asynchronous Federated Optimization") for FedBuff-style buffers."""

    def __init__(self, a: float = 0.5, **kw):
        assert a >= 0.0, a
        self.staleness_a = float(a)
        super().__init__(**kw)

    def staleness_discount(self, staleness: int) -> float:
        return (1.0 + float(max(0, staleness))) ** (-self.staleness_a)


class FedAvgStaleness(_PolyStaleness, FedAvg):
    """FedAvg with polynomial staleness discounting: a contribution trained
    ``s`` global versions ago is admitted at weight ``w * (1+s)^-a``."""

    name = "fedavg_poly"


class FedProxStaleness(_PolyStaleness, FedProx):
    """FedProx proximal aggregation + polynomial staleness discounting."""

    name = "fedprox_poly"

    def __init__(self, a: float = 0.5, mu: float = 0.1):
        _PolyStaleness.__init__(self, a=a)
        FedProx.__init__(self, mu=mu)


class TrimmedMean(AggregationStrategy):
    """Byzantine-robust coordinate-wise trimmed mean: drop the k highest and
    k lowest values per coordinate (k = floor(beta * n)), average the rest.
    Ignores sample weights (standard for robust aggregation)."""

    name = "trimmed_mean"
    reduction = "stack"

    def __init__(self, beta: float = 0.2):
        assert 0.0 <= beta < 0.5, beta
        self.beta = float(beta)

    def combine(self, stacked, weights, xp):
        def one(s):
            n = s.shape[0]
            k = int(self.beta * n)
            if 2 * k >= n:
                k = (n - 1) // 2
            srt = xp.sort(xp.asarray(s, xp.float32), axis=0)
            if k:
                srt = srt[k:n - k]
            return xp.mean(srt, axis=0)
        return _tmap(one, stacked)

    def combine_masked(self, stacked, weights, xp):
        """Churn-aware trimmed mean with static shapes: dead rows (weight
        <= 0) are sorted to the top via a +big sentinel and the trim window
        ``[k, m-k)`` is computed over the *live* count ``m`` — so a departed
        client's stale row can never shift the statistic.  Reduces to
        ``combine`` when every row is live; all-dead yields zeros."""
        alive, m = _live_mask(weights, xp)

        def one(s):
            srt = _sort_dead_last(s, alive, xp)
            n = srt.shape[0]
            k = xp.floor(self.beta * m).astype(xp.int32)
            k = xp.maximum(xp.where(2 * k >= m, (m - 1) // 2, k), 0)
            idx = xp.arange(n).reshape((n,) + (1,) * (srt.ndim - 1))
            inc = (idx >= k) & (idx < m - k)
            cnt = xp.maximum(m - 2 * k, 1).astype(xp.float32)
            out = xp.sum(xp.where(inc, srt, xp.float32(0.0)), axis=0) / cnt
            return xp.where(m > 0, out, xp.zeros_like(out))
        return _tmap(one, stacked)


class CoordinateMedian(AggregationStrategy):
    """Byzantine-robust coordinate-wise median over all contributors."""

    name = "coordinate_median"
    reduction = "stack"

    def combine(self, stacked, weights, xp):
        return _tmap(lambda s: xp.median(xp.asarray(s, xp.float32), axis=0),
                     stacked)

    def combine_masked(self, stacked, weights, xp):
        """Churn-aware coordinate median: dead rows sort to the top behind a
        +big sentinel; the median indices are taken over the live count
        (all-dead yields zeros)."""
        alive, m = _live_mask(weights, xp)

        def one(s):
            srt = _sort_dead_last(s, alive, xp)
            lo = xp.take(srt, xp.maximum((m - 1) // 2, 0), axis=0)
            hi = xp.take(srt, m // 2, axis=0)
            # halve-then-add: two sentinel rows (all-dead) must not
            # overflow float32 before the m=0 guard zeroes them
            out = lo * xp.float32(0.5) + hi * xp.float32(0.5)
            return xp.where(m > 0, out, xp.zeros_like(out))
        return _tmap(one, stacked)


class FedAdam(AggregationStrategy):
    """Server-side Adam (Reddi et al., "Adaptive Federated Optimization"):
    the round's pseudo-gradient (weighted mean minus previous global) drives
    Adam moments kept at the aggregation root; state rides with the retained
    global-model publish so the root role can move between rounds."""

    name = "fedadam"
    stateful = True
    needs_ref = True
    compiled = False           # server state does not fit the pure-collective
                               # round step; host path + facade only

    def __init__(self, lr: float = 0.1, b1: float = 0.9, b2: float = 0.99,
                 eps: float = 1e-3):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps

    def finalize(self, mean, ref, state, xp):
        if ref is None:
            # first round: no pseudo-gradient yet; emit the mean, zero state
            zeros = _tmap(lambda v: xp.zeros_like(xp.asarray(v, xp.float64)),
                          mean)
            return mean, {"m": zeros, "v": _tmap(xp.copy, zeros), "t": 0}
        t = int(state["t"]) + 1 if state else 1
        m0 = state["m"] if state else _tmap(
            lambda v: xp.zeros_like(xp.asarray(v, xp.float64)), mean)
        v0 = state["v"] if state else _tmap(xp.copy, m0)
        delta = _tmap(lambda a, b: xp.asarray(a, xp.float64)
                      - xp.asarray(b, xp.float64), mean, ref)
        m = _tmap(lambda mm, d: self.b1 * mm + (1 - self.b1) * d, m0, delta)
        v = _tmap(lambda vv, d: self.b2 * vv + (1 - self.b2) * d * d,
                  v0, delta)
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t
        new = _tmap(
            lambda g, mm, vv: xp.asarray(g, xp.float64)
            + self.lr * (mm / bc1) / (xp.sqrt(vv / bc2) + self.eps),
            ref, m, v)
        return new, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], AggregationStrategy]] = {}


def register_strategy(name: str, factory: Callable[[], AggregationStrategy]):
    """Register a strategy factory under ``name`` (overwrites allowed so
    users can re-tune hyperparameters, e.g. a different fedprox mu).

    >>> from repro.api.strategies import (AggregationStrategy,
    ...                                   get_strategy, register_strategy)
    >>> class Halving(AggregationStrategy):
    ...     name = "halving"
    ...     def finalize(self, mean, ref, state, xp):
    ...         return {k: v / 2 for k, v in mean.items()}, state
    >>> _ = register_strategy("halving", Halving)
    >>> get_strategy("halving").name
    'halving'
    """
    _REGISTRY[name] = factory
    return factory


def get_strategy(s: Union[str, AggregationStrategy]) -> AggregationStrategy:
    """Resolve a name (or pass through an instance) from the registry.

    >>> from repro.api.strategies import get_strategy
    >>> get_strategy("fedavg").reduction           # decomposable: sums
    'sum'
    >>> get_strategy("trimmed_mean").reduction     # robust: full stacks
    'stack'
    >>> import numpy as np
    >>> mean = {"w": np.array([2.0, 4.0])}
    >>> new_global, state = get_strategy("fedavg").finalize(
    ...     mean, None, None, np)
    >>> new_global["w"]                            # fedavg: mean untouched
    array([2., 4.])
    >>> get_strategy("nope")                    # doctest: +ELLIPSIS
    Traceback (most recent call last):
        ...
    KeyError: "unknown aggregation strategy 'nope'; have [...]"
    """
    if isinstance(s, AggregationStrategy):
        return s
    try:
        return _REGISTRY[s]()
    except KeyError:
        raise KeyError(f"unknown aggregation strategy {s!r}; "
                       f"have {sorted(_REGISTRY)}") from None


def list_strategies() -> list[str]:
    """Registered strategy names, sorted.

    >>> from repro.api.strategies import list_strategies
    >>> {"fedavg", "fedprox", "trimmed_mean"} <= set(list_strategies())
    True
    """
    return sorted(_REGISTRY)


register_strategy("fedavg", FedAvg)
register_strategy("fedprox", FedProx)
register_strategy("fedavg_poly", FedAvgStaleness)
register_strategy("fedprox_poly", FedProxStaleness)
register_strategy("trimmed_mean", TrimmedMean)
register_strategy("coordinate_median", CoordinateMedian)
register_strategy("fedadam", FedAdam)
