"""Pluggable aggregation strategies — ONE implementation surface consumed by
both SDFLMQ data paths:

  * the host-side accumulator path (core/client.py): weighted partial sums /
    stacked contributions travel up the cluster tree over MQTT;
  * the compiled tree-collective path (core/aggregation.py): the same math
    runs as grouped psums / all-gathers under shard_map on the mesh.

A strategy is three small hooks over parameter pytrees, written against an
array namespace ``xp`` (numpy on the host path, jax.numpy when compiled):

  * ``premap(params, ref, xp)``       — transform one client's raw model
    before weighting/summation (fedprox mixes toward the previous global).
    Applied exactly once, at the leaf; partial sums are never re-premapped.
  * ``finalize(mean, ref, state, xp)``— turn the weighted mean into the new
    global (+ new server state).  fedavg returns the mean untouched, so the
    fedavg fast path is bit-identical to plain weighted averaging.
  * ``combine(stacked, weights, xp)`` — for ``reduction == "stack"``
    strategies (trimmed mean, coordinate median): full client-stacked
    parameters (leading dim = contributors) -> global.  These are not
    decomposable into partial sums, so the tree forwards the stacked
    contributions unchanged; permutation invariance (sorting) makes the
    tree result bit-identical to the flat reference.

``reduction`` is "sum" (partial sums up the tree) or "stack" (gather up the
tree).  ``stateful`` strategies (fedadam) thread server state through
``finalize``; on the host path the root aggregator publishes the state with
the global model (retained), so whichever client becomes next round's root
resumes it — MQTT retained-message sync doubling as optimizer-state
replication.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np


def _live_mask(weights, xp):
    """(alive bool mask, live count) for churn-aware masked combines."""
    alive = xp.asarray(weights) > 0
    return alive, xp.sum(alive.astype(xp.int32))


def _sort_dead_last(s, alive, xp):
    """Sort rows ascending with dead rows pushed behind a +big sentinel —
    the shared scaffolding of the masked robust combines (static shapes:
    works identically for numpy and traced jax)."""
    s = xp.asarray(s, xp.float32)
    amask = alive.reshape((s.shape[0],) + (1,) * (s.ndim - 1))
    return xp.sort(xp.where(amask, s, xp.float32(3.0e38)), axis=0)


def _flat_sq_norm(params, xp):
    """Total squared L2 norm over a whole params pytree (scalar)."""
    total = None

    def add(v):
        nonlocal total
        v = xp.asarray(v, xp.float32)
        sq = xp.sum(v * v)
        total = sq if total is None else total + sq
        return v
    _tmap(add, params)
    return total if total is not None else xp.float32(0.0)


def _tmap(fn, *trees):
    """Map over matching pytrees of dict/list/tuple containers.  Pure
    Python: the host MQTT path (flat numpy dicts) must not pay the jax
    import; the compiled path's nested param dicts map the same way."""
    t0 = trees[0]
    if isinstance(t0, dict):
        return {k: _tmap(fn, *(t[k] for t in trees)) for k in t0}
    if isinstance(t0, (list, tuple)):
        return type(t0)(_tmap(fn, *xs) for xs in zip(*trees))
    return fn(*trees)


class AggregationStrategy:
    """Base: plain weighted FedAvg semantics."""

    name = "fedavg"
    reduction = "sum"          # "sum" | "stack"
    compiled = True            # supported by the compiled collective path
    stateful = False
    needs_ref = False          # premap/finalize reads the previous global

    # -- sum-reduction hooks ------------------------------------------------
    def premap(self, params, ref, xp):
        """One client's raw model -> contribution (pre-weighting).  ``ref``
        is the previous global model (None on the first round)."""
        return params

    def finalize(self, mean, ref, state, xp):
        """Weighted mean -> (global, new_server_state)."""
        return mean, None

    # -- stack-reduction hook ----------------------------------------------
    def combine(self, stacked, weights, xp):
        """Client-stacked params (leading dim = n) + weights (n,) -> global."""
        raise NotImplementedError(f"{self.name} is not a stack strategy")

    def combine_masked(self, stacked, weights, xp):
        """Churn-aware variant used by the compiled collective path: rows
        whose weight is <= 0 (dead/vacant mesh slots) must not shift the
        statistic.  The default delegates to ``combine`` (correct for
        weighted sums, overridden by the robust stack strategies)."""
        return self.combine(stacked, weights, xp)

    # -- asynchronous-FL hook ----------------------------------------------
    def staleness_discount(self, staleness: int) -> float:
        """Weight multiplier for a contribution trained ``staleness`` global
        versions ago (bounded-staleness FedBuff buffers, repro.api.async_fl).
        The base semantics are *constant*: staleness does not change the
        weight — which keeps the async path bit-identical to the synchronous
        one when every contribution is fresh."""
        return 1.0

    def init_state(self, params):
        return None

    def describe(self) -> str:
        return (self.__doc__ or "").strip().split("\n")[0]


class FedAvg(AggregationStrategy):
    """Weighted federated averaging (McMahan et al.) — the paper's default."""


class FedProx(AggregationStrategy):
    """Proximal aggregation: each contribution is shrunk toward the previous
    global before averaging, damping client drift on non-IID data
    (aggregation-side analogue of the FedProx proximal term)."""

    name = "fedprox"
    needs_ref = True

    def __init__(self, mu: float = 0.1):
        assert 0.0 <= mu < 1.0, mu
        self.mu = float(mu)

    def premap(self, params, ref, xp):
        if ref is None:
            return params
        mu = self.mu
        return _tmap(lambda p, g: (1.0 - mu) * xp.asarray(p, xp.float32)
                     + mu * xp.asarray(g, xp.float32), params, ref)


class _PolyStaleness:
    """Mixin: polynomial staleness discount ``(1 + s) ** -a`` (Xie et al.,
    "Asynchronous Federated Optimization") for FedBuff-style buffers."""

    def __init__(self, a: float = 0.5, **kw):
        assert a >= 0.0, a
        self.staleness_a = float(a)
        super().__init__(**kw)

    def staleness_discount(self, staleness: int) -> float:
        return (1.0 + float(max(0, staleness))) ** (-self.staleness_a)


class FedAvgStaleness(_PolyStaleness, FedAvg):
    """FedAvg with polynomial staleness discounting: a contribution trained
    ``s`` global versions ago is admitted at weight ``w * (1+s)^-a``."""

    name = "fedavg_poly"


class FedProxStaleness(_PolyStaleness, FedProx):
    """FedProx proximal aggregation + polynomial staleness discounting."""

    name = "fedprox_poly"

    def __init__(self, a: float = 0.5, mu: float = 0.1):
        _PolyStaleness.__init__(self, a=a)
        FedProx.__init__(self, mu=mu)


class TrimmedMean(AggregationStrategy):
    """Byzantine-robust coordinate-wise trimmed mean: drop the k highest and
    k lowest values per coordinate (k = floor(beta * n)), average the rest.
    Ignores sample weights (standard for robust aggregation).

    ``beta`` is validated again at combine time against the *live* cohort:
    when ``2 * ceil(beta * n) >= n_live`` the requested trim would devour
    the whole cohort (tiny or heavily churned rounds), so the trim is
    clamped to the largest feasible ``k = (n_live - 1) // 2`` and the
    degeneration is counted in :attr:`trim_clamped` instead of silently
    producing a garbage mean.  (The counter is maintained on the host
    numpy path; under a jax trace the clamp applies but cannot count.)"""

    name = "trimmed_mean"
    reduction = "stack"

    def __init__(self, beta: float = 0.2):
        assert 0.0 <= beta < 0.5, beta
        self.beta = float(beta)
        #: times the requested trim degenerated and was clamped
        self.trim_clamped = 0

    def _note_clamp(self, n_live: int) -> None:
        import math
        if n_live >= 1 and 2 * math.ceil(self.beta * n_live) >= n_live:
            self.trim_clamped += 1

    def combine(self, stacked, weights, xp):
        counted = []                   # count once per combine, not per leaf

        def one(s):
            n = s.shape[0]
            if xp is np and not counted:
                counted.append(True)
                self._note_clamp(int(n))
            k = int(self.beta * n)
            if 2 * k >= n:
                k = (n - 1) // 2
            srt = xp.sort(xp.asarray(s, xp.float32), axis=0)
            if k:
                srt = srt[k:n - k]
            return xp.mean(srt, axis=0)
        return _tmap(one, stacked)

    def combine_masked(self, stacked, weights, xp):
        """Churn-aware trimmed mean with static shapes: dead rows (weight
        <= 0) are sorted to the top via a +big sentinel and the trim window
        ``[k, m-k)`` is computed over the *live* count ``m`` — so a departed
        client's stale row can never shift the statistic.  Reduces to
        ``combine`` when every row is live; all-dead yields zeros.  A trim
        that would degenerate on the live count is clamped (and counted on
        the host path, see :attr:`trim_clamped`)."""
        alive, m = _live_mask(weights, xp)
        if xp is np:
            self._note_clamp(int(m))

        def one(s):
            srt = _sort_dead_last(s, alive, xp)
            n = srt.shape[0]
            k = xp.floor(self.beta * m).astype(xp.int32)
            k = xp.maximum(xp.where(2 * k >= m, (m - 1) // 2, k), 0)
            idx = xp.arange(n).reshape((n,) + (1,) * (srt.ndim - 1))
            inc = (idx >= k) & (idx < m - k)
            cnt = xp.maximum(m - 2 * k, 1).astype(xp.float32)
            out = xp.sum(xp.where(inc, srt, xp.float32(0.0)), axis=0) / cnt
            return xp.where(m > 0, out, xp.zeros_like(out))
        return _tmap(one, stacked)


class CoordinateMedian(AggregationStrategy):
    """Byzantine-robust coordinate-wise median over all contributors."""

    name = "coordinate_median"
    reduction = "stack"

    def combine(self, stacked, weights, xp):
        return _tmap(lambda s: xp.median(xp.asarray(s, xp.float32), axis=0),
                     stacked)

    def combine_masked(self, stacked, weights, xp):
        """Churn-aware coordinate median: dead rows sort to the top behind a
        +big sentinel; the median indices are taken over the live count
        (all-dead yields zeros)."""
        alive, m = _live_mask(weights, xp)

        def one(s):
            srt = _sort_dead_last(s, alive, xp)
            lo = xp.take(srt, xp.maximum((m - 1) // 2, 0), axis=0)
            hi = xp.take(srt, m // 2, axis=0)
            # halve-then-add: two sentinel rows (all-dead) must not
            # overflow float32 before the m=0 guard zeroes them
            out = lo * xp.float32(0.5) + hi * xp.float32(0.5)
            return xp.where(m > 0, out, xp.zeros_like(out))
        return _tmap(one, stacked)


class _NormClip:
    """Mixin: norm-clipping premap (defense).  Each contribution's *update*
    (its delta from the previous global) is rescaled so its flat L2 norm
    never exceeds ``clip`` — a scaling/model-poisoning attacker can then
    inflate its update by at most ``clip / typical_norm`` no matter how
    large a λ it multiplies in.  Applied once at the leaf on both data
    paths (host MQTT aggregators and the compiled shard_map stack path).
    With no previous global yet (round 0) there is no update to measure,
    so the premap is the identity."""

    needs_ref = True

    def __init__(self, clip: float = 10.0, **kw):
        assert clip > 0.0, clip
        self.clip = float(clip)
        super().__init__(**kw)

    def premap(self, params, ref, xp):
        if ref is None:
            return params
        delta = _tmap(lambda p, g: xp.asarray(p, xp.float32)
                      - xp.asarray(g, xp.float32), params, ref)
        nrm = xp.sqrt(_flat_sq_norm(delta, xp))
        scale = xp.minimum(xp.float32(1.0),
                           self.clip / xp.maximum(nrm, xp.float32(1e-12)))
        return _tmap(lambda g, d: xp.asarray(g, xp.float32) + d * scale,
                     ref, delta)


class NormClipFedAvg(_NormClip, FedAvg):
    """FedAvg with norm-clipped updates: plain weighted averaging, but no
    single contribution can pull the mean further than ``clip`` (defends
    against update-scaling poisoning while keeping fedavg semantics for
    honest, small updates)."""

    name = "norm_clip"


def _weighted_value_sort(s, w, alive, xp):
    """Per-coordinate value sort carrying each row's weight along.  Dead
    rows (``alive`` False) are pushed behind a +big sentinel so zero-mass
    garbage can never sit inside a trim/median window.  Returns
    ``(vsorted, wsorted)`` of the same shape as ``s``."""
    s = xp.asarray(s, xp.float32)
    n = s.shape[0]
    amask = alive.reshape((n,) + (1,) * (s.ndim - 1))
    s = xp.where(amask, s, xp.float32(3.0e38))
    w = xp.where(alive, xp.asarray(w, xp.float32), xp.float32(0.0))
    order = xp.argsort(s, axis=0)
    vsorted = xp.take_along_axis(s, order, axis=0)
    wfull = xp.broadcast_to(w.reshape((n,) + (1,) * (s.ndim - 1)), s.shape)
    wsorted = xp.take_along_axis(wfull, order, axis=0)
    return vsorted, wsorted


class WeightedTrimmedMean(AggregationStrategy):
    """Weight-aware Byzantine-robust trimmed mean: per coordinate, sort the
    values and discard ``beta`` of the total *weight mass* from each end,
    then take the weighted average of the surviving mass (a boundary value
    keeps only the slice of its weight inside the window).  Unlike
    :class:`TrimmedMean` this honors FedAvg sample weights — and
    reputation-scaled weights: a client demoted to near-zero weight simply
    carries no mass.  Inherently churn-aware: rows with weight <= 0
    contribute nothing, so ``combine_masked`` and ``combine`` coincide."""

    name = "weighted_trimmed_mean"
    reduction = "stack"

    def __init__(self, beta: float = 0.2):
        assert 0.0 <= beta < 0.5, beta
        self.beta = float(beta)

    def combine(self, stacked, weights, xp):
        return self.combine_masked(stacked, weights, xp)

    def combine_masked(self, stacked, weights, xp):
        alive, m = _live_mask(weights, xp)
        beta = xp.float32(self.beta)

        def one(s):
            vsorted, wsorted = _weighted_value_sort(s, weights, alive, xp)
            cum = xp.cumsum(wsorted, axis=0)
            total = xp.sum(wsorted, axis=0, keepdims=True)
            lo, hi = beta * total, (xp.float32(1.0) - beta) * total
            # effective weight = the slice of each row's mass that falls
            # inside [beta*W, (1-beta)*W] of the cumulative distribution
            eff = xp.clip(xp.minimum(cum, hi)
                          - xp.maximum(cum - wsorted, lo), 0.0, None)
            denom = xp.sum(eff, axis=0)
            out = xp.sum(vsorted * eff, axis=0) \
                / xp.maximum(denom, xp.float32(1e-30))
            return xp.where(denom > 0, out, xp.zeros_like(out))
        return _tmap(one, stacked)


class WeightedMedian(AggregationStrategy):
    """Weight-aware coordinate-wise median: the 50%-of-total-mass point of
    the weight-cumulative value distribution (average of the lower and
    upper crossing values, reducing to :class:`CoordinateMedian` under
    equal weights).  Weight-zero (dead) rows carry no mass, so the combine
    is inherently churn-aware."""

    name = "weighted_median"
    reduction = "stack"

    def combine(self, stacked, weights, xp):
        return self.combine_masked(stacked, weights, xp)

    def combine_masked(self, stacked, weights, xp):
        alive, m = _live_mask(weights, xp)

        def one(s):
            vsorted, wsorted = _weighted_value_sort(s, weights, alive, xp)
            cum = xp.cumsum(wsorted, axis=0)
            total = xp.sum(wsorted, axis=0, keepdims=True)
            half = xp.float32(0.5) * total
            # first crossing >= half (lower median) / > half (upper median);
            # argmax over bool finds the first True per coordinate
            lo_i = xp.argmax(cum >= half, axis=0)
            hi_i = xp.argmax(cum > half, axis=0)
            lo = xp.take_along_axis(vsorted, lo_i[None], axis=0)[0]
            hi = xp.take_along_axis(vsorted, hi_i[None], axis=0)[0]
            out = lo * xp.float32(0.5) + hi * xp.float32(0.5)
            return xp.where(total[0] > 0, out, xp.zeros_like(out))
        return _tmap(one, stacked)


class MultiKrum(AggregationStrategy):
    """Multi-Krum (Blanchard et al., "Machine Learning with Adversaries"):
    score every contribution by its summed squared distance to its
    ``n_live - f - 2`` closest peers (flat, across all tensors), select the
    ``m`` best-scored rows and average them — geometric outliers (poisoned
    or scaled updates) score badly and are excluded entirely, unlike
    coordinate-wise trims.  Tolerates up to ``f`` Byzantine rows when
    ``n_live >= 2f + 3``; smaller live cohorts degrade gracefully (the
    neighbor count clamps at 1).  Selection ignores sample weights (rows
    with weight <= 0 are dead: excluded from distances and never
    selected); the selected rows are averaged unweighted, per the paper."""

    name = "multi_krum"
    reduction = "stack"

    def __init__(self, m: int = 3, f: int = 1):
        assert m >= 1 and f >= 0, (m, f)
        self.m_sel = int(m)
        self.f = int(f)

    def combine(self, stacked, weights, xp):
        return self.combine_masked(stacked, weights, xp)

    def combine_masked(self, stacked, weights, xp):
        alive, m_live = _live_mask(weights, xp)
        flats = []

        def grab(v):
            v = xp.asarray(v, xp.float32)
            flats.append(v.reshape((v.shape[0], -1)))
            return v
        _tmap(grab, stacked)
        X = xp.concatenate(flats, axis=1)          # (n, D) flat rows
        n = X.shape[0]
        sq = xp.sum(X * X, axis=1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (X @ X.T)
        BIG = xp.float32(1e30)
        dead = ~alive
        d2 = xp.where(dead[:, None] | dead[None, :], BIG, d2)
        d2 = d2 + BIG * xp.eye(n, dtype=xp.float32)      # exclude self
        dsort = xp.sort(d2, axis=1)
        kc = xp.clip(m_live - self.f - 2, 1, max(n - 1, 1))
        idx = xp.arange(n)[None, :]
        scores = xp.sum(xp.where(idx < kc, dsort, xp.float32(0.0)), axis=1)
        scores = xp.where(dead, xp.float32(xp.inf), scores)
        ranks = xp.argsort(xp.argsort(scores))     # rank of each row
        q = xp.clip(xp.minimum(m_live, self.m_sel), 1, n)
        sel = ranks < q                            # exactly q best rows
        qf = xp.maximum(xp.sum(sel.astype(xp.float32)), xp.float32(1.0))

        def one(s):
            s = xp.asarray(s, xp.float32)
            smask = sel.reshape((n,) + (1,) * (s.ndim - 1))
            out = xp.sum(xp.where(smask, s, xp.float32(0.0)), axis=0) / qf
            return xp.where(m_live > 0, out, xp.zeros_like(out))
        return _tmap(one, stacked)


class Krum(MultiKrum):
    """Krum: Multi-Krum with m=1 — emit the single best-scored contribution
    (strongest Byzantine resistance, highest variance)."""

    name = "krum"

    def __init__(self, f: int = 1):
        super().__init__(m=1, f=f)


class ClippedWeightedTrimmedMean(_NormClip, WeightedTrimmedMean):
    """Norm-clipped weighted trimmed mean: updates are norm-clipped at the
    leaf (bounding any single λ-scaled poison), then combined with the
    weight-mass trim — the belt-and-suspenders defense of the adversarial
    test wall."""

    name = "clipped_weighted_trimmed_mean"

    def __init__(self, beta: float = 0.2, clip: float = 10.0):
        _NormClip.__init__(self, clip=clip)
        WeightedTrimmedMean.__init__(self, beta=beta)


class FedAdam(AggregationStrategy):
    """Server-side Adam (Reddi et al., "Adaptive Federated Optimization"):
    the round's pseudo-gradient (weighted mean minus previous global) drives
    Adam moments kept at the aggregation root; state rides with the retained
    global-model publish so the root role can move between rounds."""

    name = "fedadam"
    stateful = True
    needs_ref = True
    compiled = False           # server state does not fit the pure-collective
                               # round step; host path + facade only

    def __init__(self, lr: float = 0.1, b1: float = 0.9, b2: float = 0.99,
                 eps: float = 1e-3):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps

    def finalize(self, mean, ref, state, xp):
        if ref is None:
            # first round: no pseudo-gradient yet; emit the mean, zero state
            zeros = _tmap(lambda v: xp.zeros_like(xp.asarray(v, xp.float64)),
                          mean)
            return mean, {"m": zeros, "v": _tmap(xp.copy, zeros), "t": 0}
        t = int(state["t"]) + 1 if state else 1
        m0 = state["m"] if state else _tmap(
            lambda v: xp.zeros_like(xp.asarray(v, xp.float64)), mean)
        v0 = state["v"] if state else _tmap(xp.copy, m0)
        delta = _tmap(lambda a, b: xp.asarray(a, xp.float64)
                      - xp.asarray(b, xp.float64), mean, ref)
        m = _tmap(lambda mm, d: self.b1 * mm + (1 - self.b1) * d, m0, delta)
        v = _tmap(lambda vv, d: self.b2 * vv + (1 - self.b2) * d * d,
                  v0, delta)
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t
        new = _tmap(
            lambda g, mm, vv: xp.asarray(g, xp.float64)
            + self.lr * (mm / bc1) / (xp.sqrt(vv / bc2) + self.eps),
            ref, m, v)
        return new, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], AggregationStrategy]] = {}


def register_strategy(name: str, factory: Callable[[], AggregationStrategy]):
    """Register a strategy factory under ``name`` (overwrites allowed so
    users can re-tune hyperparameters, e.g. a different fedprox mu).

    >>> from repro.api.strategies import (AggregationStrategy,
    ...                                   get_strategy, register_strategy)
    >>> class Halving(AggregationStrategy):
    ...     name = "halving"
    ...     def finalize(self, mean, ref, state, xp):
    ...         return {k: v / 2 for k, v in mean.items()}, state
    >>> _ = register_strategy("halving", Halving)
    >>> get_strategy("halving").name
    'halving'
    """
    _REGISTRY[name] = factory
    return factory


def get_strategy(s: Union[str, AggregationStrategy]) -> AggregationStrategy:
    """Resolve a name (or pass through an instance) from the registry.

    >>> from repro.api.strategies import get_strategy
    >>> get_strategy("fedavg").reduction           # decomposable: sums
    'sum'
    >>> get_strategy("trimmed_mean").reduction     # robust: full stacks
    'stack'
    >>> import numpy as np
    >>> mean = {"w": np.array([2.0, 4.0])}
    >>> new_global, state = get_strategy("fedavg").finalize(
    ...     mean, None, None, np)
    >>> new_global["w"]                            # fedavg: mean untouched
    array([2., 4.])
    >>> get_strategy("nope")                    # doctest: +ELLIPSIS
    Traceback (most recent call last):
        ...
    KeyError: "unknown aggregation strategy 'nope'; have [...]"
    """
    if isinstance(s, AggregationStrategy):
        return s
    try:
        return _REGISTRY[s]()
    except KeyError:
        raise KeyError(f"unknown aggregation strategy {s!r}; "
                       f"have {sorted(_REGISTRY)}") from None


def list_strategies() -> list[str]:
    """Registered strategy names, sorted.

    >>> from repro.api.strategies import list_strategies
    >>> {"fedavg", "fedprox", "trimmed_mean"} <= set(list_strategies())
    True
    """
    return sorted(_REGISTRY)


register_strategy("fedavg", FedAvg)
register_strategy("fedprox", FedProx)
register_strategy("fedavg_poly", FedAvgStaleness)
register_strategy("fedprox_poly", FedProxStaleness)
register_strategy("trimmed_mean", TrimmedMean)
register_strategy("coordinate_median", CoordinateMedian)
register_strategy("fedadam", FedAdam)
register_strategy("norm_clip", NormClipFedAvg)
register_strategy("weighted_trimmed_mean", WeightedTrimmedMean)
register_strategy("weighted_median", WeightedMedian)
register_strategy("krum", Krum)
register_strategy("multi_krum", MultiKrum)
register_strategy("clipped_weighted_trimmed_mean", ClippedWeightedTrimmedMean)
