"""Real-MQTT implementation of the ``repro.api.transport.Transport`` protocol.

:class:`PahoTransport` runs the federation's control and model planes over
an actual MQTT 3.1.1 broker — the bundled
:class:`repro.api.mini_broker.MiniBroker`, a local Mosquitto, or a managed
EMQX/HiveMQ endpoint — while ``Federation`` / ``AsyncFederatedSession``
run unchanged on top.  Three pieces make that possible:

**A connection pool, one MQTT connection per logical client id.**
``connect(client_id, ...)`` opens a dedicated broker connection (so LWT,
per-sender FIFO ordering, and per-client subscriptions behave exactly as
they do against ``SimBroker``), and ``publish(..., sender=cid)`` rides that
client's connection.  The underlying MQTT client is `paho-mqtt
<https://pypi.org/project/paho-mqtt/>`_ when the ``repro[mqtt]`` extra is
installed, with a bundled pure-stdlib fallback (``backend="builtin"``)
that speaks the same MQTT 3.1.1 subset — CI and air-gapped machines need
no wheel to exercise the real-network path.

**A background-thread → SimClock-safe delivery bridge.**  Network threads
never call application handlers.  Inbound PUBLISHes land in a thread-safe
inbox; ``settle()`` (or the clock source installed by ``attach_clock``)
dispatches them on the caller's thread, so every coordinator/client
callback runs exactly where SimBroker would have run it.  A
``clock.run_until_idle()`` — the facade's "drain everything" primitive —
transparently includes real network traffic.

**A flush-barrier quiescence protocol.**  "Drained" against a real broker
means *no message is in flight anywhere*, which a timed sleep can only
approximate.  Every connection subscribes to a private marker topic
(``$flush/<client id>`` by default — a ``$``-topic, so application
wildcard subscriptions never see it [MQTT-4.7.2-1]).  A barrier round
publishes a marker on **every** connection and waits for each echo; MQTT's
per-connection FIFO guarantees the broker has routed everything published
before the marker, and anything routed concurrently is observably on some
socket by the *next* round.  Two consecutive barrier rounds that dispatch
nothing therefore prove quiescence — deterministically, with no
timing-dependent grace window.  Brokers that reject ``$``-topic publishes
(some managed deployments) are detected — a barrier timeout before any
echo was ever observed — and the transport degrades to a timed-grace
settle; a timeout after echoes have worked is treated as transient and
the barrier retried.

Example (hermetic, against the bundled mini-broker)::

    from repro.api import Federation
    from repro.api.mini_broker import MiniBroker
    from repro.api.mqtt_transport import PahoTransport

    broker = MiniBroker(port=0).start()
    fed = Federation(transport=PahoTransport(port=broker.port))
    ...                       # identical Federation code from here on
    fed.close()
    broker.stop()

What does *not* transfer from the simulators: ``LatencyTransport``'s
partition/drop modeling applies to *outbound* publishes only (inbound
frames arrive from a real socket and are delivered as-is), and multi-part
retained payloads replay only their final part to late subscribers — size
retained topics under ``max_batch_bytes`` (see ``docs/deployment.md``).
"""
from __future__ import annotations

import queue
import random
import socket
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Optional

from repro.api.mini_broker import (CONNACK, CONNECT, DISCONNECT, PINGREQ,
                                   PUBACK, PUBLISH, SUBACK, SUBSCRIBE,
                                   UNSUBACK, UNSUBSCRIBE, ProtocolError,
                                   _Cursor, encode_utf8, packet,
                                   publish_packet)
from repro.core.broker import Message

try:                                    # optional extra: repro[mqtt]
    import paho.mqtt.client as _paho
except Exception:                       # pragma: no cover - env dependent
    _paho = None


def paho_available() -> bool:
    """Whether the optional ``paho-mqtt`` wheel is importable."""
    return _paho is not None


# ---------------------------------------------------------------------------
# MQTT client backends: one socket, one reader thread, same tiny surface
# ---------------------------------------------------------------------------

_INFLIGHT_LIMIT = 2048          # unacked QoS-1 publishes kept for retransmit


class _BuiltinClient:
    """Bundled MQTT 3.1.1 client (stdlib only): blocking writes under a
    lock, a reader thread that parses inbound packets and forwards
    PUBLISHes to ``on_message(topic, payload, qos, retain, dup)``.
    SUBSCRIBE / UNSUBSCRIBE block until the broker acks, so a subscription
    is live (broker-side) when the call returns — matching SimBroker's
    synchronous semantics.

    At-least-once sending: every QoS-1 publish enters an in-flight window
    (ordered by send) and leaves it on PUBACK; ``reconnect()`` re-dials,
    resumes or rebuilds the session (re-SUBSCRIBE when the broker reports
    no stored session), and retransmits the window with the DUP flag —
    same packet ids, original order, so per-sender FIFO survives the
    outage."""

    def __init__(self, client_id: str):
        self.client_id = client_id
        self.on_message: Callable = lambda *a: None
        # fired from the dying reader thread on an UNEXPECTED connection
        # loss (never on a deliberate disconnect) — the transport's
        # reconnect machinery hangs off this
        self.on_disconnect_cb: Optional[Callable] = None
        self._sock: Optional[socket.socket] = None
        self._rfile = None
        self._wlock = threading.Lock()
        # mid allocation, the ack table, and the in-flight window are
        # shared with the reader thread and with concurrent app/timer
        # threads — all mutations go through _mid_lock
        self._mid_lock = threading.Lock()
        self._mid = 0
        self._acks: dict[int, threading.Event] = {}
        self._inflight: "OrderedDict[int, tuple]" = OrderedDict()
        self._subs: dict[str, int] = {}       # filter -> qos (for resume)
        self._reader: Optional[threading.Thread] = None
        self._reader_dead = False
        self._pinger: Optional[threading.Thread] = None
        self._stop_ping = threading.Event()
        self._closing = False
        self.session_present = False
        self.dropped_sends = 0
        self.retransmits = 0

    # ---- connection -----------------------------------------------------
    def connect(self, host: str, port: int, will=None,
                keepalive: int = 0, timeout: float = 10.0,
                clean_session: bool = True) -> None:
        self._host, self._port, self._will = host, port, will
        self._keepalive, self._timeout = keepalive, timeout
        self._clean_session = clean_session
        self._dial()

    def _dial(self) -> None:
        self._sock = socket.create_connection((self._host, self._port),
                                              timeout=self._timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._rfile = self._sock.makefile("rb")
        flags = 0x02 if self._clean_session else 0x00
        body = encode_utf8("MQTT") + bytes((4,))
        tail = encode_utf8(self.client_id)
        will = self._will
        if will is not None:
            flags |= 0x04 | ((will.qos & 0x03) << 3) \
                | (0x20 if getattr(will, "retain", False) else 0)
            payload = bytes(will.payload)
            tail += encode_utf8(will.topic)
            tail += len(payload).to_bytes(2, "big") + payload
        body += bytes((flags,)) + self._keepalive.to_bytes(2, "big") + tail
        self._send(packet(CONNECT, 0, body))
        ptype, _, ack = self._read_packet()
        if ptype != CONNACK or ack[1] != 0:
            raise ConnectionError(f"CONNECT refused: {ack!r}")
        self.session_present = bool(ack[0] & 0x01)
        self._sock.settimeout(None)
        self._reader_dead = False
        self._reader = threading.Thread(target=self._read_loop,
                                        name=f"mqtt-{self.client_id}",
                                        daemon=True)
        self._reader.start()
        if self._keepalive > 0:
            # the CONNECT advertised a keepalive: a spec-compliant broker
            # drops the connection (and fires the LWT) after 1.5x that
            # interval of silence, so honor it with a PINGREQ heartbeat
            self._pinger = threading.Thread(
                target=self._ping_loop, args=(self._keepalive / 2.0,),
                name=f"mqtt-ping-{self.client_id}", daemon=True)
            self._pinger.start()

    @property
    def connected(self) -> bool:
        return (self._sock is not None and not self._reader_dead
                and not self._closing)

    def reconnect(self, retransmit: bool = True) -> bool:
        """One reconnect attempt.  On success the session is live again:
        subscriptions re-established when the broker kept no state (the
        SUBACK round-trip completes before this returns), and — unless the
        caller defers it — the QoS-1 in-flight window retransmitted (DUP,
        same packet ids, send order).  Returns ``False`` on any failure —
        caller backs off."""
        if self._closing:
            return False
        self._stop_ping.set()               # orphan the old ping thread
        self._stop_ping = threading.Event()
        with self._mid_lock:
            # stale SUBACK waiters were woken by the dying reader; their
            # mids must not capture acks of the new session
            self._acks.clear()
        try:
            self._dial()
            if not self.session_present:
                for filt, q in list(self._subs.items()):
                    self.subscribe(filt, qos=q)
            if retransmit:
                self.retransmit_inflight()
            return True
        except (ConnectionError, OSError, TimeoutError, ProtocolError):
            return False

    def retransmit_inflight(self) -> None:
        """Replay every unacked QoS-1 publish (DUP, original packet ids,
        send order).  A send failure leaves the rest in the window — the
        next reconnect replays them again."""
        with self._mid_lock:
            pending = list(self._inflight.items())
        for mid, (topic, payload, q, retain) in pending:
            self.retransmits += 1
            try:
                self._send(publish_packet(topic, payload, q, retain, mid,
                                          dup=True))
            except (ConnectionError, OSError):
                return

    def _ping_loop(self, interval: float) -> None:
        while not self._stop_ping.wait(interval):
            try:
                self._send(packet(PINGREQ, 0))
            except (ConnectionError, OSError):
                return

    def disconnect(self, graceful: bool = True) -> None:
        """Graceful sends DISCONNECT (no LWT); abrupt just kills the socket
        — the broker observes a network failure and fires the LWT."""
        self._closing = True
        self._stop_ping.set()
        sock, self._sock = self._sock, None
        if sock is None:
            return
        try:
            if graceful:
                with self._wlock:
                    sock.sendall(packet(DISCONNECT, 0))
        except OSError:
            pass
        try:
            sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        sock.close()
        if self._reader is not None and \
                self._reader is not threading.current_thread():
            self._reader.join(timeout=5.0)

    # ---- MQTT ops -------------------------------------------------------
    def subscribe(self, topic_filter: str, qos: int = 0,
                  timeout: float = 10.0) -> None:
        # cached first: an offline subscribe is re-established on reconnect
        self._subs[topic_filter] = qos
        mid, ev = self._next_mid()
        body = mid.to_bytes(2, "big") + encode_utf8(topic_filter) \
            + bytes((qos & 0x03,))
        self._send(packet(SUBSCRIBE, 0x02, body))
        if not ev.wait(timeout):
            raise TimeoutError(f"SUBACK timeout for {topic_filter!r}")
        self._check_alive(f"SUBSCRIBE {topic_filter!r}")

    def unsubscribe(self, topic_filter: str, timeout: float = 10.0) -> None:
        self._subs.pop(topic_filter, None)
        mid, ev = self._next_mid()
        self._send(packet(UNSUBSCRIBE, 0x02,
                          mid.to_bytes(2, "big") + encode_utf8(topic_filter)))
        if not ev.wait(timeout):
            raise TimeoutError(f"UNSUBACK timeout for {topic_filter!r}")
        self._check_alive(f"UNSUBSCRIBE {topic_filter!r}")

    def _check_alive(self, what: str) -> None:
        # the reader's death wakes every ack waiter so nothing hangs; a
        # waiter woken that way must fail, not report a phantom ack
        if self._reader_dead and not self._closing:
            raise ConnectionError(
                f"{self.client_id}: connection lost during {what}")

    def publish(self, topic: str, payload: bytes, qos: int = 0,
                retain: bool = False) -> None:
        payload = bytes(payload)
        qos = min(qos, 1)
        mid = 0
        if qos > 0:
            with self._mid_lock:
                mid = self._next_mid_locked()
                # window entry BEFORE the send: a socket death mid-write
                # still leaves the frame eligible for retransmit
                self._inflight[mid] = (topic, payload, qos, retain)
                while len(self._inflight) > _INFLIGHT_LIMIT:
                    self._inflight.popitem(last=False)
                    self.dropped_sends += 1
        try:
            self._send(publish_packet(topic, payload, qos, retain, mid))
        except (ConnectionError, OSError):
            if qos == 0:
                self.dropped_sends += 1   # fire-and-forget: legitimately lost
                raise
            # QoS 1 while offline: stays in the window, goes out on reconnect

    # ---- internals ------------------------------------------------------
    def _next_mid_locked(self) -> int:
        # caller holds _mid_lock; skip ids still owned by an unacked
        # publish or a pending SUB/UNSUB ack
        while True:
            self._mid = (self._mid % 0xFFFF) + 1
            if self._mid not in self._inflight and self._mid not in self._acks:
                return self._mid

    def _next_mid(self) -> tuple[int, threading.Event]:
        with self._mid_lock:
            mid = self._next_mid_locked()
            ev = self._acks[mid] = threading.Event()
        return mid, ev

    def _send(self, frame: bytes) -> None:
        sock = self._sock
        if sock is None:
            raise ConnectionError(f"{self.client_id}: not connected")
        with self._wlock:
            sock.sendall(frame)

    def _read_packet(self) -> tuple[int, int, bytes]:
        first = self._rfile.read(1)
        if not first:
            raise ConnectionError("EOF")
        length, mult = 0, 1
        for _ in range(4):
            b = self._rfile.read(1)
            if not b:
                raise ConnectionError("EOF")
            length += (b[0] & 0x7F) * mult
            if not b[0] & 0x80:
                break
            mult *= 128
        else:
            raise ProtocolError("bad remaining-length varint")
        body = self._rfile.read(length) if length else b""
        if len(body) != length:
            raise ConnectionError("EOF")
        return first[0] >> 4, first[0] & 0x0F, body

    def _read_loop(self) -> None:
        try:
            while True:
                ptype, flags, body = self._read_packet()
                if ptype == PUBLISH:
                    cur = _Cursor(body)
                    qos = (flags >> 1) & 0x03
                    topic = cur.utf8()
                    mid = cur.u16() if qos else 0
                    payload = cur.rest()
                    if qos:
                        self._send(packet(PUBACK, 0, mid.to_bytes(2, "big")))
                    self.on_message(topic, payload, qos, bool(flags & 0x01),
                                    bool(flags & 0x08))
                elif ptype in (SUBACK, UNSUBACK):
                    with self._mid_lock:
                        ev = self._acks.pop(
                            int.from_bytes(body[:2], "big"), None)
                    if ev is not None:
                        ev.set()
                elif ptype == PUBACK:
                    with self._mid_lock:
                        self._inflight.pop(
                            int.from_bytes(body[:2], "big"), None)
                # PINGRESP: heartbeat bookkeeping only
        except (ConnectionError, OSError, ValueError, ProtocolError):
            pass                      # socket died (or we closed it)
        finally:
            self._reader_dead = True  # flag first: woken waiters must fail
            with self._mid_lock:
                waiters = list(self._acks.values())
            for ev in waiters:
                ev.set()              # unblock anyone waiting on an ack
            cb = self.on_disconnect_cb
            if cb is not None and not self._closing:
                cb()


class _PahoClient:
    """paho-mqtt adapter presenting the same surface as ``_BuiltinClient``
    (requires the ``repro[mqtt]`` extra).  Works with paho 1.x and 2.x.

    Reconnection rides paho's own network loop (``reconnect_delay_set``
    gives it the transport's backoff bounds; paho retransmits its QoS-1
    in-flight window itself).  This adapter re-establishes subscriptions
    when the broker reports no stored session and surfaces connection
    state through ``on_disconnect_cb`` / ``on_reconnect_cb``."""

    def __init__(self, client_id: str, clean_session: bool = True):
        assert _paho is not None, "paho-mqtt is not installed"
        self.client_id = client_id
        self.on_message: Callable = lambda *a: None
        self.on_disconnect_cb: Optional[Callable] = None
        self.on_reconnect_cb: Optional[Callable] = None   # (session_present)
        self.auto_reconnect = False
        self.session_present = False
        try:            # paho >= 2.0 requires an explicit callback version
            c = _paho.Client(_paho.CallbackAPIVersion.VERSION1,
                             client_id=client_id,
                             clean_session=clean_session)
        except AttributeError:          # paho 1.x
            c = _paho.Client(client_id=client_id,
                             clean_session=clean_session)
        c.on_message = self._on_message
        c.on_connect = self._on_connect
        c.on_disconnect = self._on_disconnect
        c.on_subscribe = self._on_ack
        c.on_unsubscribe = self._on_ack
        self._c = c
        self._connected = threading.Event()
        self._connect_rc = 0
        self._first_connect = True
        self._subs: dict[str, int] = {}
        self._ack_lock = threading.Lock()
        self._acks: dict[int, threading.Event] = {}
        self._early_acks: set[int] = set()

    @property
    def connected(self) -> bool:
        return bool(self._c.is_connected())

    def configure_reconnect(self, min_delay_s: float,
                            max_delay_s: float) -> None:
        self.auto_reconnect = True
        # paho's backoff is integer seconds, doubling from min to max
        self._c.reconnect_delay_set(
            min_delay=max(1, int(min_delay_s)),
            max_delay=max(1, int(max_delay_s)))

    # paho callbacks (network-loop thread)
    def _on_message(self, _c, _ud, msg) -> None:
        self.on_message(msg.topic, bytes(msg.payload), msg.qos, msg.retain,
                        bool(getattr(msg, "dup", False)))

    def _on_connect(self, _c, _ud, flags, rc=0, *_rest) -> None:
        # rc is an int in paho 1.x and a ReasonCode in 2.x
        self._connect_rc = int(getattr(rc, "value", rc))
        if isinstance(flags, dict):
            self.session_present = bool(flags.get("session present", 0))
        else:
            self.session_present = bool(getattr(flags, "session_present", 0))
        if self._connect_rc == 0 and not self._first_connect:
            if not self.session_present:
                for filt, q in list(self._subs.items()):
                    self._c.subscribe(filt, q)
            cb = self.on_reconnect_cb
            if cb is not None:
                cb(self.session_present)
        self._first_connect = False
        self._connected.set()

    def _on_disconnect(self, _c, _ud, rc=0, *_rest) -> None:
        rc = int(getattr(rc, "value", rc))
        if rc == 0:
            return                       # deliberate disconnect
        if not self.auto_reconnect:
            # stop paho's implicit retry loop: mark the teardown deliberate
            try:
                self._c.disconnect()
            except Exception:
                pass
        cb = self.on_disconnect_cb
        if cb is not None:
            cb()

    def _on_ack(self, _c, _ud, mid, *_rest) -> None:
        # the SUBACK can beat the caller to registering its event (paho
        # only reveals the mid AFTER the packet is on the wire) — remember
        # early acks so _await_ack never waits for one already received
        with self._ack_lock:
            ev = self._acks.pop(mid, None)
            if ev is None:
                self._early_acks.add(mid)
            else:
                ev.set()

    def _await_ack(self, rc: int, mid, what: str, timeout: float) -> None:
        if rc != 0 or mid is None:
            raise ConnectionError(f"{self.client_id}: {what} failed rc={rc}")
        ev = threading.Event()
        with self._ack_lock:
            if mid in self._early_acks:
                self._early_acks.discard(mid)
                return
            self._acks[mid] = ev
        if not ev.wait(timeout):
            raise TimeoutError(f"{what} ack timeout")

    def connect(self, host: str, port: int, will=None,
                keepalive: int = 60, timeout: float = 10.0,
                clean_session: bool = True) -> None:
        # clean_session is fixed at Client construction for paho; the
        # parameter is accepted for surface parity with _BuiltinClient
        if will is not None:
            self._c.will_set(will.topic, bytes(will.payload), will.qos,
                             getattr(will, "retain", False))
        self._c.connect(host, port, keepalive=max(keepalive, 10))
        self._c.loop_start()
        if not self._connected.wait(timeout):
            raise ConnectionError(f"{self.client_id}: CONNACK timeout")
        if self._connect_rc != 0:
            self._c.loop_stop()
            raise ConnectionError(
                f"{self.client_id}: CONNECT refused rc={self._connect_rc}")

    def disconnect(self, graceful: bool = True) -> None:
        if graceful:
            self._c.disconnect()
            self._c.loop_stop()
        else:
            # abrupt death: stop the network loop first (so paho cannot
            # reconnect), then kill the socket — the broker fires the LWT
            self._c.loop_stop()
            sock = self._c.socket()
            if sock is not None:
                try:
                    sock.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                sock.close()

    def subscribe(self, topic_filter: str, qos: int = 0,
                  timeout: float = 10.0) -> None:
        self._subs[topic_filter] = qos
        rc, mid = self._c.subscribe(topic_filter, qos)
        self._await_ack(rc, mid, f"SUBSCRIBE {topic_filter!r}", timeout)

    def unsubscribe(self, topic_filter: str, timeout: float = 10.0) -> None:
        self._subs.pop(topic_filter, None)
        rc, mid = self._c.unsubscribe(topic_filter)
        try:
            self._await_ack(rc, mid, f"UNSUBSCRIBE {topic_filter!r}", timeout)
        except TimeoutError:
            pass                # UNSUBACK loss is benign; don't hard-fail

    def publish(self, topic: str, payload: bytes, qos: int = 0,
                retain: bool = False) -> None:
        self._c.publish(topic, bytes(payload), qos=min(qos, 1), retain=retain)


# ---------------------------------------------------------------------------
# the Transport implementation
# ---------------------------------------------------------------------------

class _Endpoint:
    """Pool entry: one logical client = one broker connection + its
    application callback + barrier/reconnect bookkeeping."""

    __slots__ = ("client_id", "client", "on_message", "markers",
                 "connected", "closed", "failed", "reconnecting",
                 "generation", "clean_session")

    def __init__(self, client_id: str, client, on_message: Callable,
                 clean_session: bool = True):
        self.client_id = client_id
        self.client = client
        self.on_message = on_message
        self.markers = threading.Semaphore(0)   # flush-marker echoes
        self.connected = False       # live broker connection right now?
        self.closed = False          # deliberately disconnected — stay down
        self.failed = False          # reconnect budget exhausted
        self.reconnecting = False    # a backoff loop is running for this ep
        self.generation = 0          # bumps per outage: keys the jitter rng
        self.clean_session = clean_session


class PahoTransport:
    """``repro.api.transport.Transport`` over a real MQTT broker.

    Parameters:
        host, port:     broker endpoint (e.g. a started ``MiniBroker``'s
                        ``.port``, or 1883 for a local Mosquitto).
        backend:        ``"auto"`` (paho if installed, else builtin),
                        ``"paho"``, or ``"builtin"``.
        flush_root:     marker-topic root for the quiescence barrier.  The
                        default ``$flush`` is invisible to application
                        wildcard subscriptions; point it at a normal topic
                        for brokers that reject ``$``-topic publishes.
        settle_grace_s: per-wait window for the timed-grace fallback (only
                        used when the barrier is unavailable).
        settle_timeout_s: hard ceiling for one ``settle()`` call.
        keepalive_s:    MQTT keepalive (0 disables — fine for the bundled
                        mini-broker, which never expires connections).
        clean_session:  transport-wide default for ``connect()``;
                        ``False`` makes every pooled connection a
                        persistent MQTT session (broker keeps
                        subscriptions + queues QoS 1 across outages).
        reconnect:      ``"auto"`` (reconnect iff ``clean_session=False``
                        — resumption is what makes it lossless), ``True``,
                        or ``False``.  Dropped connections are re-dialed
                        under bounded exponential backoff with jitter;
                        the QoS-1 in-flight window is retransmitted (DUP)
                        and subscriptions restored when the broker kept no
                        session.
        backoff_*:      backoff schedule: delay starts at ``backoff_base_s``,
                        multiplies by ``backoff_factor`` per failure, is
                        capped at ``backoff_max_s``, and each wait is
                        stretched by up to ``backoff_jitter`` (relative,
                        from a per-(client, outage) seeded rng — the delay
                        sequence is deterministic for a given seed).
        max_reconnects: attempts per outage before the endpoint is marked
                        failed (``None`` = unbounded).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 1883,
                 backend: str = "auto", name: Optional[str] = None,
                 flush_root: str = "$flush",
                 settle_grace_s: float = 0.05,
                 settle_timeout_s: float = 60.0,
                 keepalive_s: int = 0,
                 connect_timeout_s: float = 10.0,
                 clean_session: bool = True,
                 reconnect: Any = "auto",
                 backoff_base_s: float = 0.05,
                 backoff_factor: float = 2.0,
                 backoff_max_s: float = 2.0,
                 backoff_jitter: float = 0.1,
                 max_reconnects: Optional[int] = None,
                 reconnect_seed: int = 0):
        assert backend in ("auto", "paho", "builtin"), backend
        assert reconnect in ("auto", True, False), reconnect
        if backend == "auto":
            backend = "paho" if paho_available() else "builtin"
        if backend == "paho" and not paho_available():
            raise ModuleNotFoundError(
                "paho-mqtt is not installed — pip install 'repro[mqtt]' "
                "or pass backend='builtin'")
        self.backend = backend
        self.host = host
        self.port = port
        self.name = name or f"mqtt://{host}:{port}"
        self.flush_root = flush_root
        self.settle_grace_s = settle_grace_s
        self.settle_timeout_s = settle_timeout_s
        self.keepalive_s = keepalive_s
        self.connect_timeout_s = connect_timeout_s
        self.clean_session = clean_session
        self.reconnect = reconnect
        self.backoff_base_s = backoff_base_s
        self.backoff_factor = backoff_factor
        self.backoff_max_s = backoff_max_s
        self.backoff_jitter = backoff_jitter
        self.max_reconnects = max_reconnects
        self.reconnect_seed = reconnect_seed
        self._endpoints: dict[str, _Endpoint] = {}
        self._lock = threading.Lock()
        # entries are (endpoint, message): keyed on the endpoint OBJECT so
        # a clean-session reconnect never sees the old session's frames
        self._inbox: "queue.SimpleQueue[tuple[_Endpoint, Message]]" = \
            queue.SimpleQueue()
        self._clock = None
        self._barrier_ok = True
        self._barrier_seen = False      # any marker echo ever received?
        self._mids = 0
        # optional telemetry facade (repro.obs.Telemetry); set by
        # Federation(metrics=...).  None = zero-overhead default.
        self.obs = None
        # counters for sys_stats
        self.publishes = 0
        self.received = 0
        self.dispatched = 0
        self.bytes_out = 0
        self.bytes_in = 0
        self.barrier_rounds = 0
        self.connection_drops = 0
        self.reconnects = 0
        self.reconnect_failures = 0
        self.send_failures = 0

    @property
    def reconnect_enabled(self) -> bool:
        if self.reconnect == "auto":
            return not self.clean_session
        return bool(self.reconnect)

    # ---- Transport surface ----------------------------------------------
    def connect(self, client_id: str, on_message: Callable,
                will: Optional[Any] = None,
                clean_session: Optional[bool] = None) -> _Endpoint:
        """Open this client's dedicated broker connection.  ``will`` (any
        object with ``topic``/``payload``/``qos``/``retain``) becomes the
        connection's LWT — published by the *broker* if the connection dies
        without a graceful DISCONNECT.  ``clean_session=None`` uses the
        transport-wide default; ``False`` asks the broker to keep this
        client's session (subscriptions + offline QoS-1 queue) across
        disconnects."""
        clean = self.clean_session if clean_session is None \
            else bool(clean_session)
        old = self._endpoints.get(client_id)
        if old is not None:             # reconnect: old session's subs die
            self.disconnect(client_id, graceful=True)
        cl = (_PahoClient(client_id, clean_session=clean)
              if self.backend == "paho" else _BuiltinClient(client_id))
        ep = _Endpoint(client_id, cl, on_message, clean_session=clean)
        cl.on_message = self._receiver(ep)
        cl.on_disconnect_cb = lambda _ep=ep: self._on_conn_lost(_ep)
        if self.backend == "paho":
            cl.on_reconnect_cb = lambda sp, _ep=ep: self._on_conn_up(_ep, sp)
            if self.reconnect_enabled:
                cl.configure_reconnect(self.backoff_base_s,
                                       self.backoff_max_s)
        cl.connect(self.host, self.port, will=will,
                   keepalive=self.keepalive_s,
                   timeout=self.connect_timeout_s, clean_session=clean)
        ep.connected = True
        cl.subscribe(self._marker_topic(client_id), qos=0)
        with self._lock:
            self._endpoints[client_id] = ep
        return ep

    def disconnect(self, client_id: str, graceful: bool = True) -> None:
        with self._lock:
            ep = self._endpoints.pop(client_id, None)
        if ep is not None:
            ep.closed = True            # stops any reconnect loop for good
            ep.connected = False
            ep.client.disconnect(graceful=graceful)

    def subscribe(self, client_id: str, topic_filter: str,
                  qos: int = 0) -> None:
        try:
            self._endpoint(client_id).client.subscribe(topic_filter, qos=qos)
        except (ConnectionError, OSError):
            if not self.reconnect_enabled:
                raise
            # offline: the client cached the filter; it is re-subscribed
            # (and the broker-side session restored) on reconnect

    def unsubscribe(self, client_id: str, topic_filter: str) -> None:
        ep = self._endpoints.get(client_id)
        if ep is not None:
            try:
                ep.client.unsubscribe(topic_filter)
            except (ConnectionError, OSError):
                if not self.reconnect_enabled:
                    raise

    def publish(self, topic: str, payload: bytes, qos: int = 0,
                retain: bool = False, sender: str = "") -> int:
        """Publish on ``sender``'s connection (per-sender FIFO, exactly as
        a fleet of real clients would).  An empty ``sender`` rides a shared
        utility connection.  During an outage, QoS-1 publishes enter the
        client's in-flight window and go out on reconnect; QoS-0 publishes
        are dropped (fire-and-forget semantics) and counted."""
        ep = self._endpoints.get(sender) if sender else None
        if ep is None:
            ep = self._tx_endpoint()
        try:
            ep.client.publish(topic, payload, qos=qos, retain=retain)
        except (ConnectionError, OSError):
            self.send_failures += 1
        self.publishes += 1
        self.bytes_out += len(payload)
        self._mids += 1
        return self._mids

    # ---- reconnect machinery ---------------------------------------------
    def _on_conn_lost(self, ep: _Endpoint) -> None:
        """Unexpected connection loss (network thread).  Marks the endpoint
        down and — for the builtin backend — starts one backoff loop."""
        if ep.closed or not ep.connected:
            return
        ep.connected = False
        self.connection_drops += 1
        if self.obs is not None:
            self.obs.trace("mqtt_connection_lost", client=ep.client_id)
        if not self.reconnect_enabled or self.backend == "paho":
            return                      # paho's loop re-dials on its own
        with self._lock:
            if ep.reconnecting:
                return
            ep.reconnecting = True
        threading.Thread(target=self._reconnect_loop, args=(ep,),
                         name=f"mqtt-reconnect-{ep.client_id}",
                         daemon=True).start()

    def _on_conn_up(self, ep: _Endpoint, session_present: bool) -> None:
        ep.failed = False
        ep.connected = True
        self.reconnects += 1
        if self.obs is not None:
            self.obs.trace("mqtt_reconnected", client=ep.client_id,
                           session_present=bool(session_present))

    def _reconnect_loop(self, ep: _Endpoint) -> None:
        """Bounded exponential backoff with jitter, seeded per (client,
        outage) so the wait sequence is deterministic for a given
        ``reconnect_seed``."""
        rng = random.Random(
            f"{self.reconnect_seed}/{ep.client_id}/{ep.generation}")
        ep.generation += 1
        delay = self.backoff_base_s
        attempts = 0
        try:
            while not ep.closed and self._endpoints.get(ep.client_id) is ep:
                if self.max_reconnects is not None \
                        and attempts >= self.max_reconnects:
                    ep.failed = True
                    self.reconnect_failures += 1
                    if self.obs is not None:
                        self.obs.trace("mqtt_reconnect_failed",
                                       client=ep.client_id,
                                       attempts=attempts)
                    return
                time.sleep(min(delay * (1.0 + self.backoff_jitter
                                        * rng.random()),
                               self.backoff_max_s))
                attempts += 1
                if ep.closed or self._endpoints.get(ep.client_id) is not ep:
                    return
                if ep.client.reconnect(retransmit=False):
                    ep.reconnecting = False
                    self._on_conn_up(ep, ep.client.session_present)
                    if not ep.client.session_present:
                        # amnesiac broker: every peer's subscriptions died
                        # with it.  Retransmitting now would feed frames to
                        # a subscriber-less broker (PUBACKed, routed to
                        # nobody, gone) — hold the window until the rest of
                        # this pool has re-subscribed (bounded, so a peer
                        # that never recovers can't block delivery forever)
                        self._await_pool_recovery()
                    ep.client.retransmit_inflight()
                    return
                delay = min(delay * self.backoff_factor, self.backoff_max_s)
        finally:
            ep.reconnecting = False

    def _await_pool_recovery(self, timeout: Optional[float] = None) -> None:
        deadline = time.monotonic() + (
            timeout if timeout is not None else max(4 * self.backoff_max_s,
                                                    1.0))
        while time.monotonic() < deadline and self._recovery_pending():
            time.sleep(0.005)

    def sys_stats(self) -> dict:
        return {
            "backend": self.backend,
            "broker": f"{self.host}:{self.port}",
            "connections": len(self._endpoints),
            "publishes": self.publishes,
            "received": self.received,
            "dispatched": self.dispatched,
            "pending_dispatch": self.received - self.dispatched,
            "bytes_out": self.bytes_out,
            "bytes_in": self.bytes_in,
            "barrier_rounds": self.barrier_rounds,
            "barrier_supported": self._barrier_ok,
            "connection_drops": self.connection_drops,
            "reconnects": self.reconnects,
            "reconnect_failures": self.reconnect_failures,
            "send_failures": self.send_failures,
            "reconnect_enabled": self.reconnect_enabled,
            "clean_session": self.clean_session,
            # canonical core schema (repro.obs.SYS_CORE), from this
            # transport's perspective: sent = published to the broker,
            # received = delivered by the broker to pooled subscribers
            "messages_sent": self.publishes,
            "messages_received": self.received,
            "bytes_sent": self.bytes_out,
            "bytes_received": self.bytes_in,
        }

    def close(self) -> None:
        """Gracefully disconnect every pooled connection."""
        with self._lock:
            eps, self._endpoints = list(self._endpoints.values()), {}
        for ep in eps:
            ep.closed = True
            ep.connected = False
            ep.client.disconnect(graceful=True)

    def __enter__(self) -> "PahoTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- inbound bridge --------------------------------------------------
    def _receiver(self, ep: _Endpoint) -> Callable:
        marker = self._marker_topic(ep.client_id)

        def on_net_message(topic: str, payload: bytes, qos: int,
                           retain: bool, dup: bool = False) -> None:
            # network-loop thread: never run application code here
            if topic == marker:
                self._barrier_seen = True
                ep.markers.release()
                return
            self.received += 1
            self.bytes_in += len(payload)
            self._inbox.put((ep, Message(topic, payload, qos, retain,
                                         duplicate=dup)))
        return on_net_message

    def _dispatch_one(self, ep: _Endpoint, msg: Message) -> bool:
        self.dispatched += 1
        # frames for a disconnected (or takeover-replaced) session drop:
        # a clean-session reconnect must not inherit the old inbox
        if self._endpoints.get(ep.client_id) is not ep:
            return False
        ep.on_message(msg)
        return True

    def _dispatch_available(self) -> int:
        """Deliver everything currently in the inbox on *this* thread."""
        n = 0
        while True:
            try:
                ep, msg = self._inbox.get_nowait()
            except queue.Empty:
                return n
            if self._dispatch_one(ep, msg):
                n += 1

    def settle(self, block: bool = True,
               timeout: Optional[float] = None) -> int:
        """Dispatch in-flight traffic to the registered callbacks on the
        calling thread; returns the number of messages delivered.

        ``block=False`` drains only what has already arrived.
        ``block=True`` runs flush-barrier rounds (or timed-grace waits if
        the broker rejected the marker topic) until two consecutive rounds
        deliver nothing — i.e. the whole publish/react cascade has
        quiesced."""
        total = self._dispatch_available()
        if not block:
            return total
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else self.settle_timeout_s)
        quiet = 0
        while quiet < 2:
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"{self.name}: settle() exceeded its deadline with "
                    f"traffic still flowing")
            n = self._settle_round(deadline)
            if n:
                total += n
                quiet = 0
            elif self._recovery_pending():
                # endpoints are mid-reconnect: frames may still be parked
                # in their in-flight windows, so an empty round proves
                # nothing yet — wait for the backoff loops to finish
                quiet = 0
                time.sleep(min(self.settle_grace_s,
                               max(deadline - time.monotonic(), 0.001)))
            else:
                quiet += 1
        return total

    def _recovery_pending(self) -> bool:
        if not self.reconnect_enabled:
            return False
        with self._lock:
            eps = list(self._endpoints.values())
        return any(not ep.connected and not ep.closed and not ep.failed
                   for ep in eps)

    def _settle_round(self, deadline: float) -> int:
        if self._barrier_ok and self._barrier(deadline):
            return self._dispatch_available()
        # grace fallback: wait a fixed window for anything to arrive
        try:
            ep, msg = self._inbox.get(
                timeout=min(self.settle_grace_s,
                            max(deadline - time.monotonic(), 0.001)))
        except queue.Empty:
            return 0
        # dispatch the probed head directly — re-queuing it would put it
        # behind frames that arrived meanwhile, breaking per-sender FIFO
        n = 1 if self._dispatch_one(ep, msg) else 0
        return n + self._dispatch_available()

    def _barrier(self, deadline: float) -> bool:
        """One flush-barrier round: a marker on every connection, wait for
        every echo.  A timeout before ANY echo was ever observed means the
        broker eats the marker topic — the transport latches into
        timed-grace mode.  A timeout after echoes have worked is treated
        as transient (slow link, tight caller deadline): this settle round
        falls back to the grace wait and the next round retries the
        barrier."""
        with self._lock:
            eps = [ep for ep in self._endpoints.values() if ep.connected]
        if not eps:
            return False
        self.barrier_rounds += 1
        sent = []
        for ep in eps:
            # drain echoes of earlier (timed-out) rounds: a stale token
            # must not satisfy THIS round's happens-before proof
            while ep.markers.acquire(blocking=False):
                pass
            try:
                ep.client.publish(self._marker_topic(ep.client_id), b"",
                                  qos=0)
            except (ConnectionError, OSError):
                continue        # endpoint died mid-round: reconnect handles
            sent.append(ep)
        if not sent:
            return False
        budget = min(5.0, max(deadline - time.monotonic(), 0.001))
        for ep in sent:
            if not ep.markers.acquire(timeout=budget):
                if not self._barrier_seen and ep.connected \
                        and self._endpoints.get(ep.client_id) is ep:
                    self._barrier_ok = False    # broker eats marker topics
                return False
        return True

    # ---- SimClock bridge -------------------------------------------------
    def attach_clock(self, clock) -> None:
        """Install this transport as an external event source on a
        ``SimClock``: any clock drain (``run_until_idle``, ``advance_to``,
        an unheld publish) then also pumps real network traffic, and the
        clock's idle callbacks only fire once the network is quiet.
        ``Federation`` calls this automatically."""
        if self._clock is not None:
            self._clock.remove_source(self._clock_source)
        self._clock = clock
        clock.add_source(self._clock_source)

    def _clock_source(self, block: bool) -> bool:
        if not block:
            return self._dispatch_available() > 0
        if not self._endpoints:
            return False
        return self.settle(block=True) > 0

    # ---- helpers ---------------------------------------------------------
    def _marker_topic(self, client_id: str) -> str:
        return f"{self.flush_root}/{client_id}"

    def _endpoint(self, client_id: str) -> _Endpoint:
        ep = self._endpoints.get(client_id)
        if ep is None:
            raise KeyError(f"unknown client {client_id!r}: connect() first")
        return ep

    def _tx_endpoint(self) -> _Endpoint:
        """Lazy shared utility connection for publishes with no (or a
        not-yet-connected) ``sender`` — matching SimBroker, where
        ``sender`` is routing metadata and needs no session.  Note the
        per-sender FIFO guarantee only holds for publishes issued after
        the sender's own ``connect()``."""
        ep = self._endpoints.get("__tx__")
        if ep is None:
            ep = self.connect("__tx__", lambda msg: None)
        return ep


__all__ = ["PahoTransport", "paho_available"]
