"""Transport abstraction: the interface SDFLMQ actually needs from a broker.

``Transport`` is the protocol extracted from SimBroker — MQTTFC, clients,
the coordinator, and the parameter server depend on this surface only, so a
real paho-mqtt backend (or a multi-broker bridge fabric) can slot in behind
the same federation code.

``SimClock`` is a discrete-event virtual clock: a priority queue of
timestamped events drained strictly in ``(time, insertion)`` order.  Two
event classes live on it:

  * **message events** — in-flight deliveries scheduled by transports and
    broker bridges; drained by ``run_until_idle()`` and by any time advance;
  * **timer events** — control-plane alarms (round deadlines, waiting-time
    expiry, scenario triggers); they fire *only* when time is explicitly
    advanced (``advance_to``/``advance``), never during a plain message
    drain, so legacy synchronous flows are untouched.

``LatencyTransport`` decorates any Transport with a per-link edge-network
model (base delay + jitter + loss probability per publishing client) and an
**event-driven delivery queue**: each publish is enqueued with its modeled
arrival time instead of pumping immediately, so

  * two clients' updates published A,B can genuinely arrive B,A under
    asymmetric link delay (hold the clock, then drain);
  * QoS 0 publishes are *really* dropped with probability ``drop_p``;
  * QoS >= 1 publishes always arrive (at-least-once) but a drawn drop
    counts as a retransmission and the message arrives *late* (2x latency)
    — genuinely after messages sent later on faster links;
  * ``partition(groups)`` holds QoS>=1 traffic between clients in
    different groups until ``heal()`` (QoS 0 cross-partition traffic is
    lost, as a real broker outage would lose it);
  * with the clock un-held (the default), every top-level publish drains
    the queue to idle immediately, which is behaviorally identical to the
    old synchronous pump — zero-delay models stay bit-identical.

Randomness is drawn from a *per-link* seeded ``random.Random`` stream
(keyed on ``(seed, sender)``), so a link's jitter/drop sequence is
reproducible regardless of how messages from other links interleave, and
parallel tests never share RNG state.
"""
from __future__ import annotations

import heapq
import itertools
import random
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Protocol, runtime_checkable


@runtime_checkable
class Transport(Protocol):
    """What the control/data planes require from a message broker."""

    name: str

    def connect(self, client_id: str, on_message: Callable,
                will: Optional[Any] = None,
                clean_session: Optional[bool] = None) -> Any: ...

    def disconnect(self, client_id: str, graceful: bool = True) -> None: ...

    def subscribe(self, client_id: str, topic_filter: str,
                  qos: int = 0) -> None: ...

    def unsubscribe(self, client_id: str, topic_filter: str) -> None: ...

    def publish(self, topic: str, payload: bytes, qos: int = 0,
                retain: bool = False, sender: str = "") -> int: ...

    def sys_stats(self) -> dict: ...


# ---------------------------------------------------------------------------
# Virtual time
# ---------------------------------------------------------------------------

@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable = field(compare=False)
    timer: bool = field(compare=False, default=False)
    cancelled: bool = field(compare=False, default=False)

    def cancel(self) -> None:
        self.cancelled = True


class SimClock:
    """Discrete-event virtual clock shared by transports, brokers, and the
    coordinator.  ``schedule`` enqueues an event; draining fires events in
    strict ``(time, insertion)`` order and advances ``now`` to each event's
    timestamp — time never flows backwards.

    >>> from repro.api.transport import SimClock
    >>> clock, order = SimClock(), []
    >>> _ = clock.schedule(2.0, lambda: order.append("late"))
    >>> _ = clock.schedule(1.0, lambda: order.append("early"))
    >>> clock.run_until_idle()      # messages drain in timestamp order
    >>> order, clock.now
    (['early', 'late'], 2.0)
    >>> _ = clock.schedule(5.0, lambda: order.append("alarm"), timer=True)
    >>> clock.run_until_idle()      # timers wait for an explicit advance
    >>> _ = clock.advance_to(5.0)
    >>> order[-1]
    'alarm'
    """

    def __init__(self, now: float = 0.0):
        self.now = float(now)
        # Message events live in per-shard heaps (one per broker site in a
        # fleet fabric; the anonymous ``None`` shard otherwise) and timer
        # events in their own heap.  The global ``(time, seq)`` order is
        # reconstructed by popping the minimum head across heaps, so the
        # split is invisible to callers — but a message-only drain never
        # touches armed timers (the old single heap popped and re-pushed
        # every earlier timer on each delivery: O(timers log n) per event),
        # and each site's backlog stays in its own smaller heap.
        self._mheaps: dict[Any, list[_Event]] = {None: []}
        self._theap: list[_Event] = []
        self._seq = itertools.count()
        self._held = 0
        self._draining = False
        self._idle_cbs: list[Callable] = []
        # external event sources (real-network transports): polled during
        # drains so "idle" also means "no real traffic in flight"
        self._sources: list[Callable[[bool], bool]] = []

    # ---- external sources ------------------------------------------------
    def add_source(self, poll: Callable[[bool], bool]) -> None:
        """Register an external event source — ``poll(block)`` must
        dispatch any pending external events (e.g. inbound frames from a
        real MQTT connection) and return whether it made progress.  With
        ``block=True`` the source may wait for in-flight traffic to
        surface (``PahoTransport`` runs its flush-barrier quiescence
        protocol there).  Sources are polled during every drain, so
        ``run_until_idle`` / ``advance_to`` transparently include real
        network traffic, and idle callbacks fire only once both the event
        heap AND every source are quiet."""
        if poll not in self._sources:
            self._sources.append(poll)

    def remove_source(self, poll: Callable[[bool], bool]) -> None:
        try:
            self._sources.remove(poll)
        except ValueError:
            pass

    def _poll_sources(self, block: bool) -> bool:
        progressed = False
        for poll in list(self._sources):
            if poll(block):
                progressed = True
        return progressed

    # ---- scheduling ------------------------------------------------------
    def schedule(self, t: float, fn: Callable, timer: bool = False,
                 shard: Any = None) -> _Event:
        """Schedule ``fn`` to run at virtual time ``t`` (clamped to now).
        ``timer=True`` marks a control-plane alarm: it fires only on
        explicit time advances, never during a message drain.  ``shard``
        names the event-loop shard (e.g. a broker site) whose heap the
        event rides; unknown shards are created on first use."""
        ev = _Event(max(float(t), self.now), next(self._seq), fn, timer)
        if timer:
            heapq.heappush(self._theap, ev)
        else:
            h = self._mheaps.get(shard)
            if h is None:
                h = self._mheaps[shard] = []
            heapq.heappush(h, ev)
        return ev

    def call_when_idle(self, fn: Callable) -> None:
        """Run ``fn`` (once) the next time the message queue is empty —
        i.e. after every in-flight delivery cascade has settled."""
        self._idle_cbs.append(fn)

    def schedule_periodic(self, period: float, fn: Callable,
                          first_at: Optional[float] = None,
                          jitter_fn: Optional[Callable] = None) -> "_PeriodicTimer":
        """Arm a recurring *timer* event every ``period`` virtual seconds
        (first firing at ``first_at``, default ``now + period``).  The
        returned handle's ``cancel()`` stops the series; ``fn`` returning
        ``False`` also stops it.  ``jitter_fn()`` (if given) is added to
        each inter-fire gap — pass a seeded callable for reproducible
        jitter.  Used by async-FL per-client pacing and head-gossip timers."""
        return _PeriodicTimer(self, float(period), fn, first_at, jitter_fn)

    # ---- hold: manual mode ----------------------------------------------
    @property
    def held(self) -> bool:
        return self._held > 0

    @contextmanager
    def hold(self):
        """While held, transports stop auto-draining after each publish:
        deliveries accumulate in the queue and are released only by
        ``advance_to``/``advance``/``run_until_idle`` — this is what lets
        messages genuinely arrive out of publish order."""
        self._held += 1
        try:
            yield self
        finally:
            self._held -= 1

    # ---- introspection ---------------------------------------------------
    def pending(self, timers: bool = True) -> int:
        n = sum(1 for h in self._mheaps.values()
                for e in h if not e.cancelled)
        if timers:
            n += sum(1 for e in self._theap if not e.cancelled)
        return n

    def shards(self) -> dict:
        """Live message-event count per event-loop shard (introspection)."""
        return {k: sum(1 for e in h if not e.cancelled)
                for k, h in self._mheaps.items() if h}

    @staticmethod
    def _head(h: list) -> Optional[_Event]:
        while h and h[0].cancelled:
            heapq.heappop(h)                 # lazy cleanup: O(1) amortized
        return h[0] if h else None

    def next_event_time(self) -> Optional[float]:
        times = [e.time for e in map(self._head, self._mheaps.values()) if e]
        th = self._head(self._theap)
        if th is not None:
            times.append(th.time)
        return min(times) if times else None

    # ---- draining --------------------------------------------------------
    def _pop_due(self, limit: float, timers: bool) -> Optional[_Event]:
        # pop the globally-earliest due event: scan shard heads (K small),
        # never touching the timer heap during message-only drains
        best_h = None
        best = None
        for h in self._mheaps.values():
            e = self._head(h)
            if e and (best is None or (e.time, e.seq) < (best.time, best.seq)):
                best, best_h = e, h
        if timers:
            e = self._head(self._theap)
            if e and (best is None or (e.time, e.seq) < (best.time, best.seq)):
                best, best_h = e, self._theap
        if best is None or best.time > limit:
            return None
        return heapq.heappop(best_h)

    def _fire_idle_cbs(self) -> bool:
        if self._idle_cbs and self.pending(timers=False) == 0:
            cbs, self._idle_cbs = self._idle_cbs, []
            for cb in cbs:
                cb()
            return True
        return False

    def _drain(self, limit: float, timers: bool) -> None:
        if self._draining:
            return
        self._draining = True
        try:
            while True:
                # external sources first (cheap non-blocking poll): inbound
                # real-network frames dispatch before anything else, like
                # queued SimBroker deliveries would
                if self._sources and self._poll_sources(block=False):
                    continue
                # idle callbacks fire the moment no message events remain —
                # checked before the next (possibly later) timer pops, so
                # "the cascade settled" is observed at the right instant.
                # With external sources, "settled" must include traffic
                # still in flight on real sockets: block on the sources'
                # quiescence protocol before declaring idle.
                if self._idle_cbs and self._sources \
                        and self.pending(timers=False) == 0 \
                        and self._poll_sources(block=True):
                    continue
                if self._fire_idle_cbs():
                    continue
                ev = self._pop_due(limit, timers)
                if ev is None:
                    if self._sources and self._poll_sources(block=True):
                        continue
                    break
                self.now = max(self.now, ev.time)
                ev.fn()
        finally:
            self._draining = False

    def run_until_idle(self) -> None:
        """Deliver every queued *message* event in timestamp order (timers
        stay armed), advancing ``now`` along the way."""
        self._drain(float("inf"), timers=False)

    def advance_to(self, t: float) -> float:
        """Advance virtual time to ``t``, firing every event (messages AND
        timers) scheduled at or before ``t`` in exact timestamp order."""
        self._drain(float(t), timers=True)
        self.now = max(self.now, float(t))
        return self.now

    def advance(self, dt: float) -> float:
        return self.advance_to(self.now + dt)


class _PeriodicTimer:
    """Self-rescheduling timer series on a SimClock (see
    ``SimClock.schedule_periodic``)."""

    __slots__ = ("clock", "period", "fn", "jitter_fn", "cancelled", "_ev",
                 "fires")

    def __init__(self, clock: SimClock, period: float, fn: Callable,
                 first_at: Optional[float], jitter_fn: Optional[Callable]):
        self.clock = clock
        self.period = period
        self.fn = fn
        self.jitter_fn = jitter_fn
        self.cancelled = False
        self.fires = 0
        t0 = clock.now + period if first_at is None else float(first_at)
        self._ev = clock.schedule(t0, self._fire, timer=True)

    def _fire(self) -> None:
        if self.cancelled:
            return
        self.fires += 1
        keep = self.fn()
        if keep is False or self.cancelled:
            self.cancelled = True
            return
        gap = self.period + (self.jitter_fn() if self.jitter_fn else 0.0)
        self._ev = self.clock.schedule(self.clock.now + max(gap, 1e-9),
                                       self._fire, timer=True)

    def cancel(self) -> None:
        self.cancelled = True
        if self._ev is not None:
            self._ev.cancel()


@dataclass
class LinkModel:
    """Per-link network parameters (seconds / probability).  ``dup_p`` is
    the probability that a QoS>=1 publish is *redelivered* — the broker's
    at-least-once duplicate, arriving as a genuine second copy after the
    original (possibly after newer frames), exercising receiver dedup."""
    delay_s: float = 0.0
    jitter_s: float = 0.0
    drop_p: float = 0.0
    dup_p: float = 0.0


@dataclass
class _LinkStats:
    messages: int = 0
    dropped: int = 0
    retransmits: int = 0
    duplicates: int = 0
    latency_s: float = 0.0
    max_latency_s: float = 0.0

    def observe(self, lat: float) -> None:
        self.messages += 1
        self.latency_s += lat
        self.max_latency_s = max(self.max_latency_s, lat)


class LatencyTransport:
    """Event-driven per-link delay/jitter/drop/partition decorator over a
    Transport, scheduling deliveries on a shared ``SimClock``.

    >>> from repro.api.transport import LatencyTransport
    >>> from repro.core.broker import SimBroker
    >>> t = LatencyTransport(SimBroker(), delay_s=0.05)
    >>> got = []
    >>> _ = t.connect("sub", lambda m: got.append(bytes(m.payload)))
    >>> t.subscribe("sub", "sensors/+", qos=1)
    >>> _ = t.publish("sensors/t1", b"21.5", qos=1, sender="edge-node")
    >>> got                      # clock un-held: publish drained to idle
    [b'21.5']
    >>> t.clock.now              # ... after the modeled link delay
    0.05
    """

    def __init__(self, inner: Transport, delay_s: float = 0.0,
                 jitter_s: float = 0.0, drop_p: float = 0.0,
                 dup_p: float = 0.0, seed: int = 0,
                 clock: Optional[SimClock] = None):
        self.inner = inner
        self.default = LinkModel(delay_s, jitter_s, drop_p, dup_p)
        # event-loop shard this transport's deliveries ride (a fleet fabric
        # sets one per broker site; None = the clock's anonymous shard)
        self.shard: Any = None
        self.links: dict[str, LinkModel] = {}
        self.seed = seed
        self._rngs: dict[str, random.Random] = {}
        self.clock = clock if clock is not None else SimClock()
        # real-network inner transports (PahoTransport) register themselves
        # as an external event source so clock drains pump their traffic
        attach = getattr(inner, "attach_clock", None)
        if attach is not None:
            attach(self.clock)
        self.link_stats: dict[str, _LinkStats] = {}
        # partition state: list of disjoint client-id groups; traffic
        # between different groups is cut (ungrouped actors reach everyone)
        self._groups: Optional[list[set]] = None
        self._held_msgs: list[tuple[str, Any]] = []     # (receiver, Message)
        self._callbacks: dict[str, Callable] = {}
        self._current_sender: Optional[str] = None
        self._last_arrival: dict[str, float] = {}       # per-sender FIFO
        self.partition_held = 0
        self.partition_dropped = 0
        # optional telemetry facade (repro.obs.Telemetry); set by
        # Federation(metrics=...).  None = zero-overhead default.
        self.obs = None

    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def virtual_time_s(self) -> float:
        return self.clock.now

    def set_link(self, client_id: str, delay_s: float = 0.0,
                 jitter_s: float = 0.0, drop_p: float = 0.0,
                 dup_p: float = 0.0) -> None:
        self.links[client_id] = LinkModel(delay_s, jitter_s, drop_p, dup_p)

    def clear_link(self, client_id: str) -> None:
        self.links.pop(client_id, None)

    def _rng_for(self, sender: str) -> random.Random:
        rng = self._rngs.get(sender)
        if rng is None:
            rng = self._rngs[sender] = random.Random(f"{self.seed}/{sender}")
        return rng

    # ---- partitions ------------------------------------------------------
    def partition(self, *groups) -> None:
        """Cut connectivity between clients in different ``groups`` (each an
        iterable of client ids).  Clients not named in any group keep full
        connectivity.  QoS>=1 and retained traffic across the cut is held;
        QoS 0 traffic is lost."""
        self._groups = [set(g) for g in groups]
        if self.obs is not None:
            self.obs.trace("partition", groups=len(self._groups),
                           clients=sum(len(g) for g in self._groups))

    def heal(self) -> None:
        """Restore connectivity and release held messages (delivered at the
        heal time, in the order they were originally routed)."""
        self._groups = None
        held, self._held_msgs = self._held_msgs, []
        if self.obs is not None:
            self.obs.trace("heal", released=len(held))
        for receiver, msg in held:
            self.clock.schedule(
                self.clock.now,
                lambda r=receiver, m=msg: self._deliver_direct(r, m),
                shard=self.shard)
        if not self.clock.held:
            self.clock.run_until_idle()

    def _cut(self, sender: str, receiver: str) -> bool:
        if self._groups is None or sender == receiver:
            return False
        gs = gr = None
        for g in self._groups:
            if sender in g:
                gs = g
            if receiver in g:
                gr = g
        return gs is not None and gr is not None and gs is not gr

    def _deliver_direct(self, receiver: str, msg) -> None:
        fn = self._callbacks.get(receiver)
        if fn is not None:
            fn(msg)

    # ---- Transport surface ----------------------------------------------
    def connect(self, client_id, on_message, will=None,
                clean_session: Optional[bool] = None):
        self._callbacks[client_id] = on_message

        def guarded(msg, _cid=client_id, _fn=on_message):
            snd = self._current_sender
            if snd is not None and self._cut(snd, _cid):
                if msg.qos >= 1 or msg.retain:
                    self.partition_held += 1
                    self._held_msgs.append((_cid, msg))
                else:
                    self.partition_dropped += 1
                return
            _fn(msg)

        return self.inner.connect(client_id, guarded, will=will,
                                  clean_session=clean_session)

    def disconnect(self, client_id, graceful: bool = True):
        self._callbacks.pop(client_id, None)
        return self.inner.disconnect(client_id, graceful=graceful)

    def subscribe(self, client_id, topic_filter, qos: int = 0):
        return self.inner.subscribe(client_id, topic_filter, qos=qos)

    def unsubscribe(self, client_id, topic_filter):
        return self.inner.unsubscribe(client_id, topic_filter)

    def publish(self, topic: str, payload: bytes, qos: int = 0,
                retain: bool = False, sender: str = "") -> int:
        link = self.links.get(sender, self.default)
        st = self.link_stats.setdefault(sender or "<anon>", _LinkStats())
        rng = self._rng_for(sender or "<anon>")
        lat = link.delay_s + rng.uniform(0.0, link.jitter_s)
        if link.drop_p and rng.random() < link.drop_p:
            if qos == 0:
                st.dropped += 1
                return -1                     # fire-and-forget: lost
            st.retransmits += 1               # at-least-once: resend once,
            lat *= 2.0                        # arriving genuinely late
        st.observe(lat)
        # per-sender FIFO: one client's messages ride one ordered MQTT
        # connection, so a later publish never overtakes an earlier one
        # (cross-sender reordering is real; same-sender reordering is not)
        key = sender or "<anon>"
        arrival = max(self.clock.now + lat, self._last_arrival.get(key, 0.0))
        self._last_arrival[key] = arrival
        if self.obs is not None:
            self.obs.trace("publish", topic=topic, sender=key, qos=qos,
                           bytes=len(payload), arrival=round(arrival, 6))
        self.clock.schedule(
            arrival,
            lambda: self._deliver(topic, payload, qos, retain, sender),
            shard=self.shard)
        if link.dup_p and qos >= 1 and not retain \
                and rng.random() < link.dup_p:
            # broker at-least-once redelivery: a genuine second copy of the
            # same frame, arriving after the original — deliberately NOT
            # clamped to the per-sender FIFO horizon, so it can land after
            # newer frames, exactly like a real broker's retransmit
            st.duplicates += 1
            dup_arrival = arrival + max(lat, 1e-6) \
                + rng.uniform(0.0, link.jitter_s + link.delay_s)
            self.clock.schedule(
                dup_arrival,
                lambda: self._deliver(topic, payload, qos, retain, sender),
                shard=self.shard)
        if not self.clock.held:
            self.clock.run_until_idle()
        return 0

    def _deliver(self, topic, payload, qos, retain, sender) -> None:
        if self.obs is not None:
            self.obs.trace("deliver", topic=topic, sender=sender or "<anon>",
                           bytes=len(payload))
        prev, self._current_sender = self._current_sender, sender or None
        try:
            self.inner.publish(topic, payload, qos=qos, retain=retain,
                               sender=sender)
        finally:
            self._current_sender = prev

    def sys_stats(self) -> dict:
        out = dict(self.inner.sys_stats())
        out["virtual_time_s"] = round(self.clock.now, 6)
        out["pending_deliveries"] = self.clock.pending(timers=False)
        out["partition_held"] = self.partition_held
        out["partition_dropped"] = self.partition_dropped
        out["links"] = {
            k: {"messages": s.messages, "dropped": s.dropped,
                "retransmits": s.retransmits, "duplicates": s.duplicates,
                "mean_latency_ms": round(
                    1e3 * s.latency_s / s.messages, 3) if s.messages else 0.0,
                "max_latency_ms": round(1e3 * s.max_latency_s, 3)}
            for k, s in self.link_stats.items()}
        return out

    # anything else (bridge, retained_topics, delivery_log, ...) passes
    # through to the wrapped broker
    def __getattr__(self, item):
        return getattr(self.inner, item)
