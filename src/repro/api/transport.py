"""Transport abstraction: the interface SDFLMQ actually needs from a broker.

``Transport`` is the protocol extracted from SimBroker — MQTTFC, clients,
the coordinator, and the parameter server depend on this surface only, so a
real paho-mqtt backend (or a multi-broker bridge fabric) can slot in behind
the same federation code.

``LatencyTransport`` decorates any Transport with a per-link edge-network
model (base delay + jitter + loss probability per publishing client):

  * QoS 0 publishes are *really* dropped with probability ``drop_p`` —
    message-loss scenarios exercise the straggler/flush machinery;
  * QoS >= 1 publishes always arrive (at-least-once) but a drawn drop
    counts as a retransmission and doubles that message's modeled latency;
  * delivery stays synchronous and deterministic (the decorated broker
    pumps immediately); latency is tracked on a virtual clock, so examples
    and tests observe per-link/per-round timing without wall-clock sleeps.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Protocol, runtime_checkable


@runtime_checkable
class Transport(Protocol):
    """What the control/data planes require from a message broker."""

    name: str

    def connect(self, client_id: str, on_message: Callable,
                will: Optional[Any] = None) -> Any: ...

    def disconnect(self, client_id: str, graceful: bool = True) -> None: ...

    def subscribe(self, client_id: str, topic_filter: str,
                  qos: int = 0) -> None: ...

    def unsubscribe(self, client_id: str, topic_filter: str) -> None: ...

    def publish(self, topic: str, payload: bytes, qos: int = 0,
                retain: bool = False, sender: str = "") -> int: ...

    def sys_stats(self) -> dict: ...


@dataclass
class LinkModel:
    """Per-link network parameters (seconds / probability)."""
    delay_s: float = 0.0
    jitter_s: float = 0.0
    drop_p: float = 0.0


@dataclass
class _LinkStats:
    messages: int = 0
    dropped: int = 0
    retransmits: int = 0
    latency_s: float = 0.0
    max_latency_s: float = 0.0

    def observe(self, lat: float) -> None:
        self.messages += 1
        self.latency_s += lat
        self.max_latency_s = max(self.max_latency_s, lat)


class LatencyTransport:
    """Deterministic per-link delay/jitter/drop decorator over a Transport."""

    def __init__(self, inner: Transport, delay_s: float = 0.0,
                 jitter_s: float = 0.0, drop_p: float = 0.0, seed: int = 0):
        self.inner = inner
        self.default = LinkModel(delay_s, jitter_s, drop_p)
        self.links: dict[str, LinkModel] = {}
        self.rng = random.Random(seed)
        self.virtual_time_s = 0.0
        self.link_stats: dict[str, _LinkStats] = {}

    @property
    def name(self) -> str:
        return self.inner.name

    def set_link(self, client_id: str, delay_s: float = 0.0,
                 jitter_s: float = 0.0, drop_p: float = 0.0) -> None:
        self.links[client_id] = LinkModel(delay_s, jitter_s, drop_p)

    # ---- Transport surface ----------------------------------------------
    def connect(self, client_id, on_message, will=None):
        return self.inner.connect(client_id, on_message, will=will)

    def disconnect(self, client_id, graceful: bool = True):
        return self.inner.disconnect(client_id, graceful=graceful)

    def subscribe(self, client_id, topic_filter, qos: int = 0):
        return self.inner.subscribe(client_id, topic_filter, qos=qos)

    def unsubscribe(self, client_id, topic_filter):
        return self.inner.unsubscribe(client_id, topic_filter)

    def publish(self, topic: str, payload: bytes, qos: int = 0,
                retain: bool = False, sender: str = "") -> int:
        link = self.links.get(sender, self.default)
        st = self.link_stats.setdefault(sender or "<anon>", _LinkStats())
        lat = link.delay_s + self.rng.uniform(0.0, link.jitter_s)
        if link.drop_p and self.rng.random() < link.drop_p:
            if qos == 0:
                st.dropped += 1
                return -1                     # fire-and-forget: lost
            st.retransmits += 1               # at-least-once: resend once
            lat *= 2.0
        st.observe(lat)
        self.virtual_time_s += lat
        return self.inner.publish(topic, payload, qos=qos, retain=retain,
                                  sender=sender)

    def sys_stats(self) -> dict:
        out = dict(self.inner.sys_stats())
        out["virtual_time_s"] = round(self.virtual_time_s, 6)
        out["links"] = {
            k: {"messages": s.messages, "dropped": s.dropped,
                "retransmits": s.retransmits,
                "mean_latency_ms": round(
                    1e3 * s.latency_s / s.messages, 3) if s.messages else 0.0,
                "max_latency_ms": round(1e3 * s.max_latency_s, 3)}
            for k, s in self.link_stats.items()}
        return out

    # anything else (bridge, retained_topics, delivery_log, ...) passes
    # through to the wrapped broker
    def __getattr__(self, item):
        return getattr(self.inner, item)
