"""Multi-broker fleet fabric: bridged per-site brokers on one sharded clock.

The paper's §III-F bridging scales the control plane horizontally: each
site (region, campus, cell) runs its own broker, and bridges forward the
``sdflmq`` topic space between them.  ``build_fabric`` assembles the
simulated version of that deployment:

  * one **core** ``SimBroker`` hosting the coordinator and parameter
    server,
  * ``n_sites`` site brokers, each bridged to the core (hub-and-spoke — a
    tree fabric, which the per-hop re-origination loop prevention in
    ``SimBroker.bridge`` keeps duplicate-free),
  * one shared ``SimClock``; every site's ``LatencyTransport`` rides its
    own event-loop **shard**, so each site's delivery backlog lives in its
    own heap and the clock merge-scans the shard heads in global
    ``(time, seq)`` order,
  * one ``Federation`` over the core transport — ``fabric.cohort(site,
    ...)`` attaches a ``CohortClient`` to its site's transport.

Site-level failure knobs: ``partition_site``/``heal_site`` take a site's
bridges down (reliable traffic queues on the bridge and replays on heal,
QoS 0 is lost — a real broker outage), while the per-site transports carry
the usual per-link delay/jitter/drop/duplication models for straggler
sites and duplicate storms.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.api.federation import Federation, FleetSession
from repro.api.transport import LatencyTransport, SimClock
from repro.core.broker import SimBroker

__all__ = ["FleetFabric", "build_fabric"]


@dataclass
class FleetFabric:
    """Handle to one assembled multi-site fabric."""
    clock: SimClock
    core: LatencyTransport
    sites: dict[str, LatencyTransport]
    federation: Federation

    def site(self, name: str) -> LatencyTransport:
        return self.sites[name]

    def cohort(self, site: str, cohort_id: str, member_ids,
               stats=None):
        """A ``CohortClient`` fronting ``member_ids``, attached to
        ``site``'s broker (and that site's event-loop shard)."""
        return self.federation.cohort(cohort_id, member_ids, stats=stats,
                                      transport=self.sites[site])

    def create_fleet_session(self, *args, **kwargs) -> FleetSession:
        return self.federation.create_fleet_session(*args, **kwargs)

    # ---- site-level failures --------------------------------------------
    def partition_site(self, site: str) -> None:
        """Sever ``site`` from the core: both bridge directions go down.
        Reliable traffic queues on the bridges until ``heal_site``."""
        site_b = self.sites[site].inner
        self.core.inner.set_bridge_down(site_b.name, down=True)
        site_b.set_bridge_down(self.core.inner.name, down=True)

    def heal_site(self, site: str) -> None:
        site_b = self.sites[site].inner
        self.core.inner.set_bridge_down(site_b.name, down=False)
        site_b.set_bridge_down(self.core.inner.name, down=False)
        if not self.clock.held:
            self.clock.run_until_idle()

    def shard_backlog(self) -> dict:
        """Live pending-delivery count per event-loop shard."""
        return self.clock.shards()


def build_fabric(n_sites: int = 2, site_delay_s: float = 0.0,
                 site_jitter_s: float = 0.0,
                 site_latency: Optional[dict] = None,
                 core_latency: Optional[dict] = None,
                 clock: Optional[SimClock] = None, seed: int = 0,
                 **federation_kwargs) -> FleetFabric:
    """Assemble a hub-and-spoke multi-broker fabric.

    ``site_delay_s``/``site_jitter_s`` model the inter-broker bridge links
    (core <-> site); ``site_latency``/``core_latency`` are ``LinkModel``
    kwargs for the per-site client transports.  Remaining kwargs go to
    ``Federation`` (role policy, deadlines, metrics, ...).
    """
    clock = clock if clock is not None else SimClock()
    core_b = SimBroker("core")
    core_t = LatencyTransport(core_b, clock=clock, seed=seed,
                              **(core_latency or {}))
    core_t.shard = "core"
    sites: dict[str, LatencyTransport] = {}
    for i in range(n_sites):
        name = f"site{i}"
        b = SimBroker(name)
        # hub-and-spoke: every site bridges to the core only (a tree —
        # cycle-free under per-hop re-origination)
        core_b.bridge(b, delay_s=site_delay_s, jitter_s=site_jitter_s,
                      clock=clock, seed=seed)
        t = LatencyTransport(b, clock=clock, seed=seed + 1 + i,
                             **(site_latency or {}))
        t.shard = name
        sites[name] = t
    fed = Federation(transport=core_t, **federation_kwargs)
    return FleetFabric(clock, core_t, sites, fed)
