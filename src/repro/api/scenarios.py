"""Declarative edge-network scenarios over the virtual clock.

Scenario builders turn "what goes wrong" into armed events on a
federation's ``SimClock`` (time-driven: partitions, flaky links) or its
round loop (round-driven churn, layered on ``ft.failures.FailurePlan``)::

    from repro.api import Federation, scenarios

    fed = Federation(latency=dict(delay_s=0.01), round_deadline_s=2.0)
    session = fed.create_session(...)
    report = scenarios.play(
        session, train_fn,
        events=[scenarios.partition([["c0", "c1"], ["c2", "c3"]],
                                    t0=2.0, t1=5.0),
                scenarios.flaky_link("c4", p=0.3, delay_s=0.2),
                scenarios.churn(fail_at={3: ["c5"]}, join_at={5: ["c9"]})],
        rounds=8, round_time_s=1.0,
        initial_params=init)

``play`` drives a ``step_time``-paced round loop: each round's training and
publishes are enqueued with the clock **held**, then virtual time advances
in ``round_time_s`` strides — deliveries and control-plane timers (round
deadlines, partition windows) fire strictly in timestamp order, so messages
genuinely reorder, partitioned traffic waits for heal, and deadline cuts
land between deliveries exactly as they would on a real edge network.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.ft.failures import FailurePlan


# ---------------------------------------------------------------------------
# Scenario events
# ---------------------------------------------------------------------------

class ScenarioEvent:
    """Base: ``arm`` schedules time-driven triggers; ``apply_round`` fires
    once per round launch (before training)."""

    def arm(self, session) -> None:  # pragma: no cover - trivial default
        pass

    def apply_round(self, session, round_idx: int) -> None:
        pass


@dataclass
class Partition(ScenarioEvent):
    """Cut connectivity between client groups during ``[t0, t1)`` virtual
    seconds.  ``t1=None`` leaves the partition open until an explicit
    ``transport.heal()``.  Clients not named in any group (coordinator,
    parameter server, ...) keep full connectivity unless listed."""
    groups: Sequence[Sequence[str]]
    t0: float = 0.0
    t1: Optional[float] = None

    def arm(self, session) -> None:
        transport = session.federation.transport
        clock = session.federation.clock
        clock.schedule(self.t0,
                       lambda: transport.partition(*self.groups), timer=True)
        if self.t1 is not None:
            clock.schedule(self.t1, transport.heal, timer=True)


@dataclass
class FlakyLink(ScenarioEvent):
    """Degrade one client's link (loss probability ``p``, duplication
    probability ``dup_p`` for at-least-once redelivery, optional extra
    delay/jitter) during ``[t0, t1)``; restores the previous model at t1."""
    client_id: str
    p: float = 0.0
    delay_s: float = 0.0
    jitter_s: float = 0.0
    dup_p: float = 0.0
    t0: float = 0.0
    t1: Optional[float] = None

    def arm(self, session) -> None:
        transport = session.federation.transport
        clock = session.federation.clock
        saved: list = []

        def degrade():
            saved.append(transport.links.get(self.client_id))
            transport.set_link(self.client_id, delay_s=self.delay_s,
                               jitter_s=self.jitter_s, drop_p=self.p,
                               dup_p=self.dup_p)

        def restore():
            prev = saved.pop() if saved else None
            if prev is None:
                transport.clear_link(self.client_id)
            else:
                transport.links[self.client_id] = prev

        clock.schedule(self.t0, degrade, timer=True)
        if self.t1 is not None:
            clock.schedule(self.t1, restore, timer=True)


@dataclass
class Churn(ScenarioEvent):
    """Round-driven membership churn from a ``FailurePlan``: at round ``r``
    fail ``plan.fail_at[r]`` abnormally (LWT fires), join
    ``plan.join_at[r]`` elastically, and slow ``plan.straggle_at[r]``
    (extra per-link delay for that round only)."""
    plan: FailurePlan
    _slowed: dict = field(default_factory=dict)

    def apply_round(self, session, round_idx: int) -> None:
        transport = session.federation.transport
        clock = session.federation.clock
        # restore last round's stragglers
        for cid, prev in self._slowed.items():
            if prev is None:
                transport.clear_link(cid)
            else:
                transport.links[cid] = prev
        self._slowed = {}
        changed = False
        for cid in self.plan.fail_at.get(round_idx, []):
            if cid in session.participants:
                session.fail(cid)
                changed = True
        for cid in self.plan.join_at.get(round_idx, []):
            session.join(session.federation.client(cid))
            changed = True
        if changed:
            # settle the rearrangement handshake before training starts, so
            # churn applies at the round boundary (not mid-flight)
            clock.run_until_idle()
        for cid, extra in self.plan.straggle_at.get(round_idx, {}).items():
            if cid not in session.participants:
                continue
            self._slowed[cid] = transport.links.get(cid)
            transport.set_link(cid, delay_s=extra)


# ---- builders (the declarative surface) -----------------------------------

def partition(groups: Sequence[Sequence[str]], t0: float = 0.0,
              t1: Optional[float] = None) -> Partition:
    return Partition(groups, t0, t1)


def flaky_link(client_id: str, p: float = 0.0, delay_s: float = 0.0,
               jitter_s: float = 0.0, dup_p: float = 0.0, t0: float = 0.0,
               t1: Optional[float] = None) -> FlakyLink:
    return FlakyLink(client_id, p, delay_s, jitter_s, dup_p, t0, t1)


def churn(plan: Optional[FailurePlan] = None, *,
          fail_at: Optional[dict] = None, join_at: Optional[dict] = None,
          straggle_at: Optional[dict] = None) -> Churn:
    if plan is None:
        plan = FailurePlan(fail_at=fail_at or {}, join_at=join_at or {},
                           straggle_at=straggle_at or {})
    return Churn(plan)


# ---------------------------------------------------------------------------
# The scenario runner
# ---------------------------------------------------------------------------

@dataclass
class ScenarioReport:
    rounds_launched: int = 0
    rounds_completed: int = 0
    final_state: str = ""
    virtual_time_s: float = 0.0
    deadline_cuts: int = 0
    stale_dropped: int = 0
    partition_held: int = 0
    partition_dropped: int = 0
    stalled: bool = False
    timeline: list = field(default_factory=list)   # (t, event) breadcrumbs


def play_async(session, train_fn: Callable,
               events: Sequence[ScenarioEvent] = (),
               target_version: Optional[int] = None,
               max_time_s: float = 600.0, initial_params=None):
    """Drive an ``AsyncFederatedSession`` through its K-of-N pacing loop
    with scenario ``events`` armed.  Time-driven events (partitions, flaky
    links) fire on the virtual clock exactly as in ``play``; round-driven
    events (churn) fire once per minted *global version* instead of per
    synchronous round.  Returns the session's ``AsyncReport`` (versions
    minted, admitted/stale-rejected contributions, gossip counters,
    virtual time, timeline)."""
    from repro.api.async_fl import AsyncFederatedSession
    assert isinstance(session, AsyncFederatedSession), \
        "play_async drives async sessions; use play() for synchronous ones"
    return session.run_async(train_fn, target_version=target_version,
                             max_time_s=max_time_s, events=events,
                             initial_params=initial_params)


def play(session, train_fn: Callable, events: Sequence[ScenarioEvent] = (),
         rounds: Optional[int] = None, round_time_s: float = 1.0,
         initial_params=None, stats_fn: Optional[Callable] = None,
         max_idle_steps: int = 50) -> ScenarioReport:
    """Drive ``session`` through a virtual-time round loop with ``events``
    armed.  Each newly started round is trained + published immediately,
    then the clock advances in ``round_time_s`` strides until the session
    terminates, ``rounds`` rounds have launched, or no progress is made for
    ``max_idle_steps`` strides (e.g. an unhealed partition with no round
    deadline) — then ``report.stalled`` is set."""
    fed = session.federation
    clock = fed.clock
    report = ScenarioReport()
    if initial_params is not None:
        session._initial = initial_params
    for ev in events:
        ev.arm(session)
    launched = -1
    idle = 0
    with clock.hold():
        while session.state == "running":
            r = session.round_idx
            if rounds is not None and report.rounds_launched >= rounds \
                    and r != launched:
                break
            if r != launched:
                for ev in events:
                    ev.apply_round(session, r)
                if session.state != "running" or not session.participants:
                    break
                session.run_round_async(train_fn, stats_fn=stats_fn)
                launched = r
                report.rounds_launched += 1
                report.timeline.append((round(clock.now, 6), f"round {r}"))
                idle = 0
            clock.advance(round_time_s)
            if session.round_idx == launched:
                idle += 1
                if idle >= max_idle_steps:
                    report.stalled = True
                    break
    fed.deliver()
    report.rounds_completed = session.round_idx
    report.final_state = session.state
    report.virtual_time_s = clock.now
    coord = fed.coordinator
    report.deadline_cuts = coord.deadline_cuts
    transport = fed.transport
    report.partition_held = getattr(transport, "partition_held", 0)
    report.partition_dropped = getattr(transport, "partition_dropped", 0)
    report.stale_dropped = sum(
        cl.models.sessions[session.session_id].stale_dropped
        for cl in session.participants.values()
        if session.session_id in cl.models.sessions)
    if fed.obs is not None:
        # trace-derived timeline (the same events /metrics counts): labeled
        # control-plane events — round starts/completions, partitions,
        # heals, deadline cuts, mints — in virtual-time order.  The bare
        # "round N" breadcrumbs are preserved when metrics are off, keeping
        # the default bit-identical.
        report.timeline = fed.obs.tracer.timeline()
    return report
