"""Declarative edge-network scenarios over the virtual clock.

Scenario builders turn "what goes wrong" into armed events on a
federation's ``SimClock`` (time-driven: partitions, flaky links) or its
round loop (round-driven churn, layered on ``ft.failures.FailurePlan``)::

    from repro.api import Federation, scenarios

    fed = Federation(latency=dict(delay_s=0.01), round_deadline_s=2.0)
    session = fed.create_session(...)
    report = scenarios.play(
        session, train_fn,
        events=[scenarios.partition([["c0", "c1"], ["c2", "c3"]],
                                    t0=2.0, t1=5.0),
                scenarios.flaky_link("c4", p=0.3, delay_s=0.2),
                scenarios.churn(fail_at={3: ["c5"]}, join_at={5: ["c9"]})],
        rounds=8, round_time_s=1.0,
        initial_params=init)

``play`` drives a ``step_time``-paced round loop: each round's training and
publishes are enqueued with the clock **held**, then virtual time advances
in ``round_time_s`` strides — deliveries and control-plane timers (round
deadlines, partition windows) fire strictly in timestamp order, so messages
genuinely reorder, partitioned traffic waits for heal, and deadline cuts
land between deliveries exactly as they would on a real edge network.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.ft.failures import FailurePlan


def _amap(fn, *trees):
    """Elementwise map over parallel params pytrees (dict/list/tuple/leaf)."""
    t0 = trees[0]
    if isinstance(t0, dict):
        return {k: _amap(fn, *(t[k] for t in trees)) for k in t0}
    if isinstance(t0, (list, tuple)):
        return type(t0)(_amap(fn, *vals) for vals in zip(*trees))
    return fn(*trees)


def _copy_tree(params):
    return _amap(lambda v: np.array(v), params)


# ---------------------------------------------------------------------------
# Scenario events
# ---------------------------------------------------------------------------

class ScenarioEvent:
    """Base: ``arm`` schedules time-driven triggers; ``apply_round`` fires
    once per round launch (before training)."""

    def arm(self, session) -> None:  # pragma: no cover - trivial default
        pass

    def apply_round(self, session, round_idx: int) -> None:
        pass


@dataclass
class Partition(ScenarioEvent):
    """Cut connectivity between client groups during ``[t0, t1)`` virtual
    seconds.  ``t1=None`` leaves the partition open until an explicit
    ``transport.heal()``.  Clients not named in any group (coordinator,
    parameter server, ...) keep full connectivity unless listed."""
    groups: Sequence[Sequence[str]]
    t0: float = 0.0
    t1: Optional[float] = None

    def arm(self, session) -> None:
        transport = session.federation.transport
        clock = session.federation.clock
        clock.schedule(self.t0,
                       lambda: transport.partition(*self.groups), timer=True)
        if self.t1 is not None:
            clock.schedule(self.t1, transport.heal, timer=True)


def _link_endpoints(spec) -> list:
    """Normalize a flaky-link spec — one client id, a list of ids, or a list
    of ``(a, b)`` link pairs (both endpoints degraded) — to client ids."""
    items = [spec] if isinstance(spec, str) else list(spec)
    out: list = []
    for item in items:
        ids = [item] if isinstance(item, str) else list(item)
        for cid in ids:
            if cid not in out:
                out.append(cid)
    return out


@dataclass
class FlakyLink(ScenarioEvent):
    """Degrade client links (loss probability ``p``, duplication probability
    ``dup_p`` for at-least-once redelivery, optional extra delay/jitter)
    during ``[t0, t1)``; restores the previous models at t1.  ``clients``
    accepts one client id, a list of ids, or ``(a, b)`` link pairs — so one
    builder can degrade a whole cluster's links."""
    clients: Union[str, Sequence]
    p: float = 0.0
    delay_s: float = 0.0
    jitter_s: float = 0.0
    dup_p: float = 0.0
    t0: float = 0.0
    t1: Optional[float] = None

    def arm(self, session) -> None:
        transport = session.federation.transport
        clock = session.federation.clock
        ids = _link_endpoints(self.clients)
        saved: dict = {}

        def degrade():
            for cid in ids:
                saved[cid] = transport.links.get(cid)
                transport.set_link(cid, delay_s=self.delay_s,
                                   jitter_s=self.jitter_s, drop_p=self.p,
                                   dup_p=self.dup_p)

        def restore():
            for cid in ids:
                prev = saved.pop(cid, None)
                if prev is None:
                    transport.clear_link(cid)
                else:
                    transport.links[cid] = prev

        clock.schedule(self.t0, degrade, timer=True)
        if self.t1 is not None:
            clock.schedule(self.t1, restore, timer=True)


@dataclass
class Churn(ScenarioEvent):
    """Round-driven membership churn from a ``FailurePlan``: at round ``r``
    fail ``plan.fail_at[r]`` abnormally (LWT fires), join
    ``plan.join_at[r]`` elastically, and slow ``plan.straggle_at[r]``
    (extra per-link delay for that round only)."""
    plan: FailurePlan
    _slowed: dict = field(default_factory=dict)

    def apply_round(self, session, round_idx: int) -> None:
        transport = session.federation.transport
        clock = session.federation.clock
        # restore last round's stragglers
        for cid, prev in self._slowed.items():
            if prev is None:
                transport.clear_link(cid)
            else:
                transport.links[cid] = prev
        self._slowed = {}
        changed = False
        for cid in self.plan.fail_at.get(round_idx, []):
            if cid in session.participants:
                session.fail(cid)
                changed = True
        for cid in self.plan.join_at.get(round_idx, []):
            session.join(session.federation.client(cid))
            changed = True
        if changed:
            # settle the rearrangement handshake before training starts, so
            # churn applies at the round boundary (not mid-flight)
            clock.run_until_idle()
        for cid, extra in self.plan.straggle_at.get(round_idx, {}).items():
            if cid not in session.participants:
                continue
            self._slowed[cid] = transport.links.get(cid)
            transport.set_link(cid, delay_s=extra)


# ---------------------------------------------------------------------------
# Adversarial events (malicious clients, not just faulty links)
# ---------------------------------------------------------------------------

@dataclass
class Attack(ScenarioEvent):
    """Base for adversarial clients: ``transform_update`` rewrites what an
    attacker-controlled client publishes for a round.  ``play``/``play_async``
    wrap the caller's ``train_fn`` so every attack sees (and may replace) the
    honest update before it hits the wire — deterministic, seeded only by the
    builder's own parameters, and composable with partitions/churn/flaky
    links.  Each injection emits an ``attack_injected`` trace through the
    federation's telemetry (when metrics are on) and bumps ``injected``."""
    clients: Sequence[str] = ()
    start_round: int = 0
    end_round: Optional[int] = None
    injected: int = field(default=0, init=False)

    kind = "attack"                     # class attr, not a dataclass field

    def _active(self, round_idx: int) -> bool:
        return (round_idx >= self.start_round
                and (self.end_round is None or round_idx < self.end_round))

    def targets(self, client_id: str) -> bool:
        return client_id in self.clients

    def transform_update(self, session, round_idx: int, client_id: str,
                         params, weight, global_params):
        """Return ``(params, weight)`` to replace the honest update, or
        ``None`` to leave it untouched this round."""
        raise NotImplementedError

    def maybe_transform(self, session, round_idx: int, client_id: str,
                        params, weight, global_params):
        if not self._active(round_idx) or not self.targets(client_id):
            return None
        out = self.transform_update(session, round_idx, client_id,
                                    params, weight, global_params)
        if out is not None:
            self.injected += 1
            obs = session.federation.obs
            if obs is not None:
                obs.trace("attack_injected", session=session.session_id,
                          attack=self.kind, client=client_id,
                          round=round_idx)
        return out


@dataclass
class LabelFlip(Attack):
    """Label-flip poisoning: the attacker trains against inverted labels,
    modeled as publishing the *inverted* update ``g - flip_scale*(p - g)``
    (it pulls the global exactly opposite to its honest gradient)."""
    flip_scale: float = 1.0

    kind = "label_flip"

    def transform_update(self, session, round_idx, client_id,
                         params, weight, global_params):
        s = self.flip_scale
        if global_params is None:
            return _amap(lambda v: np.asarray(
                -s * np.asarray(v, np.float64), np.asarray(v).dtype),
                params), weight
        def flip(v, gv):
            v = np.asarray(v)
            g64 = np.asarray(gv, np.float64)
            return np.asarray(g64 - s * (np.asarray(v, np.float64) - g64),
                              v.dtype)
        return _amap(flip, params, global_params), weight


@dataclass
class ScalePoison(Attack):
    """Model-poisoning by update inflation: publishes ``g + lam*(p - g)`` —
    the honest delta scaled ×``lam`` (boosted/model-replacement attack)."""
    lam: float = 10.0

    kind = "scale_poison"

    def transform_update(self, session, round_idx, client_id,
                         params, weight, global_params):
        lam = self.lam
        if global_params is None:
            return _amap(lambda v: np.asarray(
                lam * np.asarray(v, np.float64), np.asarray(v).dtype),
                params), weight
        def scale(v, gv):
            v = np.asarray(v)
            g64 = np.asarray(gv, np.float64)
            return np.asarray(g64 + lam * (np.asarray(v, np.float64) - g64),
                              v.dtype)
        return _amap(scale, params, global_params), weight


@dataclass
class FreeRider(Attack):
    """Free-riding: contribute nothing while claiming sample weight.
    ``mode="zero"`` republishes the current global (a zero update);
    ``mode="replay"`` replays the client's own stale round-0 update forever
    (first round trains honestly to have something to replay)."""
    mode: str = "zero"
    _cache: dict = field(default_factory=dict, init=False)

    kind = "free_rider"

    def transform_update(self, session, round_idx, client_id,
                         params, weight, global_params):
        if self.mode == "replay":
            hit = self._cache.get(client_id)
            if hit is None:
                self._cache[client_id] = (_copy_tree(params), weight)
                return None                 # honest once, stale forever after
            stale_p, stale_w = hit
            return _copy_tree(stale_p), stale_w
        if global_params is None:
            return _amap(lambda v: np.zeros_like(np.asarray(v)), params), \
                weight
        return _copy_tree(global_params), weight


@dataclass
class SybilFlood(Attack):
    """Sybil join flood: at round ``at_round`` mint ``count`` fresh client
    identities and push them through the elastic-join path; every admitted
    sybil then publishes scaled-poison updates (×``lam``).  The flood both
    stresses admission/rearrangement and hands the robust combines a
    colluding majority-attempt to reject."""
    count: int = 3
    at_round: int = 1
    lam: float = 5.0
    prefix: str = "sybil"
    joined: list = field(default_factory=list, init=False)

    kind = "sybil_flood"

    def targets(self, client_id: str) -> bool:
        return client_id in self.joined or client_id in self.clients

    def apply_round(self, session, round_idx: int) -> None:
        if round_idx != self.at_round:
            return
        obs = session.federation.obs
        for i in range(self.count):
            cid = f"{self.prefix}{i}"
            if session.join(cid):
                self.joined.append(cid)
                self.injected += 1
                if obs is not None:
                    obs.trace("attack_injected", session=session.session_id,
                              attack=self.kind, client=cid, round=round_idx)

    def transform_update(self, session, round_idx, client_id,
                         params, weight, global_params):
        lam = self.lam
        if global_params is None:
            return _amap(lambda v: np.asarray(
                lam * np.asarray(v, np.float64), np.asarray(v).dtype),
                params), weight
        def scale(v, gv):
            v = np.asarray(v)
            g64 = np.asarray(gv, np.float64)
            return np.asarray(g64 + lam * (np.asarray(v, np.float64) - g64),
                              v.dtype)
        return _amap(scale, params, global_params), weight


def wrap_attacks(session, train_fn: Callable,
                 events: Sequence[ScenarioEvent]) -> Callable:
    """Wrap ``train_fn`` so armed ``Attack`` events rewrite attacker-
    controlled updates before publish.  Attacks compose in event order
    (later attacks see earlier attacks' output).  No attacks → the original
    ``train_fn`` is returned unchanged (bit-identical clean runs)."""
    attacks = [ev for ev in events if isinstance(ev, Attack)]
    if not attacks:
        return train_fn

    def attacked(client_id, global_params, round_idx):
        params, weight = train_fn(client_id, global_params, round_idx)
        for atk in attacks:
            out = atk.maybe_transform(session, round_idx, client_id,
                                      params, weight, global_params)
            if out is not None:
                params, weight = out
        return params, weight

    return attacked


# ---- builders (the declarative surface) -----------------------------------

def partition(groups: Sequence[Sequence[str]], t0: float = 0.0,
              t1: Optional[float] = None) -> Partition:
    return Partition(groups, t0, t1)


def flaky_link(clients: Union[str, Sequence], p: float = 0.0,
               delay_s: float = 0.0, jitter_s: float = 0.0,
               dup_p: float = 0.0, t0: float = 0.0,
               t1: Optional[float] = None) -> FlakyLink:
    """``clients``: one id, a list of ids, or ``(a, b)`` link pairs."""
    return FlakyLink(clients, p, delay_s, jitter_s, dup_p, t0, t1)


def label_flip(clients: Sequence[str], flip_scale: float = 1.0,
               start_round: int = 0,
               end_round: Optional[int] = None) -> LabelFlip:
    return LabelFlip(list(clients), start_round, end_round, flip_scale)


def scale_poison(clients: Sequence[str], lam: float = 10.0,
                 start_round: int = 0,
                 end_round: Optional[int] = None) -> ScalePoison:
    return ScalePoison(list(clients), start_round, end_round, lam)


def free_rider(clients: Sequence[str], mode: str = "zero",
               start_round: int = 0,
               end_round: Optional[int] = None) -> FreeRider:
    assert mode in ("zero", "replay"), mode
    return FreeRider(list(clients), start_round, end_round, mode)


def sybil_flood(count: int = 3, at_round: int = 1, lam: float = 5.0,
                prefix: str = "sybil",
                end_round: Optional[int] = None) -> SybilFlood:
    return SybilFlood([], 0, end_round, count, at_round, lam, prefix)


def churn(plan: Optional[FailurePlan] = None, *,
          fail_at: Optional[dict] = None, join_at: Optional[dict] = None,
          straggle_at: Optional[dict] = None) -> Churn:
    if plan is None:
        plan = FailurePlan(fail_at=fail_at or {}, join_at=join_at or {},
                           straggle_at=straggle_at or {})
    return Churn(plan)


# ---------------------------------------------------------------------------
# The scenario runner
# ---------------------------------------------------------------------------

@dataclass
class ScenarioReport:
    rounds_launched: int = 0
    rounds_completed: int = 0
    final_state: str = ""
    virtual_time_s: float = 0.0
    deadline_cuts: int = 0
    stale_dropped: int = 0
    partition_held: int = 0
    partition_dropped: int = 0
    stalled: bool = False
    timeline: list = field(default_factory=list)   # (t, event) breadcrumbs


def play_async(session, train_fn: Callable,
               events: Sequence[ScenarioEvent] = (),
               target_version: Optional[int] = None,
               max_time_s: float = 600.0, initial_params=None):
    """Drive an ``AsyncFederatedSession`` through its K-of-N pacing loop
    with scenario ``events`` armed.  Time-driven events (partitions, flaky
    links) fire on the virtual clock exactly as in ``play``; round-driven
    events (churn) fire once per minted *global version* instead of per
    synchronous round.  Returns the session's ``AsyncReport`` (versions
    minted, admitted/stale-rejected contributions, gossip counters,
    virtual time, timeline)."""
    from repro.api.async_fl import AsyncFederatedSession
    assert isinstance(session, AsyncFederatedSession), \
        "play_async drives async sessions; use play() for synchronous ones"
    train_fn = wrap_attacks(session, train_fn, events)
    return session.run_async(train_fn, target_version=target_version,
                             max_time_s=max_time_s, events=events,
                             initial_params=initial_params)


def play(session, train_fn: Callable, events: Sequence[ScenarioEvent] = (),
         rounds: Optional[int] = None, round_time_s: float = 1.0,
         initial_params=None, stats_fn: Optional[Callable] = None,
         max_idle_steps: int = 50) -> ScenarioReport:
    """Drive ``session`` through a virtual-time round loop with ``events``
    armed.  Each newly started round is trained + published immediately,
    then the clock advances in ``round_time_s`` strides until the session
    terminates, ``rounds`` rounds have launched, or no progress is made for
    ``max_idle_steps`` strides (e.g. an unhealed partition with no round
    deadline) — then ``report.stalled`` is set."""
    fed = session.federation
    clock = fed.clock
    report = ScenarioReport()
    if initial_params is not None:
        session._initial = initial_params
    train_fn = wrap_attacks(session, train_fn, events)
    for ev in events:
        ev.arm(session)
    launched = -1
    idle = 0
    with clock.hold():
        while session.state == "running":
            r = session.round_idx
            if rounds is not None and report.rounds_launched >= rounds \
                    and r != launched:
                break
            if r != launched:
                for ev in events:
                    ev.apply_round(session, r)
                if session.state != "running" or not session.participants:
                    break
                session.run_round_async(train_fn, stats_fn=stats_fn)
                launched = r
                report.rounds_launched += 1
                report.timeline.append((round(clock.now, 6), f"round {r}"))
                idle = 0
            clock.advance(round_time_s)
            if session.round_idx == launched:
                idle += 1
                if idle >= max_idle_steps:
                    report.stalled = True
                    break
    fed.deliver()
    report.rounds_completed = session.round_idx
    report.final_state = session.state
    report.virtual_time_s = clock.now
    coord = fed.coordinator
    report.deadline_cuts = coord.deadline_cuts
    transport = fed.transport
    report.partition_held = getattr(transport, "partition_held", 0)
    report.partition_dropped = getattr(transport, "partition_dropped", 0)
    report.stale_dropped = sum(
        cl.models.sessions[session.session_id].stale_dropped
        for cl in session.participants.values()
        if session.session_id in cl.models.sessions)
    if fed.obs is not None:
        # trace-derived timeline (the same events /metrics counts): labeled
        # control-plane events — round starts/completions, partitions,
        # heals, deadline cuts, mints — in virtual-time order.  The bare
        # "round N" breadcrumbs are preserved when metrics are off, keeping
        # the default bit-identical.
        report.timeline = fed.obs.tracer.timeline()
    return report
