"""Asynchronous federation: bounded-staleness FedBuff buffers, head gossip
under partitions, per-client round pacing — on the discrete-event substrate.

The synchronous round protocol (paper §III-E) blocks every round on the
slowest client.  ``AsyncFederatedSession`` removes the barrier while keeping
the whole cluster-tree data plane:

  * **Bounded-staleness aggregation** (FedBuff, Nguyen et al. 2022): every
    aggregator duty guards its streaming flat-f64 accumulator
    (``core.client._Accumulator`` — the buffer itself stays in-place and
    zero-copy) with an ``AsyncBuffer`` that admits *round-stamped*
    contributions.  A contribution trained ``s`` global versions ago is
    rejected when ``s > staleness_bound`` and otherwise admitted at weight
    ``w * discount(s)`` (constant or polynomial ``(1+s)^-a``, pluggable via
    the strategy's ``staleness_discount`` hook or ``AsyncConfig``).  The
    root mints a new global whenever ``buffer_k`` contributions have landed
    — K-of-N instead of the full cohort; intermediate heads forward their
    partial once a proportional share of their cluster has reported.  With
    ``buffer_k = cohort`` and an unlimited bound the trigger points and the
    accumulation order coincide exactly with the synchronous path, so the
    async globals are bit-identical to ``run_round`` (tested).

  * **Per-client pacing**: each client schedules its own next-round start
    on the shared ``SimClock`` (heterogeneous periods + seeded jitter), so
    client cadence is decoupled from any coordinator barrier.  Stragglers
    contribute late-but-stamped instead of blocking the federation.

  * **Head gossip**: cluster heads periodically publish their current model
    view on ``sdflmq/session/<sid>/gossip/<cid>`` (QoS 1).  When a head
    flushes a partial it also blends the buffer mean into its own view (a
    *site model*, stamped ``(version, site_seq)``), so during a
    ``partition()`` the side that lost the root keeps converging on gossip
    exchanges while the root's side keeps minting real globals.  Receivers
    adopt strictly-newer versions, average same-version site models, and on
    ``heal()`` the round-stamped rules reconcile both sides: held globals
    win, held contributions past the staleness bound are rejected and
    counted.

Everything runs on virtual time: two runs with the same seeds produce
bit-identical globals and identical event schedules.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.api.federation import FederatedSession, TrainFn


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclass
class AsyncConfig:
    """Knobs of one asynchronous session (serialized into the retained
    topology broadcast, so every aggregator applies the same admission
    rules — ``cohort`` is stamped in by the coordinator).

    Pass an instance (or a dict of these fields, or ``True`` for the
    defaults) as ``create_session(..., async_mode=...)`` to switch a
    session to K-of-N FedBuff federation:

    >>> from repro.api import AsyncConfig
    >>> cfg = AsyncConfig(buffer_k=3, staleness_bound=2,
    ...                   base_period_s=0.5)
    >>> wire = cfg.to_wire()          # the admission-relevant subset
    >>> wire["k"], wire["bound"]
    (3, 2)
    >>> AsyncConfig().staleness_bound is None     # default: unbounded
    True
    """

    buffer_k: int = 2                 # contributions that trigger a global
    staleness_bound: Optional[int] = None   # None = unbounded
    staleness_weight: str = "strategy"      # strategy | constant | poly
    poly_a: float = 0.5               # exponent of the poly discount
    base_period_s: float = 1.0        # default per-client pacing period
    period_jitter_s: float = 0.0      # uniform jitter added to each gap
    periods: dict = field(default_factory=dict)   # per-client overrides
    seed: int = 0                     # pacing-jitter RNG seed
    gossip_period_s: float = 0.0      # 0 = head gossip off
    gossip_alpha: float = 0.5         # site-model blend factor

    def to_wire(self) -> dict:
        """The admission-relevant subset every aggregator needs."""
        return {"k": int(self.buffer_k), "bound": self.staleness_bound,
                "weight": self.staleness_weight, "poly_a": float(self.poly_a),
                "gossip_period_s": float(self.gossip_period_s),
                "gossip_alpha": float(self.gossip_alpha)}


def resolve_discount(acfg: dict, strat) -> Callable[[int], float]:
    """Staleness-discount weight function for one admission point."""
    kind = acfg.get("weight", "strategy")
    if kind == "strategy":
        return strat.staleness_discount
    if kind == "constant":
        return lambda s: 1.0
    if kind == "poly":
        a = float(acfg.get("poly_a", 0.5))
        return lambda s: (1.0 + float(max(0, s))) ** (-a)
    raise KeyError(f"unknown staleness weight {kind!r} "
                   "(have: strategy, constant, poly)")


def head_share(expected: int, k: int, cohort: int) -> int:
    """Flush trigger (in received messages) for a non-root duty: the
    cluster's proportional share of the K-of-N buffer.  With k = cohort
    this is exactly ``expected`` — the synchronous trigger."""
    return max(1, min(int(expected), -(-int(expected) * int(k)
                                       // max(int(cohort), 1))))


# ---------------------------------------------------------------------------
# The FedBuff admission gate
# ---------------------------------------------------------------------------

class AsyncBuffer:
    """Bounded-staleness admission metadata over ONE streaming accumulator
    (``core.client._Accumulator``).  The tensors live in the accumulator's
    preallocated flat buffer; this class only tracks how many *leaf*
    contributions the buffer represents, the oldest admitted stamp, and the
    rejection count — enough for K-of-N triggering and stamped partials."""

    __slots__ = ("acc", "contribs", "min_stamp", "rejected_stale", "flushes",
                 "discount")

    def __init__(self, acc, acfg: Optional[dict] = None, strat=None):
        self.acc = acc
        self.rejected_stale = 0        # lifetime, across cycles
        self.flushes = 0
        # resolved once per duty, not per message (admission hot path)
        self.discount: Callable[[int], float] = (
            resolve_discount(acfg, strat) if acfg is not None
            else (lambda s: 1.0))
        self.start_cycle()

    def start_cycle(self) -> None:
        self.contribs = 0              # leaf contributions this cycle
        self.min_stamp: Optional[int] = None

    def note_stamp(self, stamp: int) -> None:
        self.min_stamp = stamp if self.min_stamp is None \
            else min(self.min_stamp, stamp)


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

@dataclass
class AsyncReport:
    """Counters of one ``run_async`` drive (all on the virtual clock)."""
    updates: int = 0                  # global versions minted
    admitted: int = 0                 # leaf contributions admitted
    rejected_stale: int = 0           # contributions past the bound
    gossip_sent: int = 0
    gossip_adopts: int = 0            # newer-version adoptions
    gossip_merges: int = 0            # same-version site-model averages
    site_updates: int = 0             # site models minted by heads
    virtual_time_s: float = 0.0
    final_state: str = ""
    stalled: bool = False             # no event left before termination
    timed_out: bool = False           # max_time_s exhausted
    partition_held: int = 0
    partition_dropped: int = 0
    timeline: list = field(default_factory=list)   # (t, version)


# ---------------------------------------------------------------------------
# The session handle
# ---------------------------------------------------------------------------

class AsyncFederatedSession(FederatedSession):
    """Handle to one asynchronous FL session.  Create it through
    ``Federation.create_session(..., async_mode=AsyncConfig(...))`` (or a
    plain dict of the same fields), then drive it with ``run_async``::

        session = fed.create_session("s", "m", rounds=20, participants=cs,
                                     async_mode=dict(buffer_k=3,
                                                     staleness_bound=4))
        report = session.run_async(train, initial_params=init)

    ``rounds`` becomes the target number of *global versions*: the
    coordinator watches the global topic and terminates the session once
    version ``rounds`` has been minted."""

    def __init__(self, federation, session_id: str, model_name: str,
                 strategy, cfg: AsyncConfig):
        super().__init__(federation, session_id, model_name, strategy)
        self.cfg = cfg
        self._pacers: dict = {}
        self._gossipers: dict = {}
        self._train_fn: Optional[TrainFn] = None

    # -- the synchronous round loop does not apply ------------------------
    def run_round(self, *a, **kw):  # pragma: no cover - guard rail
        raise RuntimeError("async session: drive it with run_async() "
                           "(there is no synchronous round barrier)")

    run_round_async = run_round
    run = run_round

    # ------------------------------------------------------------------
    # Per-client pacing
    # ------------------------------------------------------------------
    def _period_for(self, cid: str) -> float:
        return float(self.cfg.periods.get(cid, self.cfg.base_period_s))

    def _jitter_for(self, cid: str) -> Optional[Callable[[], float]]:
        if self.cfg.period_jitter_s <= 0:
            return None
        rng = random.Random(f"{self.cfg.seed}/pace/{cid}")
        return lambda: rng.uniform(0.0, self.cfg.period_jitter_s)

    def _fire(self, cid: str):
        """One pacing tick: train against the client's current model view
        (global or gossip site model), publish stamped with the version the
        training started from.  Returning False cancels the timer series."""
        if self.state != "running" or cid not in self.participants:
            return False
        cl = self.participants[cid]
        ctx = cl.models.sessions.get(self.session_id)
        if ctx is None or ctx.terminated:
            return False
        base = ctx.view_params if ctx.view_params is not None else self._initial
        obs = self.federation.obs
        if obs is not None:
            obs.trace("train", session=self.session_id, client=cid,
                      version=ctx.global_version)
        params, n_samples = self._train_fn(cid, base, ctx.global_version)
        cl.set_model(self.session_id, params, n_samples=n_samples)
        cl.send_local(self.session_id)
        return True

    def start_pacing(self, train_fn: Optional[TrainFn] = None) -> None:
        """Arm (or re-arm after churn) every participant's pacing timer.
        Idempotent: live timers are left untouched, so mid-run joiners get
        paced without disturbing existing cadences."""
        if train_fn is not None:
            self._train_fn = train_fn
        assert self._train_fn is not None, "start_pacing needs a train_fn"
        clock = self.federation.clock
        for cid in sorted(self.participants):
            t = self._pacers.get(cid)
            if t is not None and not t.cancelled:
                continue
            jf = self._jitter_for(cid)
            first = clock.now + (jf() if jf else 0.0)
            self._pacers[cid] = clock.schedule_periodic(
                self._period_for(cid), lambda c=cid: self._fire(c),
                first_at=first, jitter_fn=jf)

    # ------------------------------------------------------------------
    # Head gossip
    # ------------------------------------------------------------------
    def _gossip_fire(self, cid: str):
        if self.state != "running":
            return False
        cl = self.participants.get(cid)
        if cl is None:
            return False
        if cl.arbiter.is_aggregator:        # only current heads publish
            cl.gossip_publish(self.session_id)
        return True                          # stay armed across role churn

    def start_gossip(self) -> None:
        if self.cfg.gossip_period_s <= 0:
            return
        clock = self.federation.clock
        for cid in sorted(self.participants):
            t = self._gossipers.get(cid)
            if t is not None and not t.cancelled:
                continue
            self._gossipers[cid] = clock.schedule_periodic(
                self.cfg.gossip_period_s, lambda c=cid: self._gossip_fire(c))

    # ------------------------------------------------------------------
    # The drive loop
    # ------------------------------------------------------------------
    def run_async(self, train_fn: TrainFn,
                  target_version: Optional[int] = None,
                  max_time_s: float = 600.0,
                  events: Sequence = (),
                  initial_params=None) -> AsyncReport:
        """Hold the clock, pace every client, and advance virtual time
        event by event until the session terminates (coordinator observed
        ``rounds`` global versions), ``target_version`` is reached, or
        ``max_time_s`` virtual seconds elapse.  ``events`` are
        ``repro.api.scenarios`` events; round-driven ones (churn) fire per
        minted *version*."""
        if initial_params is not None:
            self._initial = initial_params
        fed = self.federation
        clock = fed.clock
        report = AsyncReport()
        tv = target_version if target_version is not None \
            else self._session.fl_rounds
        for ev in events:
            ev.arm(self)
        t_end = clock.now + float(max_time_s)
        with clock.hold():
            self.start_pacing(train_fn)
            self.start_gossip()
            last_v = self.global_version()
            while self.state == "running":
                if tv and self.global_version() >= tv:
                    break
                nxt = clock.next_event_time()
                if nxt is None:
                    report.stalled = True
                    break
                if nxt > t_end:
                    report.timed_out = True
                    break
                clock.advance_to(nxt)
                v = self.global_version()
                rearmed = False
                while last_v < v:
                    last_v += 1
                    report.timeline.append((round(clock.now, 6), last_v))
                    for ev in events:
                        ev.apply_round(self, last_v)
                        rearmed = True
                if rearmed:
                    self.start_pacing()      # pace clients churned in
                    self.start_gossip()
            if self.state == "running":
                # exiting with the session still live (target version,
                # timeout, stall): cancel the timer series so the shared
                # clock goes quiet — a later drive re-arms via start_pacing
                self.stop_pacing()
        fed.deliver()
        self._fill_report(report)
        if fed.obs is not None:
            # trace-derived timeline (same events /metrics sees): replaces
            # the bare (t, version) breadcrumbs with labeled control-plane
            # events — mints, partitions, heals, gossip — in virtual-time
            # order.  The breadcrumb shape is preserved when metrics are
            # off, keeping the default bit-identical.
            report.timeline = fed.obs.tracer.timeline()
        return report

    def stop_pacing(self) -> None:
        for t in list(self._pacers.values()) + list(self._gossipers.values()):
            t.cancel()
        self._pacers.clear()
        self._gossipers.clear()

    # ------------------------------------------------------------------
    def _fill_report(self, report: AsyncReport) -> None:
        report.updates = self.global_version()
        report.final_state = self.state
        report.virtual_time_s = self.federation.clock.now
        for cl in self.participants.values():
            ctx = cl.models.sessions.get(self.session_id)
            if ctx is None:
                continue
            report.admitted += ctx.async_admitted
            report.rejected_stale += ctx.async_rejected
            report.gossip_sent += ctx.gossip_sent
            report.gossip_adopts += ctx.gossip_adopts
            report.gossip_merges += ctx.gossip_merges
            report.site_updates += ctx.site_updates
        transport = self.federation.transport
        report.partition_held = getattr(transport, "partition_held", 0)
        report.partition_dropped = getattr(transport, "partition_dropped", 0)
