"""Oracle for the SSD-form selective-SSM scan: exact recurrence."""
from __future__ import annotations

from repro.models.linear_attn import recurrent


def ssm_ref(C, Bk, x, w_log, s0=None):
    """SSD: h_t = a_t h_{t-1} + (dt B_t) x_t^T; y_t = C_t^T h_t.
    C/Bk: (B,T,H,N); x: (B,T,H,hd); w_log: (B,T,H,1) scalar-per-head decay.
    Returns (y, h_final)."""
    return recurrent(C, Bk, x, w_log, u=None, s0=s0)
