"""SSD-form selective-SSM scan op: thin wrapper over the WKV6 Pallas kernel
with use_u=False (inclusive decay) — Hymba's SSM branch and RWKV6's WKV are
the same chunked decayed-linear-attention computation (DESIGN.md §6)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.ops import wkv


@functools.partial(jax.jit, static_argnames=("chunk", "force"))
def ssm_scan(C, Bk, x, w_log, s0=None, chunk: int = 64,
             force: str = "auto"):
    """C/Bk: (B,T,H,N); x: (B,T,H,hd); w_log: (B,T,H,1).
    Returns (y (B,T,H,hd), h_final (B,H,N,hd))."""
    return wkv(C, Bk, x, w_log, u=None, s0=s0, chunk=chunk, force=force)
