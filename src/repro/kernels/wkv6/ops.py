"""jit'd wrapper for the WKV6 kernel: (B,T,H,d) <-> (BH,T,d) plumbing +
platform dispatch (pallas on TPU / interpret validation / jnp chunked)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.wkv6 import DEFAULT_CHUNK, wkv_pallas
from repro.models.linear_attn import chunked as chunked_jnp


@functools.partial(jax.jit, static_argnames=("chunk", "force"))
def wkv(r, k, v, w_log, u=None, s0=None, chunk: int = DEFAULT_CHUNK,
        force: str = "auto"):
    """r,k: (B,T,H,dk); v: (B,T,H,dv); w_log broadcastable to r;
    u: (H,dk) or None (SSD convention).  Returns (o (B,T,H,dv), s_final)."""
    B, T, H, dk = r.shape
    dv = v.shape[3]
    use = force
    if use == "auto":
        use = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use == "ref":
        return chunked_jnp(r, k, v, w_log, u=u, s0=s0, chunk=chunk)

    w_full = jnp.broadcast_to(w_log, r.shape)
    def bh(x, d):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, d)
    rb, kb, wb = bh(r, dk), bh(k, dk), bh(w_full, dk)
    vb = bh(v, dv)
    if u is None:
        ub = jnp.zeros((B * H, dk), jnp.float32)
    else:
        ub = jnp.broadcast_to(u[None], (B, H, dk)).reshape(B * H, dk)
    if s0 is None:
        s0b = jnp.zeros((B * H, dk, dv), jnp.float32)
    else:
        s0b = s0.reshape(B * H, dk, dv)
    o, sf = wkv_pallas(rb, kb, vb, wb, ub, s0b, chunk=chunk,
                       use_u=u is not None,
                       interpret=jax.default_backend() != "tpu")
    o = o.reshape(B, H, T, dv).transpose(0, 2, 1, 3)
    return o, sf.reshape(B, H, dk, dv)
