"""Oracle for the WKV6 / SSD chunked linear-attention kernel: the exact
recurrent form from repro.models.linear_attn (time-step scan)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.linear_attn import chunked as chunked_jnp
from repro.models.linear_attn import recurrent


def wkv_ref(r, k, v, w_log, u=None, s0=None):
    """r,k: (B,T,H,dk); v: (B,T,H,dv); w_log broadcastable; u: (H,dk)|None.
    Returns (o, s_final) from the exact step-by-step recurrence."""
    return recurrent(r, k, v, w_log, u=u, s0=s0)


def wkv_chunked_jnp(r, k, v, w_log, u=None, s0=None, chunk=16):
    """The jnp chunked form (itself validated against ``recurrent``)."""
    return chunked_jnp(r, k, v, w_log, u=u, s0=s0, chunk=chunk)
