"""Pallas TPU kernel: chunked decayed linear attention (RWKV6 WKV / SSD).

One grid program per (batch x head): the (T, d) streams live in VMEM
(T=4096, d=64 -> 1 MiB per operand), the recurrent state (dk, dv) stays in
an f32 VMEM scratch across the chunk loop, and each chunk does O(C^2 d)
MXU work with the numerically-safe pairwise-decay-difference formulation
(all exponents <= 0; see models/linear_attn.py for the math).

Two static variants:
  * use_u=True  — RWKV6: bonus-u convention, exclusive decay;
  * use_u=False — SSD   : inclusive decay (Hymba's SSM branch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 64


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref,
                *, chunk: int, use_u: bool):
    T, dk = r_ref.shape[1], r_ref.shape[2]
    dv = v_ref.shape[2]
    C = chunk
    n = T // C
    lower = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])
    eye = jnp.eye(C, dtype=jnp.float32)

    def body(c, S):
        sl = pl.ds(c * C, C)
        rc = r_ref[0, sl, :].astype(jnp.float32)          # (C, dk)
        kc = k_ref[0, sl, :].astype(jnp.float32)
        vc = v_ref[0, sl, :].astype(jnp.float32)          # (C, dv)
        wc = w_ref[0, sl, :].astype(jnp.float32)          # (C, dk) log-decay
        cum = jnp.cumsum(wc, axis=0)
        base = (cum - wc) if use_u else cum
        # inter-chunk: state contribution
        q_eff = rc * jnp.exp(base)
        o_inter = q_eff @ S                               # (C, dv)
        # intra-chunk pairwise decay differences (<= 0 for s < t)
        diff = base[:, None, :] - cum[None, :, :]         # (C, C, dk)
        decay = jnp.where(lower[:, :, None], jnp.exp(diff), 0.0)
        A = jnp.einsum("td,sd,tsd->ts", rc, kc, decay)
        if use_u:
            diag = jnp.sum(rc * u_ref[0].astype(jnp.float32) * kc, axis=1)
        else:
            diag = jnp.sum(rc * kc, axis=1)
        A = A + diag[:, None] * eye
        o = o_inter + A @ vc
        o_ref[0, sl, :] = o.astype(o_ref.dtype)
        # state update
        cum_last = cum[-1]                                # (dk,)
        k_eff = kc * jnp.exp(cum_last[None, :] - cum)
        S_new = S * jnp.exp(cum_last)[:, None] + k_eff.T @ vc
        return S_new

    S = jax.lax.fori_loop(0, n, body, s0_ref[0].astype(jnp.float32))
    sf_ref[0] = S


def wkv_pallas(r, k, v, w_log, u, s0, chunk: int = DEFAULT_CHUNK,
               use_u: bool = True, interpret: bool = False):
    """r,k,w_log: (BH, T, dk); v: (BH, T, dv); u: (BH, dk); s0: (BH, dk, dv).
    Returns (o (BH,T,dv) in v.dtype, s_final (BH,dk,dv) f32)."""
    BH, T, dk = r.shape
    dv = v.shape[2]
    assert T % chunk == 0, (T, chunk)
    kern = functools.partial(_wkv_kernel, chunk=chunk, use_u=use_u)
    return pl.pallas_call(
        kern,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, T, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, T, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, T, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, T, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dk), lambda i: (i, 0)),
            pl.BlockSpec((1, dk, dv), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, dv), v.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w_log, u, s0)
