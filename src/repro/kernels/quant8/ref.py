"""Oracle for the int8 block-quantization kernel (pure jnp)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x: jnp.ndarray, block: int = 256):
    """x: (N,) with N % block == 0 -> (q int8 (N,), scales f32 (N/block,)).
    Symmetric per-block quantization."""
    xb = x.astype(jnp.float32).reshape(-1, block)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_ref(q: jnp.ndarray, scale: jnp.ndarray, block: int = 256):
    qb = q.reshape(-1, block).astype(jnp.float32)
    return (qb * scale[:, None]).reshape(-1)
