"""Pallas TPU kernels: symmetric per-block int8 quantize / dequantize.

The DCN-hop compression used by the ``compressed`` aggregation schedule
(MQTTFC zlib-compression analogue).  Tiles of (ROWS, BLOCK) live in VMEM;
each row yields one f32 scale.  Quantize reads bf16/f32 and writes int8 +
scales in a single pass (the XLA path materializes an f32 upcast of the
full tensor).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256          # elements per scale
ROWS = 256            # scale rows per grid step: tile = ROWS*QBLOCK*4B = 256KB


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)              # (ROWS, QBLOCK)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(amax / 127.0, 1e-12)        # (ROWS,)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = q * s_ref[...][:, None]


def quantize_pallas(x: jax.Array, interpret: bool = False):
    """x: (R, QBLOCK) with R % ROWS == 0."""
    R, B = x.shape
    assert B == QBLOCK and R % ROWS == 0, (R, B)
    grid = (R // ROWS,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((R,), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize_pallas(q: jax.Array, scale: jax.Array, interpret: bool = False):
    R, B = q.shape
    assert B == QBLOCK and R % ROWS == 0
    grid = (R // ROWS,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS,), lambda i: (i,))],
        out_specs=pl.BlockSpec((ROWS, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, QBLOCK), jnp.float32),
        interpret=interpret,
    )(q, scale)
