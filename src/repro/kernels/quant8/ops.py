"""jit'd wrapper for quant8: padding, platform dispatch, flat API."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant8.quant8 import QBLOCK, ROWS, dequantize_pallas, \
    quantize_pallas
from repro.kernels.quant8.ref import dequantize_ref, quantize_ref


def _to_rows(x_flat):
    n = x_flat.shape[0]
    pad = (-n) % (QBLOCK * ROWS)
    if pad:
        x_flat = jnp.pad(x_flat, (0, pad))
    return x_flat.reshape(-1, QBLOCK), n


@functools.partial(jax.jit, static_argnames=("force",))
def _quantize_jit(x: jax.Array, force: str):
    flat = x.reshape(-1)
    use = force
    if use == "auto":
        use = "pallas" if jax.default_backend() == "tpu" else "ref"
    rows, _ = _to_rows(flat)
    if use == "ref":
        q, s = quantize_ref(rows.reshape(-1), QBLOCK)
        return q.reshape(-1, QBLOCK), s
    return quantize_pallas(rows, interpret=jax.default_backend() != "tpu")


def quantize(x: jax.Array, force: str = "auto"):
    """x: any shape -> (q int8 (R,QBLOCK), scales f32 (R,), n = x.size).
    n is a static int usable with ``dequantize``."""
    q, s = _quantize_jit(x, force)
    return q, s, int(x.size)


@functools.partial(jax.jit, static_argnames=("n", "force"))
def dequantize(q: jax.Array, scale: jax.Array, n: int, force: str = "auto"):
    use = force
    if use == "auto":
        use = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use == "ref":
        out = dequantize_ref(q.reshape(-1), scale, QBLOCK)
    else:
        out = dequantize_pallas(q, scale,
                                interpret=jax.default_backend() != "tpu")
        out = out.reshape(-1)
    return out[:n]
