"""Oracle for the fused weighted-aggregation (FedAvg) kernel: pure jnp."""
from __future__ import annotations

import jax.numpy as jnp


def fedavg_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """stacked: (K, N) — K client parameter blocks; weights: (K,).
    Returns the weighted mean (N,), computed in f32, cast back."""
    w = weights.astype(jnp.float32)
    acc = jnp.einsum("kn,k->n", stacked.astype(jnp.float32), w)
    return (acc / jnp.sum(w)).astype(stacked.dtype)


def qagg_ref(q: jnp.ndarray, scales: jnp.ndarray,
             weights: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the fused int8 dequantize+aggregate kernel.

    q: (K, R, G) int8; scales: (K, R, 1) f32; weights: (K,).  Mirrors the
    compiled "compressed" schedule's math exactly — dequantize each client's
    contribution, scale by its weight, plain ``sum`` over the client axis —
    so weights of 1.0 reproduce dequantize-then-sum bit-for-bit."""
    w = weights.astype(jnp.float32).reshape(-1, 1, 1)
    x = q.astype(jnp.float32) * scales
    return jnp.sum(x * w, axis=0)


def fedavg_tree_ref(stacked, weights, groups):
    """Hierarchical reference: per-group weighted sums, then combine —
    mathematically identical to fedavg_ref (associativity)."""
    w = weights.astype(jnp.float32)
    x = stacked.astype(jnp.float32)
    partials = []
    pw = []
    for g in groups:
        idx = jnp.asarray(g)
        partials.append(jnp.einsum("kn,k->n", x[idx], w[idx]))
        pw.append(jnp.sum(w[idx]))
    acc = jnp.sum(jnp.stack(partials), axis=0)
    return (acc / jnp.sum(jnp.stack(pw))).astype(stacked.dtype)
