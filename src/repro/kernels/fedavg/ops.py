"""jit'd wrapper: platform dispatch + shape plumbing for the fedavg kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fedavg.fedavg import (DEFAULT_BLOCK, fedavg_pallas,
                                         qagg_pallas)
from repro.kernels.fedavg.ref import fedavg_ref, qagg_ref


def _pad_flat(x_flat: jax.Array, block: int):
    n = x_flat.shape[-1]
    pad = (-n) % block
    if pad:
        x_flat = jnp.pad(x_flat, ((0, 0), (0, pad)))
    return x_flat, n


@functools.partial(jax.jit, static_argnames=("block", "force"))
def fedavg(stacked: jax.Array, weights: jax.Array,
           block: int = DEFAULT_BLOCK, force: str = "auto") -> jax.Array:
    """Weighted mean over the leading (clients) axis of (K, N).

    force: "pallas" (interpret on CPU), "ref", or "auto" (pallas on TPU,
    ref elsewhere — the dry-run must lower without a TPU backend)."""
    K, N = stacked.shape
    use = force
    if use == "auto":
        use = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use == "ref":
        return fedavg_ref(stacked, weights)
    interpret = jax.default_backend() != "tpu"
    padded, n = _pad_flat(stacked, min(block, max(N, 1)))
    out = fedavg_pallas(padded, weights, block=min(block, padded.shape[1]),
                        interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("force",))
def qagg(q: jax.Array, scales: jax.Array, weights: jax.Array,
         force: str = "auto") -> jax.Array:
    """Fused int8 dequantize + weighted sum over the leading client axis.

    q: (K, *shape) int8 with ``quantize_int8``-style per-last-dim-row scales
    (K, *shape[:-1], 1).  Returns the f32 weighted sum shaped ``shape``.
    force: "pallas" (interpret on CPU), "ref", or "auto"."""
    K = q.shape[0]
    shape = q.shape[1:]
    G = shape[-1] if shape else 1
    q3 = q.reshape(K, -1, G)
    s3 = scales.reshape(K, -1, 1)
    use = force
    if use == "auto":
        use = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use == "ref":
        return qagg_ref(q3, s3, weights).reshape(shape)
    interpret = jax.default_backend() != "tpu"
    R = q3.shape[1]
    rows_block = max(1, min(R, DEFAULT_BLOCK // max(G, 1)))
    pad = (-R) % rows_block
    if pad:
        q3 = jnp.pad(q3, ((0, 0), (0, pad), (0, 0)))
        s3 = jnp.pad(s3, ((0, 0), (0, pad), (0, 0)))
    out = qagg_pallas(q3, s3, weights, rows_block, interpret=interpret)
    return out[:R].reshape(shape)


def fedavg_pytree(params_stacked, weights, force: str = "auto"):
    """Apply fedavg leaf-wise over a client-stacked parameter pytree."""
    def one(leaf):
        K = leaf.shape[0]
        flat = leaf.reshape(K, -1)
        return fedavg(flat, weights, force=force).reshape(leaf.shape[1:])
    return jax.tree_util.tree_map(one, params_stacked)
