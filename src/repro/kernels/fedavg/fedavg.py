"""Pallas TPU kernel: fused K-way weighted parameter aggregation.

The aggregation hot spot SDFLMQ distributes across cluster heads.  On a
v5e the aggregator reduces K client parameter blocks into one weighted
mean.  The kernel tiles the flattened parameter vector into VMEM-resident
(K, BLOCK) tiles, does the weighted reduction in f32 on the VPU, and
writes one (BLOCK,) tile back — one HBM pass over the inputs, no (K, N)
f32 temporary (the XLA path materializes the f32 upcast).

Grid: (N // BLOCK,).  BLOCK is sized so K * BLOCK * 4B fits comfortably
in VMEM (default 16 MiB/core on v5e): K=16 x 64k x 4B = 4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 65536


def _fedavg_kernel(w_ref, x_ref, o_ref):
    # x_ref: (K, BLOCK) tile in VMEM; w_ref: (K, 1) in SMEM-ish VMEM
    x = x_ref[...].astype(jnp.float32)              # (K, B)
    w = w_ref[...].astype(jnp.float32)              # (K, 1)
    total = jnp.sum(w)
    acc = jnp.sum(x * w, axis=0) / total            # (B,)
    o_ref[...] = acc.astype(o_ref.dtype)


def fedavg_pallas(stacked: jax.Array, weights: jax.Array,
                  block: int = DEFAULT_BLOCK, interpret: bool = False):
    """stacked: (K, N) with N % block == 0 (callers pad); weights: (K,)."""
    K, N = stacked.shape
    block = min(block, N)
    assert N % block == 0, (N, block)
    grid = (N // block,)
    return pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), stacked.dtype),
        interpret=interpret,
    )(weights.reshape(K, 1), stacked)
