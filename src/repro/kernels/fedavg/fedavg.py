"""Pallas TPU kernel: fused K-way weighted parameter aggregation.

The aggregation hot spot SDFLMQ distributes across cluster heads.  On a
v5e the aggregator reduces K client parameter blocks into one weighted
mean.  The kernel tiles the flattened parameter vector into VMEM-resident
(K, BLOCK) tiles, does the weighted reduction in f32 on the VPU, and
writes one (BLOCK,) tile back — one HBM pass over the inputs, no (K, N)
f32 temporary (the XLA path materializes the f32 upcast).

Grid: (N // BLOCK,).  BLOCK is sized so K * BLOCK * 4B fits comfortably
in VMEM (default 16 MiB/core on v5e): K=16 x 64k x 4B = 4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 65536


def _fedavg_kernel(w_ref, x_ref, o_ref):
    # x_ref: (K, BLOCK) tile in VMEM; w_ref: (K, 1) in SMEM-ish VMEM
    x = x_ref[...].astype(jnp.float32)              # (K, B)
    w = w_ref[...].astype(jnp.float32)              # (K, 1)
    total = jnp.sum(w)
    acc = jnp.sum(x * w, axis=0) / total            # (B,)
    o_ref[...] = acc.astype(o_ref.dtype)


def _qagg_kernel(w_ref, q_ref, s_ref, o_ref):
    # q_ref: (K, RB, G) int8 tile; s_ref: (K, RB, 1) per-row scales;
    # w_ref: (K, 1, 1) client weights.  Dequantize on the VPU and reduce the
    # client axis in f32 — the int8 payload is the only (K, N)-sized HBM
    # traffic; the f32 upcast never leaves VMEM.
    x = q_ref[...].astype(jnp.float32) * s_ref[...]
    acc = jnp.sum(x * w_ref[...], axis=0)               # (RB, G)
    o_ref[...] = acc.astype(o_ref.dtype)


def qagg_pallas(q: jax.Array, scales: jax.Array, weights: jax.Array,
                rows_block: int, interpret: bool = False):
    """Fused dequantize + weighted-sum over clients.

    q: (K, R, G) int8 — R rows of G-wide quantization groups (G is the
    tensor's last dim, matching ``quantize_int8``'s per-row scales);
    scales: (K, R, 1) f32; weights: (K,).  Callers pad R to a multiple of
    ``rows_block``.  Tiles are (K, rows_block, G) so every tile covers whole
    quantization groups; very large G degrades to one row per tile.
    """
    K, R, G = q.shape
    assert R % rows_block == 0, (R, rows_block)
    grid = (R // rows_block,)
    return pl.pallas_call(
        _qagg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1, 1), lambda i: (0, 0, 0)),
            pl.BlockSpec((K, rows_block, G), lambda i: (0, i, 0)),
            pl.BlockSpec((K, rows_block, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_block, G), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, G), jnp.float32),
        interpret=interpret,
    )(weights.reshape(K, 1, 1).astype(jnp.float32), q, scales)


def fedavg_pallas(stacked: jax.Array, weights: jax.Array,
                  block: int = DEFAULT_BLOCK, interpret: bool = False):
    """stacked: (K, N) with N % block == 0 (callers pad); weights: (K,)."""
    K, N = stacked.shape
    block = min(block, N)
    assert N % block == 0, (N, block)
    grid = (N // block,)
    return pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), stacked.dtype),
        interpret=interpret,
    )(weights.reshape(K, 1), stacked)
