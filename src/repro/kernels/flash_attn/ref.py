"""Oracle for the flash-attention kernel: exact quadratic attention."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import full_attention


def attention_ref(q, k, v, causal=True, window=None):
    q_pos = jnp.arange(q.shape[1])
    kv_pos = jnp.arange(k.shape[1])
    return full_attention(q, k, v, q_pos, kv_pos, causal=causal,
                          window=window)
