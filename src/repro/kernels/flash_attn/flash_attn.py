"""Pallas TPU kernel: flash attention forward (online softmax).

Grid: (B*K*G, nq) — one program per (batch, kv-head, group) x q-block.
The q block (Cq, hd) stays in VMEM; the kv stream is walked in Ck blocks
with running (m, l, acc) in f32.  Block sizes default to MXU-friendly
(Cq=512, Ck=512, hd multiples of 128 padded by the wrapper).  The causal /
sliding-window mask is position-derived (iota), no mask tensor in HBM.

The backward pass on TPU reuses the XLA-native custom_vjp from
models/attention.py (itself chunked + recomputing); fusing the backward
into Pallas is a further §Perf iteration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool,
                  window, ck: int, sk: int):
    Cq, hd = q_ref.shape[1], q_ref.shape[2]
    nq_idx = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (Cq, hd)
    scale = 1.0 / (hd ** 0.5)
    q_pos = nq_idx * Cq + jnp.arange(Cq)

    n_kb = sk // ck

    def body(j, carry):
        m, l, acc = carry
        kb = k_ref[0, pl.ds(j * ck, ck), :].astype(jnp.float32)   # (Ck, hd)
        vb = v_ref[0, pl.ds(j * ck, ck), :].astype(jnp.float32)
        s = (q @ kb.T) * scale                                    # (Cq, Ck)
        kv_pos = j * ck + jnp.arange(ck)
        ok = jnp.ones((Cq, ck), bool)
        if causal:
            ok &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            ok &= q_pos[:, None] - kv_pos[None, :] < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ vb
        return m_new, l_new, acc_new

    m0 = jnp.full((Cq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Cq,), jnp.float32)
    a0 = jnp.zeros((Cq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_fwd_pallas(q, k, v, causal=True, window=None, block_q=512,
                     block_k=512, interpret=False):
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd) — kv heads pre-broadcast to q
    heads by the wrapper.  Returns o (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    Cq, Ck = min(block_q, Sq), min(block_k, Sk)
    assert Sq % Cq == 0 and Sk % Ck == 0, (Sq, Cq, Sk, Ck)
    kern = functools.partial(_flash_kernel, causal=causal, window=window,
                             ck=Ck, sk=Sk)
    return pl.pallas_call(
        kern,
        grid=(BH, Sq // Cq),
        in_specs=[
            pl.BlockSpec((1, Cq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Sk, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, Sk, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Cq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
