"""jit'd wrapper for the flash-attention forward kernel: GQA broadcast,
(B,S,H,hd) <-> (BH,S,hd) plumbing, platform dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.flash_attn import flash_fwd_pallas
from repro.models.attention import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "force"))
def flash(q, k, v, causal: bool = True, window=None, force: str = "auto"):
    """q: (B,S,H,hd); k/v: (B,S,K,hd).  Forward only."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    use = force
    if use == "auto":
        use = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use == "ref":
        return flash_attention(q, k, v, causal, window)
    kq = jnp.repeat(k, G, axis=2)       # broadcast kv heads to q heads
    vq = jnp.repeat(v, G, axis=2)
    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, x.shape[1], hd)
    o = flash_fwd_pallas(bh(q), bh(kq), bh(vq), causal=causal, window=window,
                         interpret=jax.default_backend() != "tpu")
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
