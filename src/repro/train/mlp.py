"""Tiny numpy MLP — the paper's Fig.7 workload (MNIST digit classifier).
Pure numpy so the control-plane benchmarks measure SDFLMQ, not XLA."""
from __future__ import annotations

import numpy as np

Params = dict[str, np.ndarray]


def init_mlp(seed: int = 0, dims=(784, 128, 10)) -> Params:
    rng = np.random.default_rng(seed)
    p = {}
    for i in range(len(dims) - 1):
        p[f"w{i}"] = (rng.normal(0, 1, (dims[i], dims[i + 1]))
                      * np.sqrt(2.0 / dims[i])).astype(np.float32)
        p[f"b{i}"] = np.zeros(dims[i + 1], np.float32)
    return p


def _forward(p: Params, x: np.ndarray):
    n = len([k for k in p if k.startswith("w")])
    h = x
    acts = [x]
    for i in range(n):
        z = h @ p[f"w{i}"] + p[f"b{i}"]
        h = np.maximum(z, 0) if i < n - 1 else z
        acts.append(h)
    return h, acts


def predict(p: Params, x: np.ndarray) -> np.ndarray:
    return _forward(p, x)[0].argmax(-1)


def accuracy(p: Params, x: np.ndarray, y: np.ndarray) -> float:
    return float((predict(p, x) == y).mean())


def train_epochs(p: Params, x: np.ndarray, y: np.ndarray, epochs: int = 5,
                 lr: float = 0.01, batch: int = 32, seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)
    p = {k: v.copy() for k, v in p.items()}
    n = len(x)
    n_layers = len([k for k in p if k.startswith("w")])
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n, batch):
            idx = order[s:s + batch]
            xb, yb = x[idx], y[idx]
            logits, acts = _forward(p, xb)
            z = logits - logits.max(-1, keepdims=True)
            e = np.exp(z)
            probs = e / e.sum(-1, keepdims=True)
            g = probs
            g[np.arange(len(yb)), yb] -= 1.0
            g /= len(yb)
            for i in reversed(range(n_layers)):
                a_in = acts[i]
                p[f"w{i}"] -= lr * (a_in.T @ g)
                p[f"b{i}"] -= lr * g.sum(0)
                if i > 0:
                    g = (g @ p[f"w{i}"].T) * (acts[i] > 0)
    return p
