"""Uniform model API over the architecture families + loss functions.

Every family module exposes:
    param_decls(cfg) -> ParamDecl pytree
    forward(cfg, params, batch) -> (logits (B,S,V), aux_loss)
    prefill(cfg, params, batch) -> (last_logits (B,V), cache)
    decode_step(cfg, params, cache, batch) -> (logits (B,V), cache)
    cache_decl(cfg, batch, cache_len) -> ParamDecl pytree
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import sharding as shd
from repro.models import decoder, encdec, hybrid, rwkv6

_FAMILY = {
    "dense": decoder,
    "moe": decoder,
    "vlm": decoder,
    "encdec": encdec,
    "rwkv": rwkv6,
    "hybrid": hybrid,
}


def get_model(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def param_decls(cfg: ArchConfig):
    return get_model(cfg).param_decls(cfg)


def init_params(cfg: ArchConfig, key):
    return shd.materialize(param_decls(cfg), key)


def cache_len_for(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.family == "rwkv":
        return 0  # recurrent state only
    return min(cfg.window, seq_len) if cfg.window else seq_len


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Vocab-parallel-safe CE: never gathers the full vocab to one device.
    The label-logit extraction is an iota-compare+select+reduce, which XLA
    fuses into a streaming pass over the (sharded) vocab dim."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, len(lf.shape) - 1)
    sel = jnp.where(iota == labels[..., None], lf, 0.0)
    label_logit = jnp.sum(sel, axis=-1)
    return jnp.mean(lse - label_logit)


def loss_fn(cfg: ArchConfig, params, batch):
    logits, aux = get_model(cfg).forward(cfg, params, batch)
    ce = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}
