"""Shared neural-net building blocks (pure JAX, decl-based params)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import decl


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm_decl(d_model: int):
    return {"scale": decl((d_model,), (None,), init="ones", dtype=jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_decl(d_model: int):
    return {"scale": decl((d_model,), (None,), init="ones", dtype=jnp.float32),
            "bias": decl((d_model,), (None,), init="zeros", dtype=jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq          # (..., S, half)
    ang = ang[..., None, :]                                         # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:2 * half]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2, x[..., 2 * half:]], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu_decl(d_model: int, d_ff: int):
    return {
        "w_gate": decl((d_model, d_ff), ("embed", "mlp")),
        "w_up": decl((d_model, d_ff), ("embed", "mlp")),
        "w_down": decl((d_ff, d_model), ("mlp", "embed")),
    }


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def gelu_mlp_decl(d_model: int, d_ff: int):
    return {
        "w_in": decl((d_model, d_ff), ("embed", "mlp")),
        "b_in": decl((d_ff,), ("mlp",), init="zeros", dtype=jnp.float32),
        "w_out": decl((d_ff, d_model), ("mlp", "embed")),
        "b_out": decl((d_model,), (None,), init="zeros", dtype=jnp.float32),
    }


def gelu_mlp(params, x):
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params["b_out"].astype(x.dtype)


# --------------------------------------------------------------------------
# Embeddings / logits
# --------------------------------------------------------------------------

def pad_vocab(vocab: int, multiple: int = 128) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def embed_decl(vocab: int, d_model: int):
    return {
        "in_table": decl((pad_vocab(vocab), d_model), ("vocab", "embed_tp"), init="embed"),
        "out_table": decl((pad_vocab(vocab), d_model), ("vocab", "embed"), init="embed"),
    }


def embed_lookup(params, tokens):
    return jnp.take(params["in_table"], tokens, axis=0)


def logits_out(params, x):
    # vocab-parallel projection; CE is computed without gathering full vocab.
    return jnp.einsum("...d,vd->...v", x, params["out_table"])
