"""Encoder-decoder backbone (Whisper-small).

The audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed conv-frame embeddings (B, n_frames, feat_dim).  The backbone is
faithful to Whisper's shape (LayerNorm + GELU MLP, MHA); positions use RoPE
in place of Whisper's learned/sinusoidal tables (deviation noted in
DESIGN.md — keeps parameters independent of sequence length).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import decl, stack
from repro.models import attention as attn
from repro.models import kvcache as kvc
from repro.models.layers import (embed_decl, embed_lookup, gelu_mlp,
                                 gelu_mlp_decl, layernorm, layernorm_decl,
                                 logits_out)


def _enc_layer_decl(cfg: ArchConfig):
    return {
        "ln1": layernorm_decl(cfg.d_model),
        "attn": attn.attention_decl(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim),
        "ln2": layernorm_decl(cfg.d_model),
        "mlp": gelu_mlp_decl(cfg.d_model, cfg.d_ff),
    }


def _dec_layer_decl(cfg: ArchConfig):
    d = _enc_layer_decl(cfg)
    d["ln_x"] = layernorm_decl(cfg.d_model)
    d["cross"] = attn.attention_decl(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.head_dim)
    return d


def param_decls(cfg: ArchConfig):
    fe = cfg.frontend
    return {
        "enc_in": {"w": decl((fe.feat_dim, cfg.d_model), (None, "embed"))},
        "enc_layers": stack(_enc_layer_decl(cfg), cfg.n_enc_layers),
        "enc_norm": layernorm_decl(cfg.d_model),
        "embed": embed_decl(cfg.vocab, cfg.d_model),
        "dec_layers": stack(_dec_layer_decl(cfg), cfg.n_layers),
        "final_norm": layernorm_decl(cfg.d_model),
    }


def cache_decl(cfg: ArchConfig, batch: int, cache_len: int):
    d = kvc.kv_cache_decl(cfg.n_layers, batch, cache_len, cfg.n_kv_heads,
                          cfg.head_dim)
    d.update(kvc.kv_cache_decl(cfg.n_layers, batch, cfg.frontend.n_tokens,
                               cfg.n_kv_heads, cfg.head_dim, prefix="cross_"))
    del d["cross_kv_pos"]
    return d


# --------------------------------------------------------------------------

def encode(cfg: ArchConfig, params, frames):
    x = jnp.einsum("bsf,fd->bsd", frames.astype(jnp.bfloat16), params["enc_in"]["w"])
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, lp):
        h = layernorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg.rope_theta)
        o = attn.attention(q, k, v, positions, positions, causal=False,
                           chunk=cfg.attn_chunk,
                           chunk_threshold=cfg.attn_chunk_threshold)
        x = x + attn.project_out(lp["attn"], o)
        h = layernorm(lp["ln2"], x, cfg.norm_eps)
        return x + gelu_mlp(lp["mlp"], h), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_attend(cfg, lp, x, mem_k, mem_v, dec_pos, enc_pos):
    h = layernorm(lp["ln_x"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"])
    o = attn.attention(q, mem_k, mem_v, dec_pos, enc_pos, causal=False,
                       chunk=cfg.attn_chunk,
                       chunk_threshold=cfg.attn_chunk_threshold)
    return x + attn.project_out(lp["cross"], o)


def _cross_kv(lp, mem):
    k = jnp.einsum("bsd,dhk->bshk", mem, lp["cross"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mem, lp["cross"]["wv"])
    return k, v


def _dec_layer(cfg, lp, x, mem, positions, enc_pos, collect_kv=False):
    h = layernorm(lp["ln1"], x, cfg.norm_eps)
    q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg.rope_theta)
    o = attn.attention(q, k, v, positions, positions, causal=True,
                       chunk=cfg.attn_chunk,
                       chunk_threshold=cfg.attn_chunk_threshold)
    x = x + attn.project_out(lp["attn"], o)
    mk, mv = _cross_kv(lp, mem)
    x = _cross_attend(cfg, lp, x, mk, mv, positions, enc_pos)
    h = layernorm(lp["ln2"], x, cfg.norm_eps)
    x = x + gelu_mlp(lp["mlp"], h)
    if collect_kv:
        return x, (k, v, mk, mv)
    return x, None


def forward(cfg: ArchConfig, params, batch):
    mem = encode(cfg, params, batch["frames"])
    x = embed_lookup(params["embed"], batch["tokens"])
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    enc_pos = jnp.arange(mem.shape[1], dtype=jnp.int32)

    def body(x, lp):
        return _dec_layer(cfg, lp, x, mem, positions, enc_pos)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = layernorm(params["final_norm"], x, cfg.norm_eps)
    return logits_out(params["embed"], x), jnp.float32(0.0)


def prefill(cfg: ArchConfig, params, batch):
    mem = encode(cfg, params, batch["frames"])
    x = embed_lookup(params["embed"], batch["tokens"])
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_pos = jnp.arange(mem.shape[1], dtype=jnp.int32)

    def body(x, lp):
        return _dec_layer(cfg, lp, x, mem, positions, enc_pos, collect_kv=True)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (k, v, mk, mv) = jax.lax.scan(body, x, params["dec_layers"])
    x = layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1])
    cache = {"k": k, "v": v, "kv_pos": kvc.prefilled_pos(B, S),
             "cross_k": mk, "cross_v": mv}
    return logits, cache


def decode_step(cfg: ArchConfig, params, cache, batch):
    token, pos = batch["token"], batch["pos"]
    x = embed_lookup(params["embed"], token)
    cache_len = cache["k"].shape[2]
    slot = kvc.cache_slot(pos, cache_len)
    kv_pos = kvc.update_kv_pos(cache["kv_pos"], pos, cache_len)
    enc_pos = jnp.arange(cache["cross_k"].shape[2], dtype=jnp.int32)

    def body(x, xs):
        lp, k_l, v_l, mk, mv = xs
        h = layernorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(lp["attn"], h, pos[:, None], cfg.rope_theta)
        k_l, v_l = kvc.update_kv_layer(k_l, v_l, k, v, slot)
        o = attn.decode_attention(q, k_l, v_l, kv_pos, pos)
        x = x + attn.project_out(lp["attn"], o)
        x = _cross_attend(cfg, lp, x, mk, mv, pos[:, None], enc_pos)
        h = layernorm(lp["ln2"], x, cfg.norm_eps)
        x = x + gelu_mlp(lp["mlp"], h)
        return x, (k_l, v_l)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1])
    return logits, {"k": k_new, "v": v_new, "kv_pos": kv_pos,
                    "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
