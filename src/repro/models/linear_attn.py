"""Generalized decayed linear attention — the shared computational core of
RWKV6 ("Finch", data-dependent per-channel decay) and the Hymba SSM branch
(SSD-form, scalar per-head decay).

Recurrence (per batch b, head h; d_k = key dim, d_v = value dim):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T            S: (d_k, d_v)
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)      (u-bonus optional; u=None
                                                    means o_t = r_t^T S_t —
                                                    the SSD convention)

``w_log`` is log-decay, broadcastable to (B, T, H, d_k); a scalar-per-head
decay is passed as (B, T, H, 1).

Two implementations:
  * ``recurrent`` — exact lax.scan over time; the oracle, also used for
    single-token decode.
  * ``chunked``  — scan over chunks; intra-chunk pairwise decay differences
    (all exponents of non-positive numbers -> numerically safe), inter-chunk
    via the carried state.  O(T/C) sequential steps, O(C^2) parallel work —
    this is the TPU-friendly form the Pallas kernel (kernels/wkv6) mirrors.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _bcast_w(w_log, shape):
    return jnp.broadcast_to(w_log, shape)


# --------------------------------------------------------------------------
# Recurrent (oracle / decode)
# --------------------------------------------------------------------------

def recurrent(r, k, v, w_log, u: Optional[jax.Array] = None, s0=None):
    """r,k: (B,T,H,dk); v: (B,T,H,dv); w_log broadcastable to r.
    Returns (o: (B,T,H,dv), s_final: (B,H,dk,dv))."""
    B, T, H, dk = r.shape
    dv = v.shape[-1]
    w_log = _bcast_w(w_log, r.shape).astype(jnp.float32)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                      # (B,H,dk),(B,H,dk),(B,H,dv),(B,H,dk)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,dk,dv)
        if u is not None:
            att = S + u[None, :, :, None] * kv
        else:
            att = jnp.exp(wt)[..., None] * S + kv
        o = jnp.einsum("bhk,bhkv->bhv", rt, att)
        S_new = jnp.exp(wt)[..., None] * S + kv
        return S_new, o

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, w_log))
    s_fin, o = jax.lax.scan(step, s0, xs)
    return o.transpose(1, 0, 2, 3), s_fin


def decode_step(r, k, v, w_log, S, u: Optional[jax.Array] = None):
    """One token.  r,k: (B,H,dk); v: (B,H,dv); w_log (B,H,dk) or (B,H,1);
    S: (B,H,dk,dv).  Returns (o: (B,H,dv), S_new)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.broadcast_to(w_log, rf.shape).astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    if u is not None:
        att = S + u[None, :, :, None] * kv
    else:
        att = jnp.exp(w)[..., None] * S + kv
    o = jnp.einsum("bhk,bhkv->bhv", rf, att)
    S_new = jnp.exp(w)[..., None] * S + kv
    return o.astype(r.dtype), S_new


# --------------------------------------------------------------------------
# Chunked (production path; Pallas kernel mirrors this)
# --------------------------------------------------------------------------

def chunked(r, k, v, w_log, u: Optional[jax.Array] = None, s0=None,
            chunk: int = 64):
    """Same contract as ``recurrent``; mathematically identical."""
    B, T, H, dk = r.shape
    dv = v.shape[-1]
    C = min(chunk, T)
    w_log = _bcast_w(w_log, r.shape).astype(jnp.float32)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    T_orig = T
    if T % C:
        # pad: k=0 contributes nothing, w_log=0 preserves the state
        pad = C - T % C
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        rf, kf, vf = (jnp.pad(a, widths) for a in (rf, kf, vf))
        w_log = jnp.pad(w_log, widths)
        T += pad
    n = T // C

    # keep the recurrence sharded over the model axis: the (B,n,C,H,d)
    # reshape loses GSPMD's seq sharding, which would otherwise replicate
    # the whole scan on every model device (16x HBM traffic).  The
    # recurrence is independent per (batch, head): pin heads when they
    # divide the axis, else batch.
    from repro.models.attention import _active_mesh, _constrain_dim
    mesh = _active_mesh()
    msize = mesh.shape.get("model") if mesh is not None else None

    def _pin(a, h_dim, b_dim):
        if msize is None:
            return a
        if a.shape[h_dim] % msize == 0:
            return _constrain_dim(a, h_dim)
        return _constrain_dim(a, b_dim)

    def to_chunks(a, last):
        return a.reshape(B, n, C, H, last).transpose(1, 0, 2, 3, 4)

    rc, kc, wc = (_pin(to_chunks(a, dk), 3, 1) for a in (rf, kf, w_log))
    vc = _pin(to_chunks(vf, dv), 3, 1)
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), jnp.float32)
    s0 = _pin(s0, 1, 0)

    idx = jnp.arange(C)
    lower = idx[:, None] > idx[None, :]            # strictly-causal intra mask

    def chunk_step(S, inp):
        rb, kb, vb, wb = inp                       # (B,C,H,d*)
        cum = jnp.cumsum(wb, axis=1)               # inclusive log-decay
        # RWKV convention (u-bonus) reads S *before* the t-update: exclusive
        # decay; SSD convention (u=None) reads S after: inclusive decay.
        base = (cum - wb) if u is not None else cum
        # ---- inter-chunk: state contribution -------------------------
        q_eff = rb * jnp.exp(base)                 # exp(<=0) safe
        o_inter = jnp.einsum("bchk,bhkv->bchv", q_eff, S)
        # ---- intra-chunk: pairwise decayed scores --------------------
        # diff[t,s,d] = base[t,d] - cum[s,d]  (<= 0 for s < t)
        diff = base[:, :, None] - cum[:, None, :]           # (B,C,C,H,dk)
        diff = jnp.where(lower[None, :, :, None, None], diff, -jnp.inf)
        A = jnp.einsum("bthk,bshk,btshk->bths", rb, kb, jnp.exp(diff))
        if u is not None:
            diag = jnp.einsum("bthk,hk,bthk->bth", rb, u, kb)
        else:
            diag = jnp.einsum("bthk,bthk->bth", rb, kb)
        # A layout is (B, t, H, s): place diag on t == s
        A = A + diag[:, :, :, None] * jnp.eye(C)[None, :, None, :]
        o_intra = jnp.einsum("bths,bshv->bthv", A, vb)
        # ---- state update --------------------------------------------
        cum_last = cum[:, -1]                      # (B,H,dk)
        k_eff = kb * jnp.exp(cum_last[:, None] - cum)
        S_new = S * jnp.exp(cum_last)[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", k_eff, vb)
        return S_new, o_inter + o_intra

    # remat the chunk step: without it the (B,C,C,H,dk) pairwise-decay tensor
    # is saved per chunk for backward (tens of GB); with it only the carried
    # state (B,H,dk,dv) is stacked across steps.
    s_fin, oc = jax.lax.scan(jax.checkpoint(chunk_step, prevent_cse=False),
                             s0, (rc, kc, vc, wc))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(B, T, H, dv)[:, :T_orig]
    return o, s_fin


def linear_attention(r, k, v, w_log, u=None, s0=None, chunk: int = 64,
                     impl: str = "chunked"):
    if impl == "recurrent":
        return recurrent(r, k, v, w_log, u=u, s0=s0)
    return chunked(r, k, v, w_log, u=u, s0=s0, chunk=chunk)
