"""Hymba-style hybrid: parallel attention + SSM heads per layer.

Attention branch: GQA with sliding window + RoPE.  SSM branch: selective
state-space in SSD form (scalar per-head decay, state size ``ssm_state``) —
the TPU-friendly adaptation noted in DESIGN.md; it shares the chunked
linear-attention core (and the Pallas ssm_scan kernel) with RWKV6.
Branch outputs are averaged (Hymba's fused parallel heads), then SwiGLU MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import decl, stack
from repro.models import attention as attn
from repro.models import kvcache as kvc
from repro.models import linear_attn as la
from repro.models.layers import (embed_decl, embed_lookup, logits_out,
                                 rmsnorm, rmsnorm_decl, swiglu, swiglu_decl)

CONV_W = 3


def _dims(cfg: ArchConfig):
    H, hd, N = cfg.n_heads, cfg.head_dim, cfg.ssm_state
    return H, hd, N, H * hd


def _layer_decl(cfg: ArchConfig):
    D = cfg.d_model
    H, hd, N, Din = _dims(cfg)
    return {
        "ln1": rmsnorm_decl(D),
        "attn": attn.attention_decl(D, H, cfg.n_kv_heads, hd),
        "ssm": {
            "in_w": decl((D, H, hd), ("embed", "heads", None)),
            "z_w": decl((D, H, hd), ("embed", "heads", None)),
            "B_w": decl((D, H, N), ("embed", "heads", None)),
            "C_w": decl((D, H, N), ("embed", "heads", None)),
            "dt_w": decl((D, H), ("embed", "heads")),
            "dt_bias": decl((H,), ("heads",), init="const", scale=-1.0,
                            dtype=jnp.float32),
            "A_log": decl((H,), ("heads",), init="const", scale=0.5,
                          dtype=jnp.float32),
            "D_skip": decl((H, hd), ("heads", None), init="ones",
                           dtype=jnp.float32),
            "conv_w": decl((CONV_W, Din), (None, "embed"), init="normal"),
            "conv_b": decl((Din,), ("embed",), init="zeros",
                           dtype=jnp.float32),
            "gn_scale": decl((H, hd), ("heads", None), init="ones",
                             dtype=jnp.float32),
            "out_w": decl((H, hd, D), ("heads", None, "embed")),
        },
        "ln2": rmsnorm_decl(D),
        "mlp": swiglu_decl(D, cfg.d_ff),
    }


def param_decls(cfg: ArchConfig):
    return {
        "embed": embed_decl(cfg.vocab, cfg.d_model),
        "layers": stack(_layer_decl(cfg), cfg.n_layers),
        "final_norm": rmsnorm_decl(cfg.d_model),
    }


def cache_decl(cfg: ArchConfig, batch: int, cache_len: int):
    H, hd, N, Din = _dims(cfg)
    L = cfg.n_layers
    d = kvc.kv_cache_decl(L, batch, cache_len, cfg.n_kv_heads, hd)
    d["ssm_S"] = decl((L, batch, H, N, hd),
                      ("layers", "batch", "heads", None, None),
                      init="zeros", dtype=jnp.float32)
    d["conv"] = decl((L, batch, CONV_W - 1, Din),
                     ("layers", "batch", None, "heads"), init="zeros")
    return d


# --------------------------------------------------------------------------

def _causal_conv(u_flat, w, b, conv_state=None):
    """u_flat: (B,S,Din); w: (CONV_W, Din).  Returns (out, new_state)."""
    B, S, Din = u_flat.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, CONV_W - 1, Din), u_flat.dtype)
    ext = jnp.concatenate([conv_state.astype(u_flat.dtype), u_flat], axis=1)
    out = sum(ext[:, j:j + S] * w[j].astype(u_flat.dtype)
              for j in range(CONV_W))
    out = out + b.astype(u_flat.dtype)
    new_state = ext[:, -(CONV_W - 1):]
    return out, new_state


def _ssm_branch(cfg, sp, h, s0=None, conv_state=None, chunk=None):
    """h: (B,S,D) normed input.  Returns (out, new_S, new_conv)."""
    B, S, D = h.shape
    H, hd, N, Din = _dims(cfg)
    u = jnp.einsum("bsd,dhk->bshk", h, sp["in_w"])
    z = jnp.einsum("bsd,dhk->bshk", h, sp["z_w"])
    uc, new_conv = _causal_conv(u.reshape(B, S, Din), sp["conv_w"],
                                sp["conv_b"], conv_state)
    uc = jax.nn.silu(uc.astype(jnp.float32)).astype(h.dtype).reshape(B, S, H, hd)
    Bt = jnp.einsum("bsd,dhn->bshn", h, sp["B_w"])
    Ct = jnp.einsum("bsd,dhn->bshn", h, sp["C_w"])
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", h, sp["dt_w"])
                         .astype(jnp.float32) + sp["dt_bias"])
    w_log = (-dt * jnp.exp(sp["A_log"]))[..., None]       # (B,S,H,1) <= 0
    k = Bt * dt[..., None].astype(Bt.dtype)               # fold dt into k
    y, s_fin = la.linear_attention(Ct, k, uc, w_log, u=None, s0=s0,
                                   chunk=chunk or cfg.rwkv_chunk)
    y = y + sp["D_skip"].astype(y.dtype) * uc.astype(y.dtype)
    # gated per-head rmsnorm (mamba2-style)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-5)
    yf = yf * sp["gn_scale"]
    y = yf.astype(h.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("bshk,hkd->bsd", y, sp["out_w"])
    return out, s_fin, new_conv


def _apply_layer(cfg, lp, x, positions):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg.rope_theta)
    o = attn.attention(q, k, v, positions, positions, causal=True,
                       window=cfg.window, chunk=cfg.attn_chunk,
                       chunk_threshold=cfg.attn_chunk_threshold)
    a_out = attn.project_out(lp["attn"], o)
    s_out, _, _ = _ssm_branch(cfg, lp["ssm"], h)
    x = x + 0.5 * (a_out + s_out)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    return x + swiglu(lp["mlp"], h2)


def forward(cfg: ArchConfig, params, batch):
    x = embed_lookup(params["embed"], batch["tokens"])
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, lp):
        return _apply_layer(cfg, lp, x, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_out(params["embed"], x), jnp.float32(0.0)


def prefill(cfg: ArchConfig, params, batch):
    x = embed_lookup(params["embed"], batch["tokens"])
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    W = min(cfg.window or S, S)

    def body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg.rope_theta)
        o = attn.attention(q, k, v, positions, positions, causal=True,
                           window=cfg.window, chunk=cfg.attn_chunk,
                           chunk_threshold=cfg.attn_chunk_threshold)
        a_out = attn.project_out(lp["attn"], o)
        s_out, s_fin, conv = _ssm_branch(cfg, lp["ssm"], h)
        x = x + 0.5 * (a_out + s_out)
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + swiglu(lp["mlp"], h2)
        return x, (k[:, -W:], v[:, -W:], s_fin, conv)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (kc, vc, S_fin, conv) = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1])
    kv_pos = jnp.broadcast_to(jnp.arange(S - W, S, dtype=jnp.int32), (B, W))
    return logits, {"k": kc, "v": vc, "kv_pos": kv_pos, "ssm_S": S_fin,
                    "conv": conv}


def decode_step(cfg: ArchConfig, params, cache, batch):
    token, pos = batch["token"], batch["pos"]
    x = embed_lookup(params["embed"], token)
    cache_len = cache["k"].shape[2]
    slot = kvc.cache_slot(pos, cache_len)
    kv_pos = kvc.update_kv_pos(cache["kv_pos"], pos, cache_len)

    def body(x, xs):
        lp, k_l, v_l, S_l, conv_l = xs
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(lp["attn"], h, pos[:, None], cfg.rope_theta)
        k_l, v_l = kvc.update_kv_layer(k_l, v_l, k, v, slot)
        o = attn.decode_attention(q, k_l, v_l, kv_pos, pos, window=cfg.window)
        a_out = attn.project_out(lp["attn"], o)
        s_out, S_n, conv_n = _ssm_branch(cfg, lp["ssm"], h, s0=S_l,
                                         conv_state=conv_l, chunk=1)
        x = x + 0.5 * (a_out + s_out)
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + swiglu(lp["mlp"], h2)
        return x, (k_l, v_l, S_n, conv_n)

    x, (k_new, v_new, S_new, conv_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["ssm_S"],
                  cache["conv"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1])
    return logits, {"k": k_new, "v": v_new, "kv_pos": kv_pos,
                    "ssm_S": S_new, "conv": conv_new}
