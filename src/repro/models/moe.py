"""Mixture-of-Experts FFN with capacity-based token dispatch.

Token-choice top-k routing; tokens are sorted by expert, written into fixed
(E, capacity, D) buffers (overflow dropped — standard capacity dropping) and
the expert FFNs run as dense batched einsums.  Expert weight sharding decides
the parallelism flavour automatically via the logical-axis resolver:

  * Kimi-K2 : 384 experts % 16 == 0  -> experts sharded over ``model`` (EP);
  * Mixtral : 8 experts  % 16 != 0  -> falls through to ``expert_mlp``
    (d_ff sharded over ``model``: intra-expert TP), experts replicated.

A shard_map all-to-all EP variant (``impl="ep_a2a"``) lives in
``repro/dist/moe_a2a.py`` and is used as a perf hillclimb for Kimi.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import decl
from repro.models.layers import swiglu, swiglu_decl


def moe_decl(cfg: ArchConfig):
    m = cfg.moe
    d = {
        "router": decl((cfg.d_model, m.n_experts), ("embed", "experts"),
                       dtype=jnp.float32, scale=0.5),
        "w_gate": decl((m.n_experts, cfg.d_model, m.d_ff_expert),
                       ("experts", "embed", "expert_mlp")),
        "w_up": decl((m.n_experts, cfg.d_model, m.d_ff_expert),
                     ("experts", "embed", "expert_mlp")),
        "w_down": decl((m.n_experts, m.d_ff_expert, cfg.d_model),
                       ("experts", "expert_mlp", "embed")),
    }
    if m.n_shared_experts:
        d["shared"] = swiglu_decl(cfg.d_model, m.n_shared_experts * m.d_ff_expert)
    return d


def capacity(n_tokens: int, m) -> int:
    cap = int(m.capacity_factor * m.top_k * n_tokens / m.n_experts)
    return max(8, ((cap + 7) // 8) * 8)


def route(router_w, x_flat, top_k: int):
    """x_flat: (T, D) -> (weights (T,k), ids (T,k), gates (T,E))."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, top_k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    return topw, topi, gates


def moe_apply(cfg: ArchConfig, p, x):
    """x: (B, S, D) -> (y: (B, S, D), aux_loss: scalar)."""
    m = cfg.moe
    if m.impl == "ep_a2a":
        from repro.dist.moe_a2a import moe_apply_a2a
        return moe_apply_a2a(cfg, p, x)
    if m.impl == "tp_local":
        from repro.dist.moe_a2a import moe_apply_tp_local
        return moe_apply_tp_local(cfg, p, x)
    return moe_apply_dense(cfg, p, x)


def moe_apply_dense(cfg: ArchConfig, p, x, buf_constraint=None,
                    act_constraint=None):
    """Capacity-dispatch einsum path.  ``buf_constraint``/``act_constraint``
    optionally pin the dispatch buffer (E, cap, D) / expert activations
    (E, cap, F) shardings (see repro/dist/moe_a2a.py)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    xf = x.reshape(T, D)

    topw, topi, gates = route(p["router"], xf, K)

    cap = capacity(T, m)
    N = T * K
    ids = topi.reshape(N)
    wts = topw.reshape(N)
    tok = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(ids)                       # stable
    sid, stok, sw = ids[order], tok[order], wts[order]
    first = jnp.searchsorted(sid, sid, side="left")
    rank = jnp.arange(N) - first                   # position within expert
    valid = rank < cap
    slot = jnp.where(valid, sid * cap + rank, E * cap)

    buf = jnp.zeros((E * cap, D), x.dtype).at[slot].set(xf[stok], mode="drop")
    h = buf.reshape(E, cap, D)
    if buf_constraint is not None:
        h = jax.lax.with_sharding_constraint(h, buf_constraint)
    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * up
    if act_constraint is not None:
        act = jax.lax.with_sharding_constraint(act, act_constraint)
    out = jnp.einsum("ecf,efd->ecd", act, p["w_down"]).reshape(E * cap, D)

    gathered = out[jnp.clip(slot, 0, E * cap - 1)]
    gathered = jnp.where(valid[:, None], gathered, 0)
    y = jnp.zeros((T, D), x.dtype).at[stok].add(
        gathered * sw[:, None].astype(x.dtype))

    # Switch-style load-balancing auxiliary loss.
    f = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), ids,
                            num_segments=E) / N
    pmean = jnp.mean(gates, axis=0)
    aux = m.aux_coef * E * jnp.sum(f * pmean)

    if m.n_shared_experts:
        y = y + swiglu(p["shared"], xf)
    return y.reshape(B, S, D), aux
