"""RWKV6 ("Finch") — attention-free LM with data-dependent per-channel decay.

Time-mix block: token-shift ddlerp (low-rank adapters) -> r/k/v/g/w
projections -> WKV linear-attention recurrence (chunked for training,
recurrent for decode) -> per-head groupnorm, silu(g) gating, out proj.
Channel-mix block: token-shift + squared-relu MLP.

The chunked WKV is `repro.models.linear_attn.chunked`; the Pallas kernel
(kernels/wkv6) implements the same algorithm for TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import decl, stack
from repro.models import linear_attn as la
from repro.models.layers import embed_decl, embed_lookup, layernorm, \
    layernorm_decl, logits_out

LORA_R = 64
N_MIX = 6  # base + r,k,v,w,g


def _heads(cfg: ArchConfig):
    hd = cfg.rwkv_head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def _layer_decl(cfg: ArchConfig):
    D = cfg.d_model
    H, hd = _heads(cfg)
    r = min(LORA_R, D)
    return {
        "ln1": layernorm_decl(D),
        "tm": {
            "mu": decl((N_MIX, D), (None, None), init="const", scale=0.5,
                       dtype=jnp.float32),
            "lora_A": decl((5, D, r), (None, "embed", None)),
            "lora_B": decl((5, r, D), (None, None, "embed"), init="zeros"),
            "w0": decl((D,), (None,), init="const", scale=-2.0,
                       dtype=jnp.float32),
            "u": decl((H, hd), ("heads", None), init="normal", scale=8.0,
                      dtype=jnp.float32),
            "wr": decl((D, H, hd), ("embed", "heads", None)),
            "wk": decl((D, H, hd), ("embed", "heads", None)),
            "wv": decl((D, H, hd), ("embed", "heads", None)),
            "wg": decl((D, H, hd), ("embed", "heads", None)),
            "wo": decl((H, hd, D), ("heads", None, "embed")),
            "gn_scale": decl((H, hd), ("heads", None), init="ones",
                             dtype=jnp.float32),
            "gn_bias": decl((H, hd), ("heads", None), init="zeros",
                            dtype=jnp.float32),
        },
        "ln2": layernorm_decl(D),
        "cm": {
            "mu_k": decl((D,), (None,), init="const", scale=0.5,
                         dtype=jnp.float32),
            "mu_r": decl((D,), (None,), init="const", scale=0.5,
                         dtype=jnp.float32),
            "wk": decl((D, cfg.d_ff), ("embed", "mlp")),
            "wv": decl((cfg.d_ff, D), ("mlp", "embed")),
            "wr": decl((D, D), ("embed", "mlp")),
        },
    }


def param_decls(cfg: ArchConfig):
    return {
        "embed": embed_decl(cfg.vocab, cfg.d_model),
        "layers": stack(_layer_decl(cfg), cfg.n_layers),
        "final_norm": layernorm_decl(cfg.d_model),
    }


def cache_decl(cfg: ArchConfig, batch: int, cache_len: int):
    H, hd = _heads(cfg)
    L, D = cfg.n_layers, cfg.d_model
    return {
        "S": decl((L, batch, H, hd, hd), ("layers", "batch", "heads", None, None),
                  init="zeros", dtype=jnp.float32),
        "x_tm": decl((L, batch, D), ("layers", "batch", None), init="zeros"),
        "x_cm": decl((L, batch, D), ("layers", "batch", None), init="zeros"),
    }


# --------------------------------------------------------------------------

def _shift(x, x_prev=None):
    """Token shift: previous token's activation (zeros / carried state)."""
    if x_prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = x_prev[:, None]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(tm, x, xx):
    """Data-dependent lerp -> 5 mixed streams (r,k,v,w,g)."""
    mu = tm["mu"].astype(x.dtype)
    base = x + (xx - x) * mu[0]
    t = jnp.tanh(jnp.einsum("bsd,idr->bsir", base, tm["lora_A"]))
    lora = jnp.einsum("bsir,ird->bsid", t, tm["lora_B"])
    mixed = (x[:, :, None] + (xx - x)[:, :, None]
             * (mu[1:][None, None] + lora))
    return [mixed[:, :, i] for i in range(5)]


def _time_mix(cfg, tm, x, x_prev, s0, chunk):
    """x: (B,S,D).  Returns (out, new_x_prev, new_state)."""
    B, S, D = x.shape
    H, hd = _heads(cfg)
    xx = _shift(x, x_prev)
    mr, mk, mv, mw, mg = _ddlerp(tm, x, xx)
    r = jnp.einsum("bsd,dhk->bshk", mr, tm["wr"])
    k = jnp.einsum("bsd,dhk->bshk", mk, tm["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mv, tm["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", mg, tm["wg"])
                    .astype(jnp.float32)).astype(x.dtype)
    # decay: w_log <= 0 always (chunked path relies on this)
    ww = tm["w0"].astype(jnp.float32) + mw.astype(jnp.float32)
    w_log = -jnp.exp(jnp.clip(ww, -12.0, 6.0)).reshape(B, S, H, hd)

    o, s_fin = la.linear_attention(r, k, v, w_log, u=tm["u"], s0=s0,
                                   chunk=chunk)
    # per-head groupnorm
    of = o.astype(jnp.float32)
    mean = jnp.mean(of, axis=-1, keepdims=True)
    var = jnp.var(of, axis=-1, keepdims=True)
    of = (of - mean) * jax.lax.rsqrt(var + 1e-5)
    of = of * tm["gn_scale"] + tm["gn_bias"]
    out = jnp.einsum("bshk,hkd->bsd", of.astype(x.dtype) * g, tm["wo"])
    return out, x[:, -1], s_fin


def _channel_mix(cm, x, x_prev):
    xx = _shift(x, x_prev)
    mk = cm["mu_k"].astype(x.dtype)
    mr = cm["mu_r"].astype(x.dtype)
    xk = x + (xx - x) * mk
    xr = x + (xx - x) * mr
    k = jnp.einsum("bsd,df->bsf", xk, cm["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, cm["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, cm["wr"])
                        .astype(jnp.float32)).astype(x.dtype)
    return rr * kv, x[:, -1]


def _apply_layer(cfg, lp, x, state=None, chunk=None):
    """state: (S, x_tm, x_cm) per layer or None (training from scratch)."""
    s0 = state[0] if state else None
    xp_tm = state[1] if state else None
    xp_cm = state[2] if state else None
    h = layernorm(lp["ln1"], x, cfg.norm_eps)
    tm_out, new_xtm, new_s = _time_mix(cfg, lp["tm"], h, xp_tm, s0,
                                       chunk or cfg.rwkv_chunk)
    x = x + tm_out
    h = layernorm(lp["ln2"], x, cfg.norm_eps)
    cm_out, new_xcm = _channel_mix(lp["cm"], h, xp_cm)
    x = x + cm_out
    return x, (new_s, new_xtm, new_xcm)


def forward(cfg: ArchConfig, params, batch):
    x = embed_lookup(params["embed"], batch["tokens"])

    def body(x, lp):
        x, _ = _apply_layer(cfg, lp, x)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = layernorm(params["final_norm"], x, cfg.norm_eps)
    return logits_out(params["embed"], x), jnp.float32(0.0)


def prefill(cfg: ArchConfig, params, batch):
    x = embed_lookup(params["embed"], batch["tokens"])

    def body(x, lp):
        x, st = _apply_layer(cfg, lp, x)
        return x, st

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (S, x_tm, x_cm) = jax.lax.scan(body, x, params["layers"])
    x = layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1])
    return logits, {"S": S, "x_tm": x_tm, "x_cm": x_cm}


def decode_step(cfg: ArchConfig, params, cache, batch):
    x = embed_lookup(params["embed"], batch["token"])  # (B,1,D)

    def body(x, xs):
        lp, S_l, xtm_l, xcm_l = xs
        x, (S_n, xtm_n, xcm_n) = _apply_layer(cfg, lp, x,
                                              state=(S_l, xtm_l, xcm_l),
                                              chunk=1)
        return x, (S_n, xtm_n, xcm_n)

    x, (S, x_tm, x_cm) = jax.lax.scan(
        body, x, (params["layers"], cache["S"], cache["x_tm"], cache["x_cm"]))
    x = layernorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1])
    return logits, {"S": S, "x_tm": x_tm, "x_cm": x_cm}
