"""Unified decoder-only backbone (dense / MoE / VLM families).

Scan-over-layers with stacked parameters (HLO size independent of depth),
optional leading dense layers (Kimi-K2 ``first_k_dense``), optional visual
token injection (InternVL2), GQA attention with optional sliding window and
QKV bias, RoPE, SwiGLU or MoE FFN, vocab-parallel logits.

Three entry points per the model API: ``forward`` (train), ``prefill``
(logits + filled KV cache), ``decode_step`` (one token against a cache).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import decl, stack
from repro.models import attention as attn
from repro.models import kvcache as kvc
from repro.models.layers import (embed_decl, embed_lookup, logits_out,
                                 rmsnorm, rmsnorm_decl, swiglu, swiglu_decl)
from repro.models.moe import moe_apply, moe_decl


# --------------------------------------------------------------------------
# Declarations
# --------------------------------------------------------------------------

def _layer_decl(cfg: ArchConfig, kind: str):
    d = {
        "ln1": rmsnorm_decl(cfg.d_model),
        "attn": attn.attention_decl(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, cfg.qkv_bias),
        "ln2": rmsnorm_decl(cfg.d_model),
    }
    if kind == "moe":
        d["moe"] = moe_decl(cfg)
    else:
        ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.first_k_dense) else cfg.d_ff
        d["mlp"] = swiglu_decl(cfg.d_model, ff)
    return d


def n_dense_layers(cfg: ArchConfig) -> int:
    return cfg.moe.first_k_dense if cfg.moe else 0


def param_decls(cfg: ArchConfig):
    decls = {
        "embed": embed_decl(cfg.vocab, cfg.d_model),
        "final_norm": rmsnorm_decl(cfg.d_model),
    }
    nd = n_dense_layers(cfg)
    if nd:
        decls["dense_layers"] = stack(_layer_decl(cfg, "dense"), nd)
    kind = "moe" if cfg.moe else "dense"
    decls["layers"] = stack(_layer_decl(cfg, kind), cfg.n_layers - nd)
    if cfg.family == "vlm":
        fe = cfg.frontend
        decls["vis_proj"] = {
            "w": decl((fe.feat_dim, cfg.d_model), ("mlp", "embed")),
            "norm": rmsnorm_decl(fe.feat_dim),
        }
    return decls


def cache_decl(cfg: ArchConfig, batch: int, cache_len: int):
    return kvc.kv_cache_decl(cfg.n_layers, batch, cache_len,
                             cfg.n_kv_heads, cfg.head_dim)


# --------------------------------------------------------------------------
# Layer application
# --------------------------------------------------------------------------

def _ffn(cfg: ArchConfig, lp, x, kind: str):
    if kind == "moe":
        return moe_apply(cfg, lp["moe"], x)
    return swiglu(lp["mlp"], x), jnp.float32(0.0)


def _apply_layer(cfg: ArchConfig, lp, x, positions, kind: str,
                 return_kv: bool = False):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    q, k, v = attn.project_qkv(lp["attn"], h, positions, cfg.rope_theta)
    o = attn.attention(q, k, v, positions, positions, causal=True,
                       window=cfg.window, chunk=cfg.attn_chunk,
                       chunk_threshold=cfg.attn_chunk_threshold)
    x = x + attn.project_out(lp["attn"], o)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    y, aux = _ffn(cfg, lp, h2, kind)
    x = x + y
    if return_kv:
        return x, aux, (k, v)
    return x, aux


def _apply_layer_decode(cfg: ArchConfig, lp, x, k_l, v_l, kv_pos, pos,
                        slot, kind: str):
    """x: (B,1,D); k_l/v_l: (B,S,K,hd); pos: (B,)."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    q, k, v = attn.project_qkv(lp["attn"], h, pos[:, None], cfg.rope_theta)
    k_l, v_l = kvc.update_kv_layer(k_l, v_l, k, v, slot)
    o = attn.decode_attention(q, k_l, v_l, kv_pos, pos, window=cfg.window)
    x = x + attn.project_out(lp["attn"], o)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    y, _ = _ffn(cfg, lp, h2, kind)
    return x + y, k_l, v_l


# --------------------------------------------------------------------------
# Embedding (with optional modality injection)
# --------------------------------------------------------------------------

def _embed_inputs(cfg: ArchConfig, params, batch):
    x = embed_lookup(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "patches" in batch:
        vp = params["vis_proj"]
        vis = rmsnorm(vp["norm"], batch["patches"], cfg.norm_eps)
        vis = jnp.einsum("bpf,fd->bpd", vis, vp["w"]).astype(x.dtype)
        n = vis.shape[1]
        x = jnp.concatenate([vis, x[:, n:]], axis=1)  # patches fill the front
    return x


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def _scan_layers(cfg: ArchConfig, stacked, x, positions, kind: str,
                 collect_kv: bool):
    def body(carry, lp):
        x, aux = carry
        if collect_kv:
            x, a, kv = _apply_layer(cfg, lp, x, positions, kind, True)
            return (x, aux + a), kv
        x, a = _apply_layer(cfg, lp, x, positions, kind)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    return jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)


def forward(cfg: ArchConfig, params, batch):
    """Full-sequence forward -> (logits (B,S,V), aux_loss)."""
    x = _embed_inputs(cfg, params, batch)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    aux = jnp.float32(0.0)
    if n_dense_layers(cfg):
        (x, a), _ = _scan_layers(cfg, params["dense_layers"], x, positions,
                                 "dense", False)
        aux += a
    kind = "moe" if cfg.moe else "dense"
    (x, a), _ = _scan_layers(cfg, params["layers"], x, positions, kind, False)
    aux += a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_out(params["embed"], x), aux


def prefill(cfg: ArchConfig, params, batch):
    """-> (last-token logits (B,V), cache)."""
    x = _embed_inputs(cfg, params, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    kvs = []
    aux = jnp.float32(0.0)
    if n_dense_layers(cfg):
        (x, a), kv = _scan_layers(cfg, params["dense_layers"], x, positions,
                                  "dense", True)
        kvs.append(kv)
        aux += a
    kind = "moe" if cfg.moe else "dense"
    (x, a), kv = _scan_layers(cfg, params["layers"], x, positions, kind, True)
    kvs.append(kv)
    k = jnp.concatenate([kv[0] for kv in kvs], axis=0) if len(kvs) > 1 else kvs[0][0]
    v = jnp.concatenate([kv[1] for kv in kvs], axis=0) if len(kvs) > 1 else kvs[0][1]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1])
    cache = {"k": k, "v": v, "kv_pos": kvc.prefilled_pos(B, S)}
    return logits, cache


def decode_step(cfg: ArchConfig, params, cache, batch):
    """batch: {"token": (B,1) int32, "pos": (B,) int32} -> (logits, cache)."""
    token, pos = batch["token"], batch["pos"]
    x = embed_lookup(params["embed"], token)
    cache_len = cache["k"].shape[2]
    slot = kvc.cache_slot(pos, cache_len)
    kv_pos = kvc.update_kv_pos(cache["kv_pos"], pos, cache_len)

    # Leading dense layers (Kimi first_k_dense) are processed eagerly —
    # a single scan can't mix layer pytrees of different structure.
    nd = n_dense_layers(cfg)
    kind = "moe" if cfg.moe else "dense"

    def body_uniform(x, xs):
        lp, k_l, v_l = xs
        x, k_l, v_l = _apply_layer_decode(cfg, lp, x, k_l, v_l, kv_pos, pos,
                                          slot, kind)
        return x, (k_l, v_l)

    if nd:
        new_dense = []
        for i in range(nd):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["dense_layers"])
            x, k_i, v_i = _apply_layer_decode(
                cfg, lp, x, cache["k"][i], cache["v"][i], kv_pos, pos, slot,
                "dense")
            new_dense.append((k_i, v_i))
        x, (k_rest, v_rest) = jax.lax.scan(
            body_uniform, x, (params["layers"], cache["k"][nd:], cache["v"][nd:]))
        k_new = jnp.concatenate([jnp.stack([kv[0] for kv in new_dense]), k_rest], 0)
        v_new = jnp.concatenate([jnp.stack([kv[1] for kv in new_dense]), v_rest], 0)
    else:
        x, (k_new, v_new) = jax.lax.scan(
            body_uniform, x, (params["layers"], cache["k"], cache["v"]))

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], x[:, -1])
    return logits, {"k": k_new, "v": v_new, "kv_pos": kv_pos}
