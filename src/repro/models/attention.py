"""Attention: GQA / MHA, sliding-window, chunked (memory-efficient) and
single-token decode variants.  All math in the XLA-native path so the
multi-pod dry-run lowers on any backend; the Pallas flash kernel is used via
``kernels/flash_attn/ops.py`` when running on a real TPU.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import decl
from repro.models.layers import rope

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Declarations
# --------------------------------------------------------------------------

def attention_decl(d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool = False):
    d = {
        "wq": decl((d_model, n_heads, head_dim), ("embed", "heads", None)),
        "wk": decl((d_model, n_kv, head_dim), ("embed", "kv_heads", None)),
        "wv": decl((d_model, n_kv, head_dim), ("embed", "kv_heads", None)),
        "wo": decl((n_heads, head_dim, d_model), ("heads", None, "embed")),
    }
    if qkv_bias:
        d["bq"] = decl((n_heads, head_dim), ("heads", None), init="zeros", dtype=jnp.float32)
        d["bk"] = decl((n_kv, head_dim), ("kv_heads", None), init="zeros", dtype=jnp.float32)
        d["bv"] = decl((n_kv, head_dim), ("kv_heads", None), init="zeros", dtype=jnp.float32)
    return d


def project_qkv(params, x, positions, theta: float, *, apply_rope: bool = True):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if apply_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def project_out(params, o):
    """o: (B, S, H, hd) -> (B, S, D)."""
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


# --------------------------------------------------------------------------
# Masking helpers
# --------------------------------------------------------------------------

def _mask_bias(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """(…, Sq, Sk) additive bias from position constraints."""
    ok = jnp.ones(q_pos.shape[-1:] + kv_pos.shape[-1:], dtype=bool)
    if causal:
        ok &= q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        ok &= q_pos[:, None] - kv_pos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# --------------------------------------------------------------------------
# Full (quadratic) attention — short sequences
# --------------------------------------------------------------------------

def full_attention(q, k, v, q_pos, kv_pos, *, causal: bool,
                   window: Optional[int] = None) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,Sk,K,hd) with H % K == 0."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = scores + _mask_bias(q_pos, kv_pos, causal, window)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return o.reshape(B, Sq, H, hd)


# --------------------------------------------------------------------------
# Flash attention (pure JAX): two-level chunking + custom_vjp that
# recomputes scores in the backward pass.  Without this, scan residuals
# (per-chunk score tensors) dominate device memory.  The Pallas kernel
# (kernels/flash_attn) mirrors this algorithm; this is also its oracle's
# memory-efficient production form.
# --------------------------------------------------------------------------

def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _active_mesh():
    from jax._src.mesh import thread_resources
    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def _constrain_dim(x, dim, axis_name="model"):
    """Pin one dim to a mesh axis (UNCONSTRAINED elsewhere) when a mesh is
    active and sizes divide; no-op otherwise.  This is what keeps the
    q-chunk dim of flash attention sharded through the kv scan — GSPMD
    propagation alone replicates it."""
    m = _active_mesh()
    if m is None or axis_name not in m.axis_names:
        return x
    if x.shape[dim] % m.shape[axis_name] != 0 or x.shape[dim] == 0:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    spec = [PartitionSpec.UNCONSTRAINED] * x.ndim
    spec[dim] = axis_name
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(m, PartitionSpec(*spec)))
    except Exception:
        return x


def _nq_for(Sq, chunk_q):
    """Number of q chunks: prefer the model-axis size so the chunk dim
    shards exactly; fall back to ceil(S/chunk)."""
    m = _active_mesh()
    if m is not None and "model" in m.axis_names:
        ma = m.shape["model"]
        if Sq % ma == 0 and Sq // ma >= 1:
            return ma
    return max(1, -(-Sq // chunk_q))


def _mask_bias_chunks(q_pos_c, kv_pos_c, causal, window):
    """q_pos_c: (nq,Cq); kv_pos_c: (Ck,) -> bias (nq,Cq,Ck)."""
    ok = jnp.ones(q_pos_c.shape + kv_pos_c.shape[-1:], dtype=bool)
    if causal:
        ok &= q_pos_c[..., None] >= kv_pos_c[None, None, :]
    if window is not None:
        ok &= q_pos_c[..., None] - kv_pos_c[None, None, :] < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _chunk_inputs(q, k, v, chunk_q, chunk_k, q_offset, kv_offset):
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    nq = _nq_for(Sq, chunk_q)
    Cq = -(-Sq // nq)
    Ck = min(chunk_k, Sk)
    nk = -(-Sk // Ck)
    q_pos = q_offset + jnp.arange(nq * Cq, dtype=jnp.int32)
    kv_pos = jnp.where(jnp.arange(nk * Ck) < Sk,
                       kv_offset + jnp.arange(nk * Ck, dtype=jnp.int32), 2**30)
    qc = _pad_to(q.reshape(B, Sq, K, G, hd), nq * Cq, 1)         .reshape(B, nq, Cq, K, G, hd)
    qc = _constrain_dim(qc, 1)
    kcs = _pad_to(k, nk * Ck, 1).reshape(B, nk, Ck, K, hd).transpose(1, 0, 2, 3, 4)
    vcs = _pad_to(v, nk * Ck, 1).reshape(B, nk, Ck, K, hd).transpose(1, 0, 2, 3, 4)
    pcs = kv_pos.reshape(nk, Ck)
    qpos_c = q_pos.reshape(nq, Cq)
    return qc, kcs, vcs, pcs, qpos_c, (B, Sq, Sk, H, K, G, hd, nq, Cq, nk, Ck)


def _flash_impl(q, k, v, causal, window, chunk_q, chunk_k,
                q_offset, kv_offset):
    qc, kcs, vcs, pcs, qpos_c, dims = _chunk_inputs(
        q, k, v, chunk_q, chunk_k, q_offset, kv_offset)
    B, Sq, Sk, H, K, G, hd, nq, Cq, nk, Ck = dims
    scale = 1.0 / math.sqrt(hd)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s = jnp.einsum("bnckgh,bskh->bnkgcs", qc, kb,
                       preferred_element_type=jnp.float32) * scale
        s = s + _mask_bias_chunks(qpos_c, pb, causal, window)[:, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bnkgcs,bskh->bnkgch", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = _constrain_dim(jnp.full((B, nq, K, G, Cq), NEG_INF, jnp.float32), 1)
    l0 = _constrain_dim(jnp.zeros((B, nq, K, G, Cq), jnp.float32), 1)
    a0 = _constrain_dim(jnp.zeros((B, nq, K, G, Cq, hd), jnp.float32), 1)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step, prevent_cse=False),
                                  (m0, l0, a0), (kcs, vcs, pcs))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))          # (B,nq,K,G,Cq)
    o = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,nq,K,G,Cq,hd)
    o = o.transpose(0, 1, 4, 2, 3, 5).reshape(B, nq * Cq, H, hd)[:, :Sq]
    return o.astype(q.dtype), lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal=True, window=None, chunk_q=512,
                    chunk_k=1024, q_offset=0, kv_offset=0):
    o, _ = _flash_impl(q, k, v, causal, window, chunk_q, chunk_k,
                       q_offset, kv_offset)
    return o


def _flash_fwd(q, k, v, causal, window, chunk_q, chunk_k, q_offset, kv_offset):
    o, lse = _flash_impl(q, k, v, causal, window, chunk_q, chunk_k,
                         q_offset, kv_offset)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, chunk_q, chunk_k, q_offset, kv_offset,
               res, do):
    q, k, v, o, lse = res
    qc, kcs, vcs, pcs, qpos_c, dims = _chunk_inputs(
        q, k, v, chunk_q, chunk_k, q_offset, kv_offset)
    B, Sq, Sk, H, K, G, hd, nq, Cq, nk, Ck = dims
    scale = 1.0 / math.sqrt(hd)

    D = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    doc = _constrain_dim(_pad_to(do.reshape(B, Sq, K, G, hd), nq * Cq, 1)
                         .reshape(B, nq, Cq, K, G, hd), 1)
    Dc = _constrain_dim(_pad_to(D.reshape(B, Sq, K, G), nq * Cq, 1)
                        .reshape(B, nq, Cq, K, G), 1)
    lse_e = lse[..., None]                             # (B,nq,K,G,Cq,1)

    def step(dq, inp):
        kb, vb, pb = inp
        s = jnp.einsum("bnckgh,bskh->bnkgcs", qc, kb,
                       preferred_element_type=jnp.float32) * scale
        s = s + _mask_bias_chunks(qpos_c, pb, causal, window)[:, None, None]
        p = jnp.exp(s - lse[..., None])
        dv_c = jnp.einsum("bnkgcs,bnckgh->bskh", p,
                          doc.astype(jnp.float32))
        dp = jnp.einsum("bnckgh,bskh->bnkgcs", doc, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - Dc.transpose(0, 1, 3, 4, 2)[..., None]) * scale
        dq = dq + jnp.einsum("bnkgcs,bskh->bnckgh", ds, kb)
        dk_c = jnp.einsum("bnkgcs,bnckgh->bskh", ds, qc.astype(jnp.float32))
        return dq, (dk_c, dv_c)

    dq0 = _constrain_dim(jnp.zeros((B, nq, Cq, K, G, hd), jnp.float32), 1)
    dq, (dk_s, dv_s) = jax.lax.scan(jax.checkpoint(step, prevent_cse=False),
                                    dq0, (kcs, vcs, pcs))
    dq = dq.reshape(B, nq * Cq, H, hd)[:, :Sq]
    dk = dk_s.transpose(1, 0, 2, 3, 4).reshape(B, nk * Ck, K, hd)[:, :Sk]
    dv = dv_s.transpose(1, 0, 2, 3, 4).reshape(B, nk * Ck, K, hd)[:, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention(q, k, v, q_pos, kv_pos, *, causal: bool,
              window: Optional[int] = None, chunk: int = 1024,
              chunk_threshold: int = 1024) -> jax.Array:
    """Dispatch: exact quadratic for short kv, flash for long.  q_pos/kv_pos
    must be contiguous ranges for the flash path (always true for our
    training/prefill calls); decode uses decode_attention instead."""
    if k.shape[1] <= chunk_threshold:
        return full_attention(q, k, v, q_pos, kv_pos, causal=causal, window=window)
    return flash_attention(q, k, v, causal, window, min(chunk // 2, 512),
                           chunk)


# --------------------------------------------------------------------------
# Single-token decode attention
# --------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, kv_pos, pos, *,
                     window: Optional[int] = None) -> jax.Array:
    """q: (B,1,H,hd); caches: (B,S,K,hd); kv_pos: (B,S) absolute positions
    stored in each cache slot (-1 = empty); pos: (B,) current position."""
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    ok = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window is not None:
        ok &= pos[:, None] - kv_pos < window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache)
    return o.reshape(B, 1, H, hd)
