"""Input construction: ShapeDtypeStruct stand-ins (dry-run) or real arrays
(smoke tests) for every (arch x shape) cell, plus their PartitionSpecs.

Modality frontends are stubs per the assignment: whisper gets precomputed
frame embeddings, internvl2 gets precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model_api import cache_len_for


def batch_struct(cfg: ArchConfig, shape: ShapeConfig, clients: int = 0):
    """Abstract batch pytree.  clients>0 prepends an FL-clients dim
    (training only)."""
    gb, S = shape.global_batch, shape.seq_len

    def shp(*dims):
        if clients:
            assert dims[0] % clients == 0, (dims, clients)
            return (clients, dims[0] // clients) + tuple(dims[1:])
        return tuple(dims)

    if shape.kind == "train":
        b = {
            "tokens": jax.ShapeDtypeStruct(shp(gb, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct(shp(gb, S), jnp.int32),
        }
    elif shape.kind == "prefill":
        b = {"tokens": jax.ShapeDtypeStruct((gb, S), jnp.int32)}
    else:  # decode
        b = {
            "token": jax.ShapeDtypeStruct((gb, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((gb,), jnp.int32),
        }
    if shape.kind in ("train", "prefill"):
        fe = cfg.frontend
        if cfg.family == "encdec":
            b["frames"] = jax.ShapeDtypeStruct(
                shp(gb, fe.n_tokens, fe.feat_dim), jnp.bfloat16)
        elif cfg.family == "vlm":
            b["patches"] = jax.ShapeDtypeStruct(
                shp(gb, fe.n_tokens, fe.feat_dim), jnp.bfloat16)
    return b


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, clients: int = 0,
                client_axis: Optional[str] = None, data_axis: str = "data",
                seq_axis: Optional[str] = "model",
                axis_sizes: Optional[dict] = None):
    """PartitionSpecs matching batch_struct.  The sequence dim shards over
    the ``model`` axis (sequence parallelism): activations stay bounded even
    for 32k prefill, and attention q stays seq-sharded through the chunked
    online-softmax scan.  Dims that don't divide their mesh axis replicate
    (e.g. global_batch=1 for long_500k)."""
    sizes = axis_sizes or {"data": 16, "model": 16, "pod": 2}

    def ok(dim, ax):
        return ax is not None and dim % sizes.get(ax, 1) == 0

    def sp(shp, has_seq):
        parts = []
        i = 0
        if clients:
            parts.append(client_axis if ok(shp[0], client_axis) else None)
            i = 1
            ax = data_axis if client_axis != data_axis else None
            parts.append(ax if len(shp) > 1 and ok(shp[1], ax) else None)
        else:
            parts.append(data_axis if ok(shp[0], data_axis) else None)
        if has_seq and len(shp) > len(parts):
            sax = seq_axis if ok(shp[len(parts)], seq_axis) else None
            parts.append(sax)
        parts += [None] * (len(shp) - len(parts))
        return P(*parts[:len(shp)])

    b = batch_struct(cfg, shape, clients)
    out = {}
    for k, v in b.items():
        has_seq = k in ("tokens", "labels") and shape.kind != "decode"
        out[k] = sp(v.shape, has_seq)
    return out


def make_batch(cfg: ArchConfig, shape: ShapeConfig, key, clients: int = 0):
    """Concrete random batch (smoke tests / examples)."""
    structs = batch_struct(cfg, shape, clients)
    out = {}
    for name, st in structs.items():
        key, sub = jax.random.split(key)
        if st.dtype == jnp.int32 and name in ("tokens", "labels", "token"):
            out[name] = jax.random.randint(sub, st.shape, 0, cfg.vocab, jnp.int32)
        elif name == "pos":
            out[name] = jnp.full(st.shape, shape.seq_len - 1, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, st.shape, jnp.float32).astype(st.dtype)
    return out
