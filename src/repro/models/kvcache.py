"""KV caches (full-length and sliding-window ring buffers) + recurrent
state declarations.  Cache layout: stacked over layers for scan.

Decode caches shard the *sequence* dim over the ``model`` mesh axis
("cache_seq" rule) and batch over ``data`` — a 32k-decode cache for
Kimi-K2 would be ~57 GB/chip replicated, but is ~3.6 GB/chip seq-sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import decl


def kv_cache_decl(n_layers: int, batch: int, cache_len: int, n_kv: int,
                  head_dim: int, dtype=jnp.bfloat16, prefix: str = ""):
    return {
        prefix + "k": decl((n_layers, batch, cache_len, n_kv, head_dim),
                           ("layers", "batch", "cache_seq", "kv_heads", None),
                           init="zeros", dtype=dtype),
        prefix + "v": decl((n_layers, batch, cache_len, n_kv, head_dim),
                           ("layers", "batch", "cache_seq", "kv_heads", None),
                           init="zeros", dtype=dtype),
        prefix + "kv_pos": decl((batch, cache_len), ("batch", "cache_seq"),
                                init="neg_ones", dtype=jnp.int32),
    }


def cache_slot(pos: jax.Array, cache_len: int) -> jax.Array:
    """Ring-buffer slot for absolute position ``pos`` (scalar or (B,))."""
    return jnp.asarray(pos) % cache_len


def update_kv_layer(k_l, v_l, new_k, new_v, slot):
    """Insert one token into a layer's cache.  k_l: (B,S,K,hd);
    new_k: (B,1,K,hd); slot: (B,)."""
    b = jnp.arange(k_l.shape[0])
    k_l = k_l.at[b, slot].set(new_k[:, 0])
    v_l = v_l.at[b, slot].set(new_v[:, 0])
    return k_l, v_l


def update_kv_pos(kv_pos, pos, cache_len):
    """kv_pos: (B,S); pos: (B,) absolute position being written."""
    b = jnp.arange(kv_pos.shape[0])
    return kv_pos.at[b, cache_slot(pos, cache_len)].set(pos)


def prefilled_pos(batch: int, seq: int):
    """kv_pos array describing a fully prefilled cache of length seq."""
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))


def pad_cache(cache: dict, max_len: int) -> dict:
    """Grow a prefilled cache's sequence capacity to ``max_len`` (empty
    slots marked kv_pos=-1).  Required before decoding past the prompt
    length on full-attention models; windowed caches wrap instead."""
    out = dict(cache)
    if "k" not in cache:
        return out                      # recurrent state (rwkv): nothing to do
    cur = cache["k"].shape[2]
    extra = max_len - cur
    if extra <= 0:
        return out
    for key in ("k", "v"):
        pad = [(0, 0)] * cache[key].ndim
        pad[2] = (0, extra)
        out[key] = jnp.pad(cache[key], pad)
    out["kv_pos"] = jnp.pad(cache["kv_pos"], ((0, 0), (0, extra)),
                            constant_values=-1)
    return out
