"""Render EXPERIMENTS.md tables from the dry-run JSON records."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(out_dir: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(recs, mesh: str) -> str:
    rows = ["| arch | shape | status | bytes/dev GiB | flops/dev | "
            "coll GB | HLO collectives |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped ({r['reason'][:40]}...) | | | | |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | |")
            continue
        rf = r["roofline"]
        counts = ", ".join(f"{k}:{int(v)}" for k, v in
                           sorted(rf["collective_counts"].items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{fmt_bytes(r['memory'].get('total_per_device', 0))} | "
            f"{rf['flops_per_dev']:.2e} | "
            f"{rf['collective_bytes'] / 1e9:.2f} | {counts} |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS | useful ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != "pod" or r["status"] != "ok":
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"**{rf['dominant']}** | {rf['model_flops_total']:.2e} | "
            f"{rf['useful_flops_ratio']:.3f} | {rf['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def worst_cells(recs, k=6):
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "pod"
          and r["shape"] == "train_4k"]
    ok.sort(key=lambda r: r["roofline"]["roofline_fraction"])
    return [(r["arch"], r["shape"], round(r["roofline"]["roofline_fraction"], 4),
             r["roofline"]["dominant"]) for r in ok[:k]]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--what", default="all",
                    choices=["all", "dryrun", "roofline", "worst"])
    args = ap.parse_args()
    recs = load(args.dir)
    if args.what in ("all", "dryrun"):
        print("### Single-pod (16x16)\n")
        print(dryrun_table(recs, "pod"))
        print("\n### Multi-pod (2x16x16)\n")
        print(dryrun_table(recs, "multipod"))
    if args.what in ("all", "roofline"):
        print("\n### Roofline (single-pod)\n")
        print(roofline_table(recs))
    if args.what in ("all", "worst"):
        print("\nworst train cells:", worst_cells(recs))


if __name__ == "__main__":
    main()
