"""End-to-end SDFLMQ training driver.

Wires the whole stack together:
  control plane — SimBroker + Coordinator + SDFLMQClients + ParameterServer
                  run the paper's session protocol (create/join, clustering,
                  role (re)arrangement via topics, readiness/stats updates);
  data plane    — the coordinator's cluster tree is compiled to an
                  AggSchedule and executed as ONE jitted fl_round_step per
                  round (local steps + hierarchical aggregation);
  substrate     — federated token streams (non-IID), checkpoint manager
                  (resume-exact), failure injection -> LWT -> role
                  rearrangement, straggler demotion.

Compiled steps are cached per schedule signature: a role rearrangement that
reuses a previously-seen topology costs a dict lookup (the compiled-world
analogue of the paper's "only affected clients re-subscribe").

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --rounds 8 --local-steps 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.federation import Federation
from repro.configs.base import ShapeConfig, get_arch, smoke_config
from repro.ckpt.manager import CheckpointManager
from repro.core.fl_step import build_fl_round_step, init_state, n_clients_for
from repro.core.stats import StatsSimulator
from repro.core.topology import compile_tree, flat_schedule
from repro.data.federated import FederatedTokens
from repro.ft.failures import FailurePlan, demote_stragglers
from repro.launch.mesh import make_host_mesh


class SDFLMQTrainer:
    def __init__(self, cfg, mesh, n_clients: int, rounds: int,
                 batch_per_client: int, seq: int, ckpt_dir: str | None = None,
                 schedule_kind: str = "tree", seed: int = 0,
                 failure_plan: FailurePlan | None = None,
                 strategy: str = "fedavg",
                 update_filter=None):
        self.cfg, self.mesh, self.rounds = cfg, mesh, rounds
        self.n = n_clients
        self.batch_per_client, self.seq = batch_per_client, seq
        self.schedule_kind = schedule_kind
        self.strategy = strategy
        self.update_filter = update_filter
        self.failures = failure_plan or FailurePlan()

        # ---- control plane (via the repro.api facade) ----------------
        self.fed = Federation(role_policy=cfg.fl.role_policy,
                              aggregator_ratio=cfg.fl.aggregator_ratio,
                              levels=cfg.fl.levels)
        self.broker = self.fed.transport
        self.coord = self.fed.coordinator
        self.ps = self.fed.param_server
        self.sim = StatsSimulator([f"c{i}" for i in range(n_clients)],
                                  seed=seed)
        sid = self.sid = "train_session"
        members = [self.fed.client(f"c{i}",
                                   preferred_role="aggregator" if i % 3 == 0
                                   else "trainer",
                                   stats=self.sim.sample(f"c{i}", 0))
                   for i in range(n_clients)]
        self.session = self.fed.create_session(
            sid, cfg.name, rounds, participants=members, strategy=strategy)
        self.clients = self.session.participants
        assert self.session.state == "running"

        # ---- data plane ----------------------------------------------
        self.data = FederatedTokens(cfg.vocab, n_clients, seed=seed)
        self.state = init_state(cfg, mesh, jax.random.PRNGKey(seed),
                                total_steps=rounds * cfg.fl.local_steps,
                                update_filter=update_filter)
        self._compiled = {}
        self.ckpt = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
        self.start_round = 0
        if self.ckpt:
            restored, meta = self.ckpt.restore_latest(like=self.state)
            if restored is not None:
                self.state = jax.tree_util.tree_map(jnp.asarray, restored)
                self.start_round = int(meta["step"])
        self.metrics: list[dict] = []
        self.latencies: dict[str, float] = {}

    # ------------------------------------------------------------------
    def _schedule(self):
        if self.schedule_kind != "tree":
            from repro.core.topology import AggSchedule
            return AggSchedule(self.schedule_kind, self.n)
        tree = self.coord.tree_of(self.sid)
        # clients keep their original mesh row; dead rows ride zero-weighted
        index_of = {cid: int(cid[1:]) for cid in tree.client_order}
        return compile_tree(tree, axis_size=self.n, index_of=index_of)

    def _step_for(self, schedule):
        key = schedule.signature()
        if key not in self._compiled:
            self._compiled[key] = jax.jit(
                build_fl_round_step(self.cfg, self.mesh, schedule,
                                    strategy=self.strategy,
                                    update_filter=self.update_filter))
        return self._compiled[key]

    def run(self) -> list[dict]:
        sid = self.sid
        weights_np = np.array(
            [self.clients[f"c{i}"].stats.samples or 1.0
             for i in range(self.n)], np.float32)
        for r in range(self.start_round, self.rounds):
            t0 = time.perf_counter()
            # failure injection -> LWT -> coordinator rearranges; the dead
            # client's mesh row gets zero FedAvg weight (sums unaffected)
            for dead in self.failures.fail_at.get(r, []):
                if dead in self.clients:
                    self.session.fail(dead)
                    weights_np[int(dead[1:])] = 0.0
            schedule = self._schedule()
            step = self._step_for(schedule)
            batch_np = self.data.global_batch(
                self.n, self.batch_per_client, self.seq, r)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            with self.mesh:
                self.state, m = step(self.state, batch,
                                     jnp.asarray(weights_np))
            loss = float(m["loss"])
            dt = time.perf_counter() - t0
            self.metrics.append({"round": r, "loss": loss, "time_s": dt,
                                 "schedule": schedule.signature(),
                                 "n_clients": len(self.clients)})
            # round-status updates: stats + readiness -> role optimization
            slow = self.failures.straggle_at.get(r, {})
            for cid, cl in list(self.clients.items()):
                st = self.sim.sample(cid, r + 1)
                st.last_round_s = dt * slow.get(cid, 1.0)
                st.samples = int(weights_np[int(cid[1:])])
                self.latencies[cid] = st.last_round_s
                cl.signal_ready(sid, stats=st)
            if self.ckpt and self.ckpt.should_save(r + 1):
                self.ckpt.save(r + 1, self.state, {"loss": loss})
        return self.metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--schedule", default="tree",
                    choices=["tree", "flat", "rs_ag"])
    ap.add_argument("--strategy", default="fedavg",
                    help="aggregation strategy (repro.api.strategies)")
    ap.add_argument("--update-filter", default=None,
                    help="partial-update ParamFilter patterns "
                         "(comma-separated globs, ! prefix excludes); only "
                         "matching leaves train and aggregate")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data-mesh", type=int, default=0,
                    help="data axis size (0 = #clients)")
    ap.add_argument("--model-mesh", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    cfg = cfg.replace(fl=cfg.fl.__class__(
        mode="replica", local_steps=args.local_steps,
        aggregator_ratio=cfg.fl.aggregator_ratio, levels=cfg.fl.levels,
        schedule=args.schedule, role_policy=cfg.fl.role_policy))
    n_dev = len(jax.devices())
    data_ax = args.data_mesh or args.clients
    assert data_ax * args.model_mesh <= n_dev, \
        f"need {data_ax * args.model_mesh} devices, have {n_dev} " \
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
    mesh = make_host_mesh(data=data_ax, model=args.model_mesh)
    trainer = SDFLMQTrainer(cfg, mesh, args.clients, args.rounds,
                            args.batch_per_client, args.seq,
                            ckpt_dir=args.ckpt_dir,
                            schedule_kind=args.schedule,
                            strategy=args.strategy,
                            update_filter=args.update_filter)
    for m in trainer.run():
        print(f"round {m['round']:3d} loss {m['loss']:.4f} "
              f"{m['time_s']:.2f}s sched={m['schedule']} "
              f"clients={m['n_clients']}")


if __name__ == "__main__":
    main()
