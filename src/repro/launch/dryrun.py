import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization.  Only the dry-run uses 512 placeholder
# devices; tests/benches see the real host device.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import (SHAPES, get_arch, list_archs,  # noqa: E402
                                shape_applicable)
from repro.core.clustering import build_tree  # noqa: E402
from repro.core.fl_step import (abstract_state, build_fl_round_step,  # noqa: E402
                                client_axis_for, n_clients_for)
from repro.core.topology import compile_tree, flat_schedule  # noqa: E402
from repro.dist import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import build_roofline, model_flops  # noqa: E402
from repro.models import inputs as minputs  # noqa: E402
from repro.models import model_api  # noqa: E402
from repro.optim.api import make_optimizer  # noqa: E402


# --------------------------------------------------------------------------
# Parameter accounting
# --------------------------------------------------------------------------

def param_counts(cfg):
    """(total, active) parameter counts; active discounts routed experts."""
    decls = model_api.param_decls(cfg)
    total = shd.param_count(decls)
    if cfg.moe is None:
        return total, total
    leaves = jax.tree_util.tree_leaves(decls, is_leaf=shd.is_decl)
    expert_n = sum(l.size for l in leaves if "experts" in l.axes)
    frac = cfg.moe.top_k / cfg.moe.n_experts
    active = total - expert_n + expert_n * frac
    return total, int(active)


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, shardings attached — no alloc)
# --------------------------------------------------------------------------

def _attach(tree, spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda st, sp: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                            sharding=NamedSharding(mesh, sp)),
        tree, spec_tree)


def input_specs(cfg, shape, mesh):
    """Abstract inputs for one cell: everything train/serve lowering needs."""
    kind = shape.kind
    if kind == "train":
        n = n_clients_for(cfg, mesh)
        ax = client_axis_for(cfg, mesh)
        clients = n if n > 1 else 0
        batch = minputs.batch_struct(cfg, shape, clients)
        specs = minputs.batch_specs(cfg, shape, clients, client_axis=ax)
        batch = _attach(batch, specs, mesh)
        opt = make_optimizer(cfg)
        state = abstract_state(cfg, mesh, opt.name)
        weights = jax.ShapeDtypeStruct((max(n, 1),), jnp.float32,
                                       sharding=NamedSharding(
                                           mesh, P(ax) if n > 1 else P()))
        return {"state": state, "batch": batch, "weights": weights}

    # serving: global (non-client) params
    rules = shd.rules_for(cfg.fl.mode)
    decls = model_api.param_decls(cfg)
    pspecs = shd.specs_for(decls, rules, mesh)
    params = _attach(shd.abstract(decls), pspecs, mesh)
    batch = minputs.batch_struct(cfg, shape)
    bspecs = minputs.batch_specs(cfg, shape)
    batch = _attach(batch, bspecs, mesh)
    if kind == "prefill":
        return {"params": params, "batch": batch}
    # decode: cache
    model = model_api.get_model(cfg)
    clen = model_api.cache_len_for(cfg, shape.seq_len)
    cdecls = model.cache_decl(cfg, shape.global_batch, max(clen, 1))
    cspecs = shd.specs_for(cdecls, rules, mesh)
    cache = _attach(shd.abstract(cdecls), cspecs, mesh)
    return {"params": params, "batch": batch, "cache": cache}


# --------------------------------------------------------------------------
# Cell lowering
# --------------------------------------------------------------------------

def make_schedule(cfg, mesh, kind=None):
    n = n_clients_for(cfg, mesh)
    kind = kind or cfg.fl.schedule
    if n <= 1:
        return flat_schedule(max(n, 1))
    if kind == "tree":
        clients = [f"c{i}" for i in range(n)]
        tree = build_tree("dryrun", clients, clients,
                          cfg.fl.aggregator_ratio, cfg.fl.levels)
        return compile_tree(tree)
    from repro.core.topology import AggSchedule
    return AggSchedule(kind, n)


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               schedule: str = None, donate: bool = True,
               moe_impl: str = None, overrides: dict = None):
    cfg = get_arch(arch_name)
    if moe_impl and cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, impl=moe_impl))
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped", "reason": why}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(cfg, shape, mesh)
    model = model_api.get_model(cfg)

    with jax.default_device(jax.devices()[0]):
        if shape.kind == "train":
            sched = make_schedule(cfg, mesh, schedule)
            step = build_fl_round_step(cfg, mesh, sched)
            fn = jax.jit(step, donate_argnums=(0,) if donate else ())
            with mesh:
                lowered = fn.lower(specs["state"], specs["batch"],
                                   specs["weights"])
        elif shape.kind == "prefill":
            fn = jax.jit(lambda p, b: model.prefill(cfg, p, b))
            with mesh:
                lowered = fn.lower(specs["params"], specs["batch"])
        else:
            fn = jax.jit(lambda p, c, b: model.decode_step(cfg, p, c, b),
                         donate_argnums=(1,) if donate else ())
            with mesh:
                lowered = fn.lower(specs["params"], specs["cache"],
                                   specs["batch"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[k] = int(getattr(ma, k, 0))
        mem["total_per_device"] = (mem.get("argument_size_in_bytes", 0)
                                   + mem.get("output_size_in_bytes", 0)
                                   + mem.get("temp_size_in_bytes", 0)
                                   - mem.get("alias_size_in_bytes", 0))
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    total_p, active_p = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(active_p, tokens, "train")
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(active_p, tokens, "serve")
    else:
        tokens = shape.global_batch
        mf = model_flops(active_p, tokens, "serve")

    n_dev = mesh.devices.size
    rf = build_roofline(compiled, n_dev, mf)
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "status": "ok",
        "n_devices": n_dev,
        "schedule": schedule or cfg.fl.schedule,
        "moe_impl": cfg.moe.impl if cfg.moe else None,
        "params_total": total_p, "params_active": active_p,
        "tokens": tokens,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem,
        "roofline": rf.to_dict(),
    }
    return rec


# --------------------------------------------------------------------------

def cell_list():
    cells = []
    for a in list_archs():
        for s in SHAPES:
            cells.append((a, s))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--schedule", default=None,
                    choices=[None, "tree", "flat", "rs_ag", "compressed"])
    ap.add_argument("--moe-impl", default=None, choices=[None, "auto", "ep_a2a", "tp_local"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        cells = cell_list()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
            if args.schedule:
                tag += f"__{args.schedule}"
            if args.moe_impl:
                tag += f"__{args.moe_impl}"
            path = os.path.join(args.out, tag + ".json")
            try:
                rec = lower_cell(arch, shape, mp, args.schedule,
                                 moe_impl=args.moe_impl)
            except Exception as e:
                failures += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multipod" if mp else "pod",
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            st = rec["status"]
            extra = ""
            if st == "ok":
                r = rec["roofline"]
                extra = (f" dom={r['dominant']} comp={r['compute_s']:.4f}s"
                         f" mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s"
                         f" frac={r['roofline_fraction']:.3f}"
                         f" bytes/dev={rec['memory'].get('total_per_device', 0)/2**30:.2f}GiB"
                         f" compile={rec['compile_s']}s")
            elif st == "error":
                extra = " " + rec["error"][:160]
            else:
                extra = " " + rec["reason"][:80]
            print(f"[{st:7s}] {tag}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
