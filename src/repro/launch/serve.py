"""Serving driver: batched prefill+decode over a (reduced or full) assigned
architecture — the inference-side counterpart of launch/train.py.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch, smoke_config
from repro.models import model_api
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a TPU pod)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = smoke_config(cfg)
    params = model_api.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, batch_size=args.batch_size)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        engine.submit(rng.integers(0, cfg.vocab,
                                   size=rng.integers(4, args.prompt_len + 1)),
                      max_new=args.max_new)
    done = engine.run()
    wall = time.perf_counter() - t0
    s = engine.stats
    out_toks = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {out_toks} tokens in {wall:.2f}s "
          f"({out_toks / wall:.1f} tok/s end-to-end)")
    print(f"prefill: {s['prefill_tokens']} tok {s['prefill_s']:.2f}s | "
          f"decode: {s['decode_steps']} steps {s['decode_s']:.2f}s")


if __name__ == "__main__":
    main()
