"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the ``pod``
axis rides on DCN (broker-bridging analogue), ``data``/``model`` on ICI.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2, pods: int = 0):
    """Small mesh over however many (CPU) devices exist — tests/examples."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware model (per chip) — roofline constants.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link (conservative single-link)
DCN_BW = 6.25e9                # B/s per chip cross-pod (50 Gbps)
