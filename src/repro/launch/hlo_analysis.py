"""Loop-aware static analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, but our models
are scan-over-layers (and flash attention / linear attention scan over
chunks), so raw numbers under-count by ~n_layers x n_chunks.  This analyzer
parses the post-SPMD HLO, recovers each while loop's trip count
(``backend_config known_trip_count``, falling back to the loop condition's
comparison constant), and scales:

  * FLOPs        — from dot ops: 2 x prod(result_dims) x prod(contract_dims)
                   (operand shapes resolved through a module-wide symbol
                   table — optimized HLO does not inline operand shapes),
  * HBM bytes    — operand+result bytes at materialization boundaries
                   (fusion outputs, dots, copies, collectives, slices, ...),
  * collective wire bytes — per op kind with ring scaling 2(g-1)/g for
                   all-reduce, (g-1)/g for all-gather/reduce-scatter, and
                   cross-pod detection from replica-group span.

All numbers are per-device (the partitioned module is the per-device
program).  cost_analysis raw values are reported alongside for reference.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# first lowercase-word-followed-by-paren after the type is the opcode
# (dtypes are followed by '[', tuple types by more shapes, comments by '=')
_OPCODE_CALL_RE = re.compile(r"\b([a-z][\w\-]*)\(")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_PAIR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcodes treated as materialization boundaries for HBM-byte accounting.
# A TPU compilation fuses elementwise chains into their consumers, so a
# stray top-level `add`/`convert` in the CPU-lowered module is NOT priced
# as HBM traffic; only genuinely materializing ops are.
_MEM_OPS = {
    "fusion", "dot", "convolution", "copy", "transpose",
    "reduce", "dynamic-update-slice", "dynamic-slice",
    "gather", "scatter", "concatenate", "sort",
    "select-and-scatter", "reduce-window",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES} \
  | {c + "-done" for c in COLLECTIVES}


def _shape_bytes_str(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_of(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class OpLine:
    name: str
    result: str
    opcode: str
    rest: str             # text after the opening paren of operands


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    is_fused: bool = False


def _operands(rest: str) -> list[str]:
    """Operand names: the %refs before the closing paren of the op call."""
    return _OPERAND_RE.findall(rest.split(")")[0])


def parse_module(hlo: str):
    comps: dict[str, Computation] = {}
    symtab: dict[str, str] = {}       # op name -> result shape string
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{") and _COMP_HDR.match(stripped):
            cur = Computation(_COMP_HDR.match(stripped).group(1))
            comps[cur.name] = cur
            continue
        if stripped == "}" or stripped.startswith("} "):
            cur = None
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, body = m.groups()
        om = _OPCODE_CALL_RE.search(body)
        if not om:
            continue
        result = body[:om.start()]
        opcode = om.group(1)
        rest = body[om.end():]
        op = OpLine(name, result, opcode.lower(), rest)
        symtab[name] = result
        if cur is not None:
            cur.ops.append(op)
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                fm = _CALLS_RE.search(op.rest)
                if fm and fm.group(1) in comps:
                    comps[fm.group(1)].is_fused = True
    return comps, symtab


def _trip_count(op: OpLine, comps, symtab) -> int:
    m = _TRIP_RE.search(op.rest)
    if m:
        return max(int(m.group(1)), 1)
    wm = _WHILE_RE.search(op.rest)
    if wm and wm.group(1) in comps:
        best = 1
        for cop in comps[wm.group(1)].ops:
            for cm in _CONST_RE.finditer(cop.rest):
                best = max(best, int(cm.group(1)))
        return best
    return 1


def _dot_flops(op: OpLine, symtab) -> float:
    out = 1
    for d in _dims_of(op.result):
        out *= d
    ops = _operands(op.rest)
    if not ops:
        return 0.0
    lhs_dims = _dims_of(symtab.get(ops[0], ""))
    cm = _LHS_C_RE.search(op.rest)
    contract = 1
    if cm:
        for idx in [int(i) for i in cm.group(1).split(",") if i]:
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * out * contract


def _group_size(rest: str, default: int):
    m = _GROUPS_PAIR_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        return max(len(first.split(",")), 1) if first else 1
    return default


def _operand_bytes(op: OpLine, symtab) -> int:
    return sum(_shape_bytes_str(symtab.get(o, "")) for o in _operands(op.rest))


@dataclass
class HLOCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_cross_pod_bytes: float = 0.0
    coll_per_op: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)
    while_trips: list = field(default_factory=list)
    hbm_per_op: dict = field(default_factory=dict)

    def merge_scaled(self, other: "HLOCost", k: float):
        self.flops += other.flops * k
        self.hbm_bytes += other.hbm_bytes * k
        self.coll_bytes += other.coll_bytes * k
        self.coll_cross_pod_bytes += other.coll_cross_pod_bytes * k
        for key, v in other.coll_per_op.items():
            self.coll_per_op[key] = self.coll_per_op.get(key, 0.0) + v * k
        for key, v in other.coll_counts.items():
            self.coll_counts[key] = self.coll_counts.get(key, 0) + v * k
        for key, v in other.hbm_per_op.items():
            self.hbm_per_op[key] = self.hbm_per_op.get(key, 0.0) + v * k
        self.while_trips.extend(other.while_trips)


def analyze(hlo: str, n_devices: int, pod_size: int = 256) -> HLOCost:
    comps, symtab = parse_module(hlo)
    memo: dict[str, HLOCost] = {}

    def comp_cost(name: str, stack=()) -> HLOCost:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return HLOCost()
        c = comps[name]
        total = HLOCost()
        for op in c.ops:
            kind = op.opcode.replace("-start", "").replace("-done", "")
            if op.opcode == "while":
                wm = _WHILE_RE.search(op.rest)
                if wm:
                    trips = _trip_count(op, comps, symtab)
                    inner = HLOCost()
                    inner.merge_scaled(comp_cost(wm.group(2), stack + (name,)), 1)
                    t = HLOCost()
                    t.merge_scaled(inner, trips)
                    t.while_trips = [trips] + inner.while_trips
                    total.merge_scaled(t, 1)
                continue
            if op.opcode in ("call", "map", "custom-call"):
                cm = _CALLS_RE.search(op.rest) or _TO_APPLY_RE.search(op.rest)
                if cm:
                    total.merge_scaled(comp_cost(cm.group(1), stack + (name,)), 1)
                continue
            if op.opcode == "conditional":
                bm = _BRANCHES_RE.search(op.rest)
                if bm:
                    for b in bm.group(1).replace("%", "").split(","):
                        total.merge_scaled(
                            comp_cost(b.strip(), stack + (name,)), 1)
                continue
            if op.opcode == "fusion":
                fm = _CALLS_RE.search(op.rest)
                if fm:
                    inner = comp_cost(fm.group(1), stack + (name,))
                    total.flops += inner.flops   # dots inside fusions are real
                fb = _shape_bytes_str(op.result) + _operand_bytes(op, symtab)
                total.hbm_bytes += fb
                total.hbm_per_op["fusion"] = total.hbm_per_op.get("fusion", 0.0) + fb
                continue
            if kind in COLLECTIVES and "done" not in op.opcode:
                size = _shape_bytes_str(op.result)
                if kind in ("all-gather", "reduce-scatter"):
                    size = max(size, _operand_bytes(op, symtab))
                g = _group_size(op.rest, n_devices)
                if kind == "all-reduce":
                    wire = 2.0 * (g - 1) / g * size
                elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
                    wire = (g - 1) / g * size
                else:
                    wire = float(size)
                total.coll_bytes += wire
                total.coll_per_op[kind] = total.coll_per_op.get(kind, 0.0) + wire
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                if g > pod_size:
                    total.coll_cross_pod_bytes += wire
                total.hbm_bytes += _shape_bytes_str(op.result)
                continue
            if op.opcode == "dot":
                total.flops += _dot_flops(op, symtab)
            if op.opcode in _MEM_OPS and not c.is_fused:
                b = (_shape_bytes_str(op.result)
                     + _operand_bytes(op, symtab))
                total.hbm_bytes += b
                total.hbm_per_op[op.opcode] = total.hbm_per_op.get(op.opcode, 0.0) + b
        memo[name] = total
        return total

    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    entry_name = m.group(1) if m else next(iter(comps))
    return comp_cost(entry_name)
