"""Roofline-term computation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s/link)
                    (cross-pod replica groups priced at DCN bandwidth)

``compiled.cost_analysis()`` counts while-loop bodies once, which
undercounts scan-over-layers models by ~n_layers; the loop-aware HLO
analyzer (launch/hlo_analysis.py) recovers trip counts from loop
conditions and scales every term.  Raw cost_analysis numbers are kept in
the record for reference.  All per-device quantities come from the
partitioned (per-device) module, so dividing by per-chip peaks directly is
the same as the total/(chips x peak) formulation.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.launch.hlo_analysis import HLOCost, analyze
from repro.launch.mesh import DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class Roofline:
    cost: HLOCost                      # loop-aware, per device
    n_devices: int
    model_flops_total: float = 0.0
    raw_flops: float = 0.0             # cost_analysis (loop-unaware)
    raw_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.cost.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.cost.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        ici = (self.cost.coll_bytes - self.cost.coll_cross_pod_bytes) / ICI_BW
        dcn = self.cost.coll_cross_pod_bytes / DCN_BW
        return ici + dcn

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste (can exceed 1
        only if the analyzer under-counts)."""
        total = self.cost.flops * self.n_devices
        return self.model_flops_total / total if total else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful_compute_time / bound_time: the fraction of the ideal
        (model-FLOPs-only) roofline this step achieves if it runs at its
        dominant-term speed."""
        useful_s = (self.model_flops_total / self.n_devices) / PEAK_FLOPS_BF16
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.cost.flops,
            "hbm_bytes_per_dev": self.cost.hbm_bytes,
            "collective_bytes": self.cost.coll_bytes,
            "collective_cross_pod_bytes": self.cost.coll_cross_pod_bytes,
            "collective_per_op": self.cost.coll_per_op,
            "collective_counts": self.cost.coll_counts,
            "hbm_per_op": {k: round(v) for k, v in self.cost.hbm_per_op.items()},
            "while_trip_counts": self.cost.while_trips,
            "raw_cost_analysis_flops": self.raw_flops,
            "raw_cost_analysis_bytes": self.raw_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward."""
    if kind == "train":
        return 6.0 * n_params_active * n_tokens
    return 2.0 * n_params_active * n_tokens


def build_roofline(compiled, n_devices: int, model_flops_total: float,
                   pod_size: int = 256) -> Roofline:
    raw_flops = raw_bytes = 0.0
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        raw_flops = float(ca.get("flops", 0.0))
        raw_bytes = float(ca.get("bytes accessed", 0.0))
    except Exception:
        pass
    cost = analyze(compiled.as_text(), n_devices, pod_size)
    return Roofline(cost, n_devices, model_flops_total, raw_flops, raw_bytes)
