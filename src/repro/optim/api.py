"""Pure-JAX optimizers (no optax dependency): SGD+momentum, AdamW,
Adafactor (factored second moments — the only optimizer whose state fits a
v5e pod for the 1T-param Kimi config).  Schedules: warmup+cosine.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    name: str


def _tmap(f, *trees, **kw):
    return jax.tree_util.tree_map(f, *trees, **kw)


# --------------------------------------------------------------------------
# Schedules
# --------------------------------------------------------------------------

def warmup_cosine(peak_lr: float, warmup: int = 100, total: int = 10000,
                  floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant(lr_val: float):
    return lambda step: jnp.asarray(lr_val, jnp.float32)


# --------------------------------------------------------------------------
# SGD + momentum
# --------------------------------------------------------------------------

def sgdm(lr=constant(1e-2), momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": _tmap(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        mu = _tmap(lambda m, g: momentum * m + g.astype(m.dtype),
                   state["mu"], grads)
        updates = _tmap(lambda m: (-lr(step) * m).astype(m.dtype), mu)
        return updates, {"mu": mu}

    return Optimizer(init, update, "sgdm")


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw(lr=constant(3e-4), b1=0.9, b2=0.95, eps=1e-8, wd=0.01,
          moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"m": _tmap(z, params), "v": _tmap(z, params)}

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            mhat = m_new / c1
            vhat = v_new / c2
            step_v = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
            return (-lr(step) * step_v).astype(p.dtype), \
                m_new.astype(moment_dtype), v_new.astype(moment_dtype)

        out = _tmap(upd, grads, state["m"], state["v"], params)
        updates = _tmap(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = _tmap(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = _tmap(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    return Optimizer(init, update, "adamw")


# --------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum)
# --------------------------------------------------------------------------

def adafactor(lr=constant(1e-3), decay=0.8, eps=1e-30,
              clip_threshold=1.0) -> Optimizer:
    """Factored for >=2D params (state = row+col means, O(n+m) not O(nm));
    full second moment for 1D."""

    def init(params):
        def f(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": _tmap(f, params)}

    def update(grads, state, params, step):
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                prec = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = gf * jax.lax.rsqrt(jnp.maximum(prec, eps))
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                ns = {"v": v}
            # update clipping (rms)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr(step) * u).astype(p.dtype), ns

        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        s_leaves = treedef.flatten_up_to(state["f"])
        p_leaves = treedef.flatten_up_to(params)
        results = [upd(g, s, p) for g, s, p in zip(g_leaves, s_leaves, p_leaves)]
        updates = treedef.unflatten([r[0] for r in results])
        ns = treedef.unflatten([r[1] for r in results])
        return updates, {"f": ns}

    return Optimizer(init, update, "adafactor")


# --------------------------------------------------------------------------

def make_optimizer(cfg: ArchConfig, lr: Optional[float] = None,
                   total_steps: int = 10000) -> Optimizer:
    sched = warmup_cosine(lr or 3e-4, warmup=min(100, total_steps // 10 + 1),
                          total=total_steps)
    if cfg.optimizer == "adafactor":
        return adafactor(sched)
    if cfg.optimizer == "sgdm":
        return sgdm(sched)
    return adamw(sched)


def apply_updates(params, updates):
    return _tmap(lambda p, u: (p + u.astype(p.dtype)), params, updates)
