"""MoE parallelism variants selected by ``cfg.moe.impl``.

Both share the capacity-dispatch math in ``models/moe.moe_apply_dense`` and
differ only in the sharding constraints pinned on the dispatch buffers, so
they are numerically interchangeable with the auto path (property-tested in
scripts/smoke_moe_a2a.py):

  * ``ep_a2a``   — expert parallelism: the (E, cap, D) dispatch buffer is
                   sharded over ``model`` on the experts dim, which lowers
                   the token dispatch/return into all-to-all style
                   collectives instead of replicated compute.
  * ``tp_local`` — intra-expert tensor parallelism: experts replicated, the
                   (E, cap, F) expert activations sharded over ``model`` on
                   the d_ff dim (Mixtral-style few-big-experts).

Constraints are applied only when a mesh context is active and the dim
divides the ``model`` axis; otherwise the math silently runs unconstrained
(single-device tests).
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.moe import moe_apply_dense


def _model_axis_size():
    """Size of the ``model`` axis in the active mesh context (0 if none)."""
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.shape:
        return 0
    return int(mesh.shape["model"])


def moe_apply_a2a(cfg: ArchConfig, p, x):
    m = cfg.moe
    ax = _model_axis_size()
    buf = P("model", None, None) if ax and m.n_experts % ax == 0 else None
    return moe_apply_dense(cfg, p, x, buf_constraint=buf)


def moe_apply_tp_local(cfg: ArchConfig, p, x):
    m = cfg.moe
    ax = _model_axis_size()
    act = P(None, None, "model") if ax and m.d_ff_expert % ax == 0 else None
    return moe_apply_dense(cfg, p, x, act_constraint=act)
