"""Distribution substrate: logical-axis sharding declarations, wire/collective
compression, and MoE expert-parallel dispatch variants."""
