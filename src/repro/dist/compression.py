"""Collective/wire compression: int8 block quantization with per-row
(last-dim) absmax scales, plus the error-feedback variant that keeps the
quantization residual bounded across rounds.  Used on the DCN/pod hop where
bandwidth is scarcest (core/aggregation.py "compressed" schedule) AND — via
``xp=numpy`` — by the host MQTT uplink codec (core/client.py
``uplink_codec="int8_ef"``), so both data paths share one quantizer.

``xp`` is the array namespace (jax.numpy by default, resolved lazily so the
host path never pays the jax import)."""
from __future__ import annotations


def _jnp():
    import jax.numpy as jnp
    return jnp


def quantize_int8(x, xp=None):
    """x -> (q int8, scale f32).  Scales are per last-dim row (keepdims), so
    ``q * scale`` broadcasts back to x's shape.  Max error <= absmax/127."""
    xp = xp if xp is not None else _jnp()
    xf = xp.asarray(x).astype(xp.float32)
    if xf.ndim == 0:
        xf = xf.reshape(1)
    amax = xp.max(xp.abs(xf), axis=-1, keepdims=True)
    scale = xp.where(amax > 0, amax, 1.0) / 127.0
    q = xp.clip(xp.round(xf / scale), -127, 127).astype(xp.int8)
    return q, scale


def dequantize_int8(q, scale, xp=None):
    xp = xp if xp is not None else _jnp()
    return xp.asarray(q).astype(xp.float32) * scale


def quantize_with_error_feedback(x, err, xp=None):
    """Quantize ``x + err`` and carry the new residual forward.  The
    residual never exceeds one quantization step (absmax/127), so repeated
    compressed rounds do not drift."""
    xp = xp if xp is not None else _jnp()
    t = xp.asarray(x).astype(xp.float32) + err
    q, scale = quantize_int8(t, xp=xp)
    new_err = t - dequantize_int8(q, scale, xp=xp)
    return q, scale, new_err
