"""Collective/wire compression: int8 block quantization with per-row
(last-dim) absmax scales, plus the error-feedback variant that keeps the
quantization residual bounded across rounds (used on the DCN/pod hop where
bandwidth is scarcest; see core/aggregation.py "compressed" schedule)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_int8(x):
    """x -> (q int8, scale f32).  Scales are per last-dim row (keepdims), so
    ``q * scale`` broadcasts back to x's shape.  Max error <= absmax/127."""
    xf = jnp.asarray(x).astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf.reshape(1)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax, 1.0) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_with_error_feedback(x, err):
    """Quantize ``x + err`` and carry the new residual forward.  The
    residual never exceeds one quantization step (absmax/127), so repeated
    compressed rounds do not drift."""
    t = jnp.asarray(x).astype(jnp.float32) + err
    q, scale = quantize_int8(t)
    new_err = t - dequantize_int8(q, scale)
    return q, scale, new_err
