"""Collective/wire compression: int8 block quantization with per-row
(last-dim) absmax scales, plus the error-feedback variant that keeps the
quantization residual bounded across rounds.  Used on the DCN/pod hop where
bandwidth is scarcest (core/aggregation.py "compressed" schedule) AND — via
``xp=numpy`` — by the host MQTT uplink codec (core/client.py
``uplink_codec="int8_ef"``), so both data paths share one quantizer.

``xp`` is the array namespace (jax.numpy by default, resolved lazily so the
host path never pays the jax import)."""
from __future__ import annotations


def _jnp():
    import jax.numpy as jnp
    return jnp


def quantize_int8(x, xp=None):
    """x -> (q int8, scale f32).  Scales are per last-dim row (keepdims), so
    ``q * scale`` broadcasts back to x's shape.  Max error <= absmax/127."""
    xp = xp if xp is not None else _jnp()
    xf = xp.asarray(x).astype(xp.float32)
    if xf.ndim == 0:
        xf = xf.reshape(1)
    amax = xp.max(xp.abs(xf), axis=-1, keepdims=True)
    scale = xp.where(amax > 0, amax, 1.0) / 127.0
    q = xp.clip(xp.round(xf / scale), -127, 127).astype(xp.int8)
    return q, scale


def dequantize_int8(q, scale, xp=None):
    xp = xp if xp is not None else _jnp()
    return xp.asarray(q).astype(xp.float32) * scale


def quantize_with_error_feedback(x, err, xp=None):
    """Quantize ``x + err`` and carry the new residual forward.  The
    residual never exceeds one quantization step (absmax/127), so repeated
    compressed rounds do not drift."""
    xp = xp if xp is not None else _jnp()
    t = xp.asarray(x).astype(xp.float32) + err
    q, scale = quantize_int8(t, xp=xp)
    new_err = t - dequantize_int8(q, scale, xp=xp)
    return q, scale, new_err


def _is_numpy(xp) -> bool:
    return getattr(xp, "__name__", "").split(".")[0] == "numpy"


def topk_count(size: int, density: float) -> int:
    """Number of coordinates a top-k codec keeps for a flat tensor of
    ``size`` elements at the given density (always at least one)."""
    if size <= 0:
        return 0
    k = int(-(-size * float(density) // 1))  # ceil without math import
    return max(1, min(size, k))


def topk_sparsify(x, density, xp=None):
    """Magnitude top-k over the *flattened* tensor.

    Returns ``(idx int32, vals f32)`` with indices sorted ascending so the
    encoding is deterministic and scatter order never matters.  numpy uses
    O(n) ``argpartition``; jax uses ``lax.top_k``.  Tie-breaking between the
    two backends can differ on exactly-equal magnitudes — callers that need
    bit-parity across backends feed tie-free inputs.
    """
    xp = xp if xp is not None else _jnp()
    flat = xp.asarray(x).astype(xp.float32).reshape(-1)
    n = int(flat.shape[0])
    k = topk_count(n, density)
    if k == 0:
        return (xp.zeros((0,), xp.int32), xp.zeros((0,), xp.float32))
    mag = xp.abs(flat)
    if k >= n:
        idx = xp.arange(n, dtype=xp.int32)
    elif _is_numpy(xp):
        idx = xp.sort(xp.argpartition(mag, n - k)[n - k:]).astype(xp.int32)
    else:
        import jax.lax
        _, top = jax.lax.top_k(mag, k)
        idx = xp.sort(top).astype(xp.int32)
    return idx, xp.take(flat, idx)


def quantize_topk_int8_ef(x, err, density, xp=None):
    """Top-k + int8 + error feedback: the uplink codec for large models.

    Sparsifies ``x + err`` to the top ``density`` fraction of coordinates by
    magnitude, int8-quantizes the survivors with ONE absmax scale for the
    whole tensor, and carries *everything not sent* — the un-selected mass
    plus the quantization residual of the selected values — in the returned
    error-feedback residual.  Mass conservation holds by construction:

        densify(idx, q, scale, shape) + new_err == x + err   (in f32)

    Returns ``(idx int32, q int8, scale f32[1], new_err)`` with ``new_err``
    shaped like ``x``.
    """
    xp = xp if xp is not None else _jnp()
    t = xp.asarray(x).astype(xp.float32) + err
    idx, vals = topk_sparsify(t, density, xp=xp)
    amax = xp.max(xp.abs(vals)) if vals.size else xp.float32(0.0)
    scale = (xp.where(amax > 0, amax, 1.0) / 127.0).reshape(1)
    scale = scale.astype(xp.float32)
    q = xp.clip(xp.round(vals / scale), -127, 127).astype(xp.int8)
    deq = q.astype(xp.float32) * scale
    flat = t.reshape(-1)
    if _is_numpy(xp):
        new_err = flat.copy()
        new_err[idx] -= deq
    else:
        new_err = flat.at[idx].add(-deq)
    return idx, q, scale, new_err.reshape(t.shape)


def densify_topk(idx, q, scale, shape, xp=None):
    """Scatter a top-k int8 payload back to a dense f32 tensor."""
    xp = xp if xp is not None else _jnp()
    n = 1
    for d in shape:
        n *= int(d)
    deq = xp.asarray(q).astype(xp.float32) * xp.asarray(scale).reshape(-1)[0]
    if _is_numpy(xp):
        out = xp.zeros(n, xp.float32)
        out[xp.asarray(idx)] = deq
    else:
        out = xp.zeros(n, xp.float32).at[xp.asarray(idx)].set(deq)
    return out.reshape(shape)
