"""Logical-axis parameter declarations and the sharding resolver.

Models declare parameters as ``decl(shape, logical_axes)`` pytrees instead of
concrete arrays; one resolver maps logical axes ("embed", "heads", "mlp",
"experts", ...) onto mesh axes per FL deployment mode:

  * ``replica`` — one FL client per ``data``-axis row; each client's params
    are replicated across ``data`` (the leading "clients" axis does the
    splitting) and tensor-parallel over ``model``.
  * ``shared``  — FSDP: the embed dim shards over ``data``, TP over
    ``model``; one FL client per ``pod``.

Resolution is divisibility-aware and claims each mesh axis at most once per
tensor, scanning dims left to right.  This is what makes MoE parallelism
automatic: Kimi-K2's 384 experts divide ``model``=16, so "experts" claims
the axis (expert parallelism) and "expert_mlp" replicates; Mixtral's 8
experts do not divide 16, so "experts" falls through and "expert_mlp"
claims ``model`` (intra-expert tensor parallelism).

Stacked axes ("layers" from ``stack``, "clients" from ``prepend_axis``) are
excluded from fan-in when initializing, so a stacked layer initializes
exactly like an unstacked one.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Leading axes added by stack()/prepend_axis(): not part of a weight's
# mathematical shape, excluded from fan-in.
_STACK_AXES = ("layers", "clients")


@dataclass(frozen=True)
class ParamDecl:
    """One declared parameter: shape + logical axis names + init recipe."""
    shape: tuple
    axes: tuple
    init: str = "normal"        # normal | embed | zeros | ones | neg_ones | const
    dtype: Any = jnp.bfloat16
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def size(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1


def decl(shape, axes, init: str = "normal", dtype=jnp.bfloat16,
         scale: float = 1.0) -> ParamDecl:
    return ParamDecl(tuple(shape), tuple(axes), init, dtype, float(scale))


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def _map_decls(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_decl)


def stack(tree, n: int):
    """Prepend a scan-over-layers dim to every decl in the tree."""
    return prepend_axis(tree, n, "layers")


def prepend_axis(tree, n: int, name: str):
    """Prepend a named leading dim (e.g. "clients") to every decl."""
    return _map_decls(
        lambda d: ParamDecl((n,) + d.shape, (name,) + d.axes,
                            d.init, d.dtype, d.scale), tree)


def param_count(tree) -> int:
    return sum(d.size for d in
               jax.tree_util.tree_leaves(tree, is_leaf=is_decl))


# --------------------------------------------------------------------------
# Initialization
# --------------------------------------------------------------------------

def _fan_in(d: ParamDecl) -> int:
    """Product of contracting dims: everything but the last, excluding
    stacked leading axes."""
    f = 1
    for dim, ax in zip(d.shape[:-1], d.axes[:-1]):
        if ax not in _STACK_AXES:
            f *= dim
    return max(f, 1)


def _init_leaf(d: ParamDecl, key):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "neg_ones":
        return jnp.full(d.shape, -1, d.dtype)
    if d.init == "const":
        return jnp.full(d.shape, d.scale, d.dtype)
    if d.init == "embed":
        std = 0.02 * d.scale
    elif d.init == "normal":
        std = d.scale / math.sqrt(_fan_in(d))
    else:
        raise ValueError(f"unknown init {d.init!r}")
    x = jax.random.normal(key, d.shape, jnp.float32) * std
    return x.astype(d.dtype)


def materialize(tree, key):
    """Concrete arrays for a decl tree (deterministic: per-leaf fold_in)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_decl)
    out = [_init_leaf(d, jax.random.fold_in(key, i))
           for i, d in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract(tree):
    """ShapeDtypeStruct stand-ins (dry-run lowering: no allocation)."""
    return _map_decls(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


# --------------------------------------------------------------------------
# Logical-axis resolution
# --------------------------------------------------------------------------

def rules_for(mode: str) -> dict:
    """Logical axis -> mesh axis for an FL deployment mode.  The caller may
    override entries (fl_step sets rules["clients"])."""
    common = {
        "vocab": "model", "heads": "model", "kv_heads": "model",
        "mlp": "model", "experts": "model", "expert_mlp": "model",
        "layers": None, "clients": None,
        "batch": "data", "cache_seq": "model",
    }
    if mode == "shared":      # FSDP over data + TP over model
        return {**common, "embed": "data", "embed_tp": "data"}
    if mode == "replica":     # per-client replicas; TP over model only
        return {**common, "embed": None, "embed_tp": None}
    raise ValueError(f"unknown FL mode {mode!r}")


def _spec_for(d: ParamDecl, rules: dict, mesh: Mesh) -> P:
    used: set = set()
    parts = []
    for dim, ax in zip(d.shape, d.axes):
        m = rules.get(ax) if ax is not None else None
        if (m is not None and m in mesh.shape and m not in used
                and dim >= mesh.shape[m] and dim % mesh.shape[m] == 0):
            parts.append(m)
            used.add(m)
        else:
            parts.append(None)
    return P(*parts)


def specs_for(tree, rules: dict, mesh: Mesh):
    """PartitionSpec tree for a decl tree under the given rules/mesh."""
    return _map_decls(lambda d: _spec_for(d, rules, mesh), tree)


def shardings_for(tree, rules: dict, mesh: Mesh):
    """NamedSharding tree (usable as jit out_shardings)."""
    return _map_decls(
        lambda d: NamedSharding(mesh, _spec_for(d, rules, mesh)), tree)
