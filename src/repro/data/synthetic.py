"""Deterministic synthetic data: token streams for LM training and an
MNIST-like classification set for the paper-replication benchmarks
(no network access in this environment — the distribution is procedural
but class-structured, so FedAvg convergence curves behave like Fig. 7).
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Markov-ish synthetic token stream with learnable structure:
    next-token depends on a sliding hash of the previous K tokens, so CE
    genuinely decreases during training."""

    def __init__(self, vocab: int, seed: int = 0, order: int = 3,
                 noise: float = 0.1):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.order = order
        self.noise = noise
        self._mix = self.rng.integers(1, vocab, size=order) | 1

    def batch(self, batch: int, seq: int, step: int = 0):
        rng = np.random.default_rng((hash((step, batch, seq)) & 0xffffffff))
        toks = np.zeros((batch, seq + 1), np.int64)
        toks[:, :self.order] = rng.integers(0, self.vocab,
                                            (batch, self.order))
        for t in range(self.order, seq + 1):
            det = (toks[:, t - self.order:t] * self._mix).sum(1) % self.vocab
            noise = rng.integers(0, self.vocab, batch)
            use_noise = rng.random(batch) < self.noise
            toks[:, t] = np.where(use_noise, noise, det)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def mnist_like(n: int, seed: int = 0, n_classes: int = 10, dim: int = 784,
               structure_seed: int = 42):
    """Class-structured 28x28-like data: per-class template + noise +
    smooth deformation.  The class structure (templates/basis) is fixed by
    ``structure_seed`` so independently drawn train/test sets share it;
    ``seed`` only draws samples."""
    srng = np.random.default_rng(structure_seed)
    templates = srng.normal(0, 1.0, (n_classes, dim)).astype(np.float32)
    basis = srng.normal(0, 1, (8, dim)).astype(np.float32)  # confusables
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n)
    coef = rng.normal(0, 0.6, (n, 8)).astype(np.float32)
    x = templates[y] + coef @ basis + rng.normal(0, 1.5, (n, dim)).astype(np.float32)
    return x.astype(np.float32), y.astype(np.int32)
