"""Federated data partitioning: IID and Dirichlet non-IID splits plus
per-client token-stream shards (each FL client sees its own distribution —
the heterogeneity that motivates SDFLMQ's role optimization)."""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import TokenStream, mnist_like


def dirichlet_split(y: np.ndarray, n_clients: int, alpha: float = 0.5,
                    seed: int = 0) -> list[np.ndarray]:
    """Label-skewed split (lower alpha = more skew).  Every client gets at
    least one sample."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    idx_per_client: list[list[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for ci, part in enumerate(np.split(idx, cuts)):
            idx_per_client[ci].extend(part.tolist())
    out = []
    for ci in range(n_clients):
        if not idx_per_client[ci]:
            idx_per_client[ci] = [int(rng.integers(0, len(y)))]
        out.append(np.asarray(sorted(idx_per_client[ci])))
    return out


def iid_split(n: int, n_clients: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(idx, n_clients)]


class FederatedMNIST:
    """The paper's evaluation setup: each client holds a fraction of the
    training set (Fig. 7 uses 1% per client across 5 clients)."""

    def __init__(self, n_clients: int, frac_per_client: float = 0.01,
                 total: int = 60000, alpha: float | None = None,
                 seed: int = 0):
        self.x, self.y = mnist_like(total, seed=seed)
        per = max(1, int(total * frac_per_client))
        if alpha is None:
            splits = iid_split(total, n_clients, seed)
            self.client_idx = [s[:per] for s in splits]
        else:
            splits = dirichlet_split(self.y, n_clients, alpha, seed)
            self.client_idx = [s[:per] for s in splits]
        xt, yt = mnist_like(10000, seed=seed + 1)
        self.test = (xt, yt)

    def client_data(self, i: int):
        idx = self.client_idx[i]
        return self.x[idx], self.y[idx]

    def n_samples(self, i: int) -> int:
        return len(self.client_idx[i])


class FederatedTokens:
    """Per-client token streams with distinct transition structure
    (non-IID) — used by the LM examples and the e2e driver."""

    def __init__(self, vocab: int, n_clients: int, seed: int = 0,
                 heterogeneous: bool = True):
        self.streams = [
            TokenStream(vocab, seed=seed + (i if heterogeneous else 0),
                        noise=0.05 + 0.1 * (i % 3))
            for i in range(n_clients)
        ]

    def client_batch(self, i: int, batch: int, seq: int, step: int):
        return self.streams[i].batch(batch, seq, step)

    def global_batch(self, clients: int, per_client: int, seq: int, step: int):
        import numpy as np
        bs = [self.client_batch(i, per_client, seq, step)
              for i in range(clients)]
        return {k: np.stack([b[k] for b in bs]) for k in bs[0]}
