"""Metrics registry: counters, gauges, histograms with labels.

Zero-dependency, pull-friendly. Instruments are created through a
:class:`MetricsRegistry` and rendered either as the Prometheus text
exposition format (``render_prom()``) or as a JSON-safe ``snapshot()``.
Registered *collectors* run just before every render/snapshot so that
cheap source-of-truth counters (broker ``$SYS`` dicts, ``wire_stats()``,
accumulator arenas) can be mirrored into the registry lazily instead of
taxing the hot path.

Quick tour (doctested):

>>> from repro.obs.registry import MetricsRegistry
>>> reg = MetricsRegistry()
>>> c = reg.counter("sdflmq_demo_total", "Demo counter", labels=("kind",))
>>> c.labels(kind="publish").inc()
>>> c.labels(kind="publish").inc(2)
>>> c.labels(kind="publish").value
3.0
>>> g = reg.gauge("sdflmq_queue_depth", "Messages waiting")
>>> g.set(7)
>>> h = reg.histogram("sdflmq_lat_seconds", "Latency", buckets=(0.1, 1.0))
>>> h.observe(0.05); h.observe(3.0)
>>> print(reg.render_prom())
# HELP sdflmq_demo_total Demo counter
# TYPE sdflmq_demo_total counter
sdflmq_demo_total{kind="publish"} 3
# HELP sdflmq_queue_depth Messages waiting
# TYPE sdflmq_queue_depth gauge
sdflmq_queue_depth 7
# HELP sdflmq_lat_seconds Latency
# TYPE sdflmq_lat_seconds histogram
sdflmq_lat_seconds_bucket{le="0.1"} 1
sdflmq_lat_seconds_bucket{le="1.0"} 1
sdflmq_lat_seconds_bucket{le="+Inf"} 2
sdflmq_lat_seconds_sum 3.05
sdflmq_lat_seconds_count 2
<BLANKLINE>
>>> reg.series_count()
7
>>> snap = reg.snapshot()
>>> snap["sdflmq_demo_total"]["samples"]['kind="publish"']
3.0

Re-requesting a metric with the same name returns the same family; a
kind or label mismatch raises:

>>> reg.counter("sdflmq_demo_total", labels=("kind",)) is c
True
>>> reg.gauge("sdflmq_demo_total")
Traceback (most recent call last):
    ...
ValueError: metric 'sdflmq_demo_total' already registered as counter
"""
from __future__ import annotations

import bisect
import threading
from typing import Callable, Dict, Iterable, List, Optional, Tuple

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram"]

DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integral floats render without '.0'."""
    f = float(v)
    if f != f or f in (float("inf"), float("-inf")):
        return {float("inf"): "+Inf", float("-inf"): "-Inf"}.get(f, "NaN")
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


class Counter:
    """Monotonically increasing sample."""

    __slots__ = ("_value",)

    def __init__(self) -> None:
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a gauge")
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Sample that can go up, down, or be set outright."""

    __slots__ = ("_value",)

    def __init__(self) -> None:
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self._value += n

    def dec(self, n: float = 1.0) -> None:
        self._value -= n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Cumulative histogram over fixed upper bounds (plus +Inf)."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Tuple[float, ...]) -> None:
        self.buckets = buckets
        self.counts = [0] * len(buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        i = bisect.bisect_left(self.buckets, v)
        if i < len(self.buckets):
            self.counts[i] += 1

    @property
    def value(self) -> Dict[str, object]:
        return {
            "count": self.count,
            "sum": self.sum,
            "buckets": {_fmt(ub): c for ub, c in zip(self.buckets, self.counts)},
        }


_CHILD_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """A named metric with a fixed label set; children keyed by label values."""

    __slots__ = ("kind", "name", "help", "label_names", "buckets", "_children", "_lock")

    def __init__(self, kind: str, name: str, help: str,
                 label_names: Tuple[str, ...],
                 buckets: Optional[Tuple[float, ...]] = None) -> None:
        self.kind = kind
        self.name = name
        self.help = help
        self.label_names = label_names
        self.buckets = buckets
        self._children: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()

    def labels(self, **kv: object):
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"metric '{self.name}' takes labels {self.label_names}, got {tuple(kv)}"
            )
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    if self.kind == "histogram":
                        child = Histogram(self.buckets or DEFAULT_BUCKETS)
                    else:
                        child = _CHILD_TYPES[self.kind]()
                    self._children[key] = child
        return child

    # Label-less convenience: a family with no labels behaves as its own child.
    def _default(self):
        if self.label_names:
            raise ValueError(
                f"metric '{self.name}' has labels {self.label_names}; call .labels() first"
            )
        return self.labels()

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._default().dec(n)

    def set(self, v: float) -> None:
        self._default().set(v)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    @property
    def value(self):
        return self._default().value

    # -- rendering -------------------------------------------------------
    def _label_str(self, key: Tuple[str, ...]) -> str:
        if not key:
            return ""
        pairs = ",".join(
            f'{n}="{_escape_label(v)}"' for n, v in zip(self.label_names, key)
        )
        return "{" + pairs + "}"

    def render(self, out: List[str]) -> None:
        out.append(f"# HELP {self.name} {_escape_help(self.help)}")
        out.append(f"# TYPE {self.name} {self.kind}")
        for key in sorted(self._children):
            child = self._children[key]
            lbl = self._label_str(key)
            if self.kind == "histogram":
                cum = 0
                for ub, c in zip(child.buckets, child.counts):
                    cum += c
                    le = self._bucket_label(key, ub)
                    out.append(f"{self.name}_bucket{le} {cum}")
                le = self._bucket_label(key, float("inf"))
                out.append(f"{self.name}_bucket{le} {child.count}")
                out.append(f"{self.name}_sum{lbl} {_fmt(child.sum)}")
                out.append(f"{self.name}_count{lbl} {child.count}")
            else:
                out.append(f"{self.name}{lbl} {_fmt(child.value)}")

    def _bucket_label(self, key: Tuple[str, ...], ub: float) -> str:
        le = "+Inf" if ub == float("inf") else _fmt(float(ub)) if float(ub) != int(ub) else repr(float(ub))
        pairs = [f'{n}="{_escape_label(v)}"' for n, v in zip(self.label_names, key)]
        pairs.append(f'le="{le}"')
        return "{" + ",".join(pairs) + "}"

    def samples(self) -> Dict[str, object]:
        return {
            self._label_str(k).strip("{}"): self._children[k].value
            for k in sorted(self._children)
        }

    def n_series(self) -> int:
        if self.kind == "histogram":
            per = 0
            for child in self._children.values():
                per += len(child.buckets) + 3  # +Inf bucket, _sum, _count
            return per
        return len(self._children)


class MetricsRegistry:
    """Create-or-get instrument factory plus exposition surface.

    See the module docstring for a doctested tour of the public API:
    :meth:`counter`, :meth:`gauge`, :meth:`histogram`,
    :meth:`register_collector`, :meth:`render_prom`, :meth:`snapshot`,
    and :meth:`series_count`.
    """

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}
        self._collectors: List[Callable[[], None]] = []
        self._lock = threading.Lock()

    # -- instrument factories -------------------------------------------
    def _family(self, kind: str, name: str, help: str,
                labels: Iterable[str],
                buckets: Optional[Tuple[float, ...]] = None) -> _Family:
        label_names = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind:
                    raise ValueError(
                        f"metric '{name}' already registered as {fam.kind}"
                    )
                if fam.label_names != label_names:
                    raise ValueError(
                        f"metric '{name}' already registered with labels {fam.label_names}"
                    )
                return fam
            fam = _Family(kind, name, help, label_names, buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labels: Iterable[str] = ()) -> _Family:
        return self._family("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", labels: Iterable[str] = ()) -> _Family:
        return self._family("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "", labels: Iterable[str] = (),
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> _Family:
        return self._family("histogram", name, help, labels,
                            tuple(sorted(float(b) for b in buckets)))

    # -- collectors ------------------------------------------------------
    def register_collector(self, fn: Callable[[], None]) -> None:
        """Register a zero-arg callable run before every render/snapshot."""
        self._collectors.append(fn)

    def collect(self) -> None:
        for fn in self._collectors:
            fn()

    # -- exposition ------------------------------------------------------
    def render_prom(self) -> str:
        """Render the Prometheus text exposition format (version 0.0.4)."""
        self.collect()
        out: List[str] = []
        for name in self._families:  # insertion (registration) order
            self._families[name].render(out)
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """JSON-safe dump: {name: {kind, help, samples: {labelstr: value}}}."""
        self.collect()
        return {
            name: {"kind": fam.kind, "help": fam.help, "samples": fam.samples()}
            for name, fam in self._families.items()
        }

    def series_count(self) -> int:
        """Number of exposed sample lines (one per labeled time series)."""
        self.collect()
        return sum(f.n_series() for f in self._families.values())
