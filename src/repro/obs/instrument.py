"""Telemetry facade: binds a registry + tracer to a running federation.

``Federation(metrics=...)`` constructs one :class:`Telemetry` and threads
it through the stack.  Two mechanisms feed it:

* **Pull collectors** (zero hot-path cost): a registered collector walks
  the federation's existing stats surfaces — broker ``sys_stats()`` /
  TopicTrie cache counters, every ``MQTTFC.wire_stats()`` endpoint,
  per-session accumulator arenas and ``peak_acc_bytes``, async admission /
  gossip counters, and coordinator round bookkeeping — and mirrors them
  into labeled gauges at scrape/snapshot time.
* **Push hooks** (one ``if obs is not None`` branch each): control-plane
  event points (round start/complete, deadline cut, contribute, flush,
  mint, gossip, partition, heal, publish/deliver) call
  :meth:`Telemetry.trace`, and latency observations land in histograms
  (:meth:`observe_staleness`, :meth:`observe_round`).

Metric naming: ``sdflmq_<subsystem>_<stat>``; pulled source counters are
exposed as gauges (the source object owns monotonicity).
"""
from __future__ import annotations

from typing import Optional

from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import Tracer

__all__ = ["Telemetry", "SYS_CORE"]

#: Canonical ``sys_stats()`` core schema every transport backend exposes
#: (SimBroker, LatencyTransport, MiniBroker, PahoTransport).  The metrics
#: layer — and the conformance suite — rely on exactly these names.
SYS_CORE = ("messages_received", "messages_sent", "bytes_received", "bytes_sent")

STALENESS_BUCKETS = (0.0, 1.0, 2.0, 3.0, 5.0, 8.0, 13.0, 21.0)
ROUND_BUCKETS = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Telemetry:
    """One registry + one tracer + the glue that feeds them."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 clock: Optional[object] = None,
                 trace_capacity: int = 4096) -> None:
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = Tracer(clock=clock, maxlen=trace_capacity)
        r = self.registry
        self._events = r.counter(
            "sdflmq_trace_events_total", "Trace events emitted", labels=("kind",))
        self._staleness = r.histogram(
            "sdflmq_async_staleness_versions",
            "Version staleness of async contributions at arrival",
            buckets=STALENESS_BUCKETS)
        self._round_virtual = r.histogram(
            "sdflmq_round_virtual_seconds", "Per-round virtual latency",
            labels=("session",), buckets=ROUND_BUCKETS)
        self._round_wall = r.histogram(
            "sdflmq_round_wall_seconds", "Per-round wall latency",
            labels=("session",), buckets=ROUND_BUCKETS)

    # -- push hooks ------------------------------------------------------
    def trace(self, kind: str, **fields: object) -> None:
        self.tracer.emit(kind, **fields)
        self._events.labels(kind=kind).inc()

    def observe_staleness(self, staleness: float) -> None:
        self._staleness.observe(staleness)

    def observe_round(self, session: str, virtual_s: Optional[float],
                      wall_s: Optional[float]) -> None:
        if virtual_s is not None:
            self._round_virtual.labels(session=session).observe(virtual_s)
        if wall_s is not None:
            self._round_wall.labels(session=session).observe(wall_s)

    # -- pull collectors -------------------------------------------------
    def bind_federation(self, fed: object) -> None:
        """Register a collector mirroring the federation's stats surfaces."""
        reg = self.registry

        def set_numeric(name: str, help: str, value: object, **labels) -> None:
            if isinstance(value, bool):
                value = int(value)
            if not isinstance(value, (int, float)):
                return
            g = reg.gauge(name, help, labels=tuple(sorted(labels)))
            (g.labels(**labels) if labels else g).set(value)

        def collect() -> None:
            # Broker / transport ($SYS + latency-sim + trie cache stats).
            stats = fed.transport.sys_stats()
            for k, v in stats.items():
                if k == "per_topic_class" and isinstance(v, dict):
                    for tc, n in v.items():
                        set_numeric("sdflmq_broker_topic_class_messages",
                                    "Messages routed per topic class", n,
                                    topic_class=tc)
                elif k == "links" and isinstance(v, dict):
                    for cid, link in v.items():
                        for lk, lv in link.items():
                            set_numeric(f"sdflmq_link_{lk}",
                                        "Per-client simulated link stat", lv,
                                        client=cid)
                else:
                    set_numeric(f"sdflmq_broker_{k}", "Broker $SYS stat", v)

            # Wire endpoints (coordinator, parameter server, every client).
            endpoints = []
            coord = getattr(fed, "coordinator", None)
            if coord is not None and getattr(coord, "fc", None) is not None:
                endpoints.append((coord.fc.client_id, coord.fc))
            ps = getattr(fed, "param_server", None)
            if ps is not None and getattr(ps, "fc", None) is not None:
                endpoints.append(("param_server", ps.fc))
            for cid, cl in getattr(fed, "clients", {}).items():
                endpoints.append((cid, cl.fc))
            for cid, fc in endpoints:
                for k, v in fc.wire_stats().items():
                    set_numeric(f"sdflmq_wire_{k}", "MQTTFC wire stat", v,
                                client=cid)

            # Codec stats (uplink bytes, error-feedback residual, top-k
            # density).  Exported for every client even with codecs off —
            # the series sit at their defaults so dashboards and the CI
            # scrape gate always see them.
            for cid, cl in getattr(fed, "clients", {}).items():
                cs = getattr(cl, "codec_stats", None)
                if cs is None:
                    continue
                codec = getattr(cl, "uplink_codec", None) or "none"
                set_numeric("sdflmq_wire_uplink_bytes",
                            "Model-update uplink payload bytes shipped",
                            cs.get("uplink_bytes", 0), client=cid, codec=codec)
                set_numeric("sdflmq_codec_ef_residual_norm",
                            "Error-feedback residual L2 norm after last uplink",
                            cs.get("ef_residual_norm", 0.0), client=cid)
                set_numeric("sdflmq_topk_density",
                            "Fraction of update entries shipped last uplink",
                            cs.get("topk_density", 1.0), client=cid)

            # Per-duty accumulator arenas + async counters (client contexts).
            for cid, cl in getattr(fed, "clients", {}).items():
                for sid, ctx in cl.models.sessions.items():
                    acc_bytes = sum(a.alloc_bytes for a in ctx.accs.values())
                    set_numeric("sdflmq_acc_alloc_bytes",
                                "Live accumulator arena bytes", acc_bytes,
                                client=cid, session=sid)
                    set_numeric("sdflmq_acc_peak_bytes",
                                "Peak accumulator arena bytes",
                                ctx.peak_acc_bytes, client=cid, session=sid)
                    set_numeric("sdflmq_sync_stale_dropped",
                                "Stale sync contributions dropped",
                                ctx.stale_dropped, client=cid, session=sid)
                    for k in ("async_admitted", "async_rejected",
                              "gossip_sent", "gossip_adopts",
                              "gossip_merges", "site_updates"):
                        set_numeric(f"sdflmq_{k}", "Async-FL counter",
                                    getattr(ctx, k, 0), client=cid, session=sid)
                    set_numeric("sdflmq_defense_rejected_updates",
                                "Updates this aggregator rejected (defense)",
                                getattr(ctx, "defense_rejected", 0),
                                client=cid, session=sid)

            # Coordinator control-plane bookkeeping.
            if coord is not None:
                for k in ("rearrangement_messages", "arrangement_messages",
                          "deadline_cuts"):
                    set_numeric(f"sdflmq_coordinator_{k}",
                                "Coordinator control-plane counter",
                                getattr(coord, k, 0))
                set_numeric("sdflmq_roles_rotations",
                            "Aggregator-set rotations (moving-target defense)",
                            getattr(coord, "roles_rotations", 0))
                for sid, s in coord.sessions.items():
                    set_numeric("sdflmq_coordinator_round",
                                "Current round index", s.round_idx, session=sid)
                    # trust scores are exported for every contributor even
                    # with the defense off (they sit at the default 1.0),
                    # so dashboards and the CI scrape gate always see the
                    # series
                    for cid, st in s.contributors.items():
                        set_numeric("sdflmq_defense_reputation",
                                    "Coordinator trust score per client",
                                    getattr(st, "reputation", 1.0),
                                    client=cid, session=sid)

            # Clock.
            clock = getattr(fed, "clock", None)
            if clock is not None:
                set_numeric("sdflmq_clock_virtual_seconds",
                            "Simulated virtual time", clock.now)
                set_numeric("sdflmq_clock_pending_events",
                            "Events waiting in the simulated clock",
                            clock.pending())

            # Tracer ring health.
            set_numeric("sdflmq_trace_ring_dropped",
                        "Trace events evicted from the bounded ring",
                        self.tracer.dropped)

        reg.register_collector(collect)
