"""Exposition endpoints: stdlib-HTTP ``/metrics`` and JSON timelines.

``serve_metrics(registry)`` starts a daemon-thread HTTP server (port 0 =
ephemeral) serving:

  * ``GET /metrics``       — Prometheus text exposition (version 0.0.4)
  * ``GET /timeline.json`` — the tracer's full event dump (404 if no tracer)
  * ``GET /``              — a one-line index

No third-party dependencies; safe to leave running for the lifetime of a
simulation or a real deployment process.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import Tracer

__all__ = ["MetricsServer", "serve_metrics", "render_prom",
           "timeline_json", "write_timeline_json"]

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def render_prom(registry: MetricsRegistry) -> str:
    """Free-function alias for ``registry.render_prom()``."""
    return registry.render_prom()


def timeline_json(tracer: Tracer, indent: Optional[int] = 1) -> str:
    """Free-function alias for ``tracer.to_json()``."""
    return tracer.to_json(indent=indent)


def write_timeline_json(tracer: Tracer, path: str, indent: Optional[int] = 1) -> str:
    """Dump the tracer's events to ``path``; returns the path."""
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(tracer.to_json(indent=indent))
    return path


class MetricsServer:
    """Tiny threaded HTTP server exposing a registry (and optional tracer)."""

    def __init__(self, registry: MetricsRegistry, tracer: Optional[Tracer] = None,
                 host: str = "127.0.0.1", port: int = 0) -> None:
        self.registry = registry
        self.tracer = tracer
        srv_self = self

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # silence per-request stderr spam
                pass

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                if path == "/metrics":
                    body = srv_self.registry.render_prom().encode("utf-8")
                    self._send(200, body, PROM_CONTENT_TYPE)
                elif path == "/timeline.json":
                    if srv_self.tracer is None:
                        self._send(404, b"no tracer attached\n", "text/plain")
                    else:
                        body = srv_self.tracer.to_json(indent=1).encode("utf-8")
                        self._send(200, body, "application/json")
                elif path == "/":
                    self._send(200, b"sdflmq telemetry: /metrics /timeline.json\n",
                               "text/plain")
                else:
                    self._send(404, b"not found\n", "text/plain")

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="sdflmq-metrics", daemon=True
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def serve_metrics(registry: MetricsRegistry, tracer: Optional[Tracer] = None,
                  host: str = "127.0.0.1", port: int = 0) -> MetricsServer:
    """Start a daemon ``/metrics`` endpoint; returns the running server.

    ``port=0`` picks an ephemeral port — read it back from ``server.port``.
    """
    return MetricsServer(registry, tracer=tracer, host=host, port=port)
