"""Round-lifecycle tracer: structured events in a bounded ring buffer.

Each event is a flat dict ``{"t": <timestamp>, "kind": <str>, ...fields}``.
Timestamps come from a pluggable clock: pass the federation's
:class:`~repro.api.transport.SimClock` to get *virtual* seconds (so traces
from simulated runs line up with ``virtual_time_s`` in reports), or no
clock to fall back to wall time (``time.time()``).

The ring is bounded (``maxlen``): old events are dropped, never the run.
``dropped`` counts what fell off so exports can flag truncation.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["Tracer"]

# Noisy data-plane kinds excluded from compact timelines by default.
NOISY_KINDS = ("publish", "deliver")


class Tracer:
    __slots__ = ("_ring", "_clock", "maxlen", "emitted", "dropped")

    def __init__(self, clock: Optional[object] = None, maxlen: int = 4096) -> None:
        self._ring: deque = deque(maxlen=maxlen)
        self._clock = clock
        self.maxlen = maxlen
        self.emitted = 0
        self.dropped = 0

    def now(self) -> float:
        if self._clock is not None:
            return float(self._clock.now)
        return time.time()

    def emit(self, kind: str, **fields: object) -> None:
        if len(self._ring) == self.maxlen:
            self.dropped += 1
        ev: Dict[str, object] = {"t": round(self.now(), 6), "kind": kind}
        ev.update(fields)
        self._ring.append(ev)
        self.emitted += 1

    # -- reads -----------------------------------------------------------
    def events(self, kind: Optional[str] = None) -> List[Dict[str, object]]:
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e["kind"] == kind]

    def kinds(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self._ring:
            out[e["kind"]] = out.get(e["kind"], 0) + 1
        return out

    def clear(self) -> None:
        self._ring.clear()

    def timeline(self, include: Optional[Iterable[str]] = None,
                 exclude: Iterable[str] = NOISY_KINDS) -> List[Tuple[float, str]]:
        """Compact ``(t, label)`` view, sorted by timestamp.

        ``label`` is the event kind followed by its fields as ``k=v`` pairs,
        e.g. ``('partition', ...)`` renders as ``"partition groups=2"``.
        ``include`` (when given) whitelists kinds; otherwise ``exclude``
        drops the noisy data-plane kinds (publish/deliver) so control-plane
        structure — rounds, partitions, heals, mints — stays readable.
        """
        inc = set(include) if include is not None else None
        exc = set(exclude)
        out: List[Tuple[float, str]] = []
        for e in self._ring:
            k = e["kind"]
            if inc is not None:
                if k not in inc:
                    continue
            elif k in exc:
                continue
            extras = " ".join(
                f"{n}={e[n]}" for n in e if n not in ("t", "kind")
            )
            out.append((e["t"], f"{k} {extras}" if extras else str(k)))
        out.sort(key=lambda p: p[0])
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        """Full event dump plus ring metadata, as a JSON document."""
        return json.dumps(
            {
                "emitted": self.emitted,
                "dropped": self.dropped,
                "clock": "virtual" if self._clock is not None else "wall",
                "events": list(self._ring),
            },
            indent=indent,
            default=str,
        )
