"""repro.obs — zero-dependency telemetry for SDFLMQ federations.

The paper pitches SDFLMQ as a *real-time service at the edge*; this package
turns the repo's scattered per-object counters ($SYS stats, ``wire_stats``,
accumulator arenas, async admission counts, coordinator deadlines) into one
operational surface:

  * :class:`MetricsRegistry` — counters, gauges, and histograms with
    labels, rendered in the Prometheus text exposition format
    (``render_prom()``) or as a JSON-safe ``snapshot()``,
  * :class:`Tracer` — structured round-lifecycle events (publish/deliver/
    train/contribute/flush/mint/partition/heal/...) with virtual-or-wall
    timestamps in a bounded ring buffer, exportable as JSON timelines,
  * :func:`serve_metrics` — a one-liner stdlib-HTTP ``/metrics`` endpoint,
  * :class:`Telemetry` — the facade ``Federation(metrics=...)`` wires
    through the whole stack (pull collectors over every component's
    existing stats surface + push hooks at control-plane event points).

Everything is opt-in: with ``Federation(metrics=None)`` (the default) no
object from this package is ever constructed and the hot paths take the
exact pre-telemetry branches, so the zero-overhead default stays
bit-identical.
"""
from __future__ import annotations

from repro.obs.exporters import (render_prom, serve_metrics, timeline_json,
                                 write_timeline_json)
from repro.obs.instrument import SYS_CORE, Telemetry
from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import Tracer

__all__ = [
    "MetricsRegistry",
    "Tracer",
    "Telemetry",
    "SYS_CORE",
    "render_prom",
    "serve_metrics",
    "timeline_json",
    "write_timeline_json",
]
