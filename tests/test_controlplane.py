"""Integration tests of the SDFLMQ control plane: sessions, roles, the
host-side hierarchical FedAvg vs a flat oracle, failures, stragglers."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.broker import SimBroker
from repro.core.client import SDFLMQClient
from repro.core.coordinator import Coordinator, CoordinatorConfig
from repro.core.parameter_server import ParameterServer
from repro.core.stats import ClientStats, StatsSimulator


def build_fleet(n, levels=3, ratio=0.3, policy="memory_aware", rounds=2):
    broker = SimBroker()
    coord = Coordinator(broker, CoordinatorConfig(
        role_policy=policy, aggregator_ratio=ratio, levels=levels))
    ps = ParameterServer(broker)
    sim = StatsSimulator([f"c{i}" for i in range(n)])
    clients = {}
    for i in range(n):
        cid = f"c{i}"
        clients[cid] = SDFLMQClient(
            cid, broker, preferred_role="aggregator" if i % 2 else "trainer",
            stats=sim.sample(cid, 0))
    clients["c0"].create_fl_session("s", "m", rounds, n, n)
    for i in range(1, n):
        clients[f"c{i}"].join_fl_session("s", "m")
    return broker, coord, ps, clients, sim


def run_round(clients, params_of, weight_of):
    for cid, cl in sorted(clients.items()):
        cl.set_model("s", params_of(cid), n_samples=weight_of(cid))
    for cid, cl in sorted(clients.items()):
        cl.send_local("s")


@pytest.mark.parametrize("n,levels,ratio", [
    (5, 3, 0.3), (8, 2, 0.5), (16, 3, 0.3), (3, 3, 0.4), (24, 4, 0.25),
])
def test_tree_fedavg_equals_flat_oracle(n, levels, ratio):
    _, coord, ps, clients, _ = build_fleet(n, levels, ratio)
    assert coord.sessions["s"].state.value == "running"
    rng = np.random.default_rng(n)
    params = {c: {"w": rng.normal(size=(5, 3)).astype(np.float32)}
              for c in clients}
    weights = {c: float(rng.integers(1, 20)) for c in clients}
    run_round(clients, lambda c: params[c], lambda c: weights[c])
    g = ps.get_global("s")
    assert g is not None
    tw = sum(weights.values())
    want = sum(params[c]["w"] * weights[c] for c in clients) / tw
    np.testing.assert_allclose(g["params"]["w"], want, rtol=1e-5, atol=1e-6)
    # every client received the identical global model
    for cl in clients.values():
        np.testing.assert_allclose(cl.get_model("s")["w"], want, rtol=1e-5,
                                   atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 100))
def test_property_fedavg_exact(n, seed):
    _, coord, ps, clients, _ = build_fleet(n)
    rng = np.random.default_rng(seed)
    params = {c: {"w": rng.normal(size=(4,)).astype(np.float32)}
              for c in clients}
    weights = {c: float(rng.uniform(0.5, 9.0)) for c in clients}
    run_round(clients, lambda c: params[c], lambda c: weights[c])
    want = sum(params[c]["w"] * weights[c] for c in clients) \
        / sum(weights.values())
    np.testing.assert_allclose(ps.get_global("s")["params"]["w"], want,
                               rtol=1e-5, atol=1e-6)


def test_session_rejects_when_full_and_wrong_model():
    broker, coord, *_ = build_fleet(4)
    extra = SDFLMQClient("late", broker)
    extra.join_fl_session("s", "m")          # full
    assert "late" not in coord.sessions["s"].contributors
    other = SDFLMQClient("wrong", broker)
    other.join_fl_session("s", "not_m")
    assert "wrong" not in coord.sessions["s"].contributors


def test_duplicate_create_is_dumped():
    broker, coord, _, clients, _ = build_fleet(4)
    dup = SDFLMQClient("dup", broker)
    dup.create_fl_session("s", "other_model", 5, 2, 2)
    assert coord.sessions["s"].model_name == "m"


def test_rearrangement_sends_only_deltas():
    _, coord, ps, clients, sim = build_fleet(8, policy="round_robin",
                                             rounds=3)
    rng = np.random.default_rng(0)
    p = {"w": rng.normal(size=(3,)).astype(np.float32)}
    run_round(clients, lambda c: p, lambda c: 1)
    before = coord.rearrangement_messages
    for r in range(2):
        for cid, cl in sorted(clients.items()):
            cl.signal_ready("s", stats=sim.sample(cid, r + 1))
        run_round(clients, lambda c: p, lambda c: 1)
    sent = coord.rearrangement_messages - before
    assert 0 < sent < 8 * 2, "rearrangement must message only changed clients"


def test_failure_triggers_rearrangement_and_round_completes():
    _, coord, ps, clients, _ = build_fleet(6, rounds=2)
    rng = np.random.default_rng(1)
    params = {c: {"w": np.full(3, float(i), np.float32)}
              for i, c in enumerate(sorted(clients))}
    dead = "c5"
    clients.pop(dead).fail()
    assert dead not in coord.sessions["s"].contributors
    run_round(clients, lambda c: params[c], lambda c: 1)
    g = ps.get_global("s")
    want = np.mean([params[c]["w"] for c in sorted(clients)], axis=0)
    np.testing.assert_allclose(g["params"]["w"], want, rtol=1e-5)


def test_straggler_flush_renormalizes():
    _, coord, ps, clients, _ = build_fleet(5)
    rng = np.random.default_rng(2)
    params = {c: {"w": rng.normal(size=(3,)).astype(np.float32)}
              for c in clients}
    straggler = sorted(clients)[-1]
    for cid, cl in sorted(clients.items()):
        cl.set_model("s", params[cid], n_samples=2)
    for cid, cl in sorted(clients.items()):
        if cid != straggler:
            cl.send_local("s")
    coord.force_round_end("s")   # deadline hit -> aggregators flush partials
    g = ps.get_global("s")
    live = [c for c in sorted(clients) if c != straggler]
    want = np.mean([params[c]["w"] for c in live], axis=0)
    np.testing.assert_allclose(g["params"]["w"], want, rtol=1e-5, atol=1e-6)


def test_parameter_server_versions_and_retained_sync():
    broker, coord, ps, clients, sim = build_fleet(4, rounds=3)
    rng = np.random.default_rng(3)
    p = {"w": rng.normal(size=(3,)).astype(np.float32)}
    run_round(clients, lambda c: p, lambda c: 1)
    assert ps.versions("s")
    # a brand-new observer immediately receives the retained global model
    late = SDFLMQClient("late_observer", broker)
    late.models.ensure("s", "m")
    late._subscribe_session("s")
    np.testing.assert_allclose(late.get_model("s")["w"],
                               ps.get_global("s")["params"]["w"])


def test_elastic_join_mid_session():
    broker, coord, ps, clients, _ = build_fleet(4, rounds=3)
    assert coord.sessions["s"].state.value == "running"
    late = SDFLMQClient("late", broker)
    # capacity full -> rejected
    late.join_fl_session("s", "m")
    assert "late" not in coord.sessions["s"].contributors
    # grow capacity, join mid-run -> role assigned, next round includes it
    coord.sessions["s"].capacity_max = 8
    late.join_fl_session("s", "m")
    assert "late" in coord.sessions["s"].contributors
    assert late.arbiter.assignment is not None
    assert late.arbiter.assignment.train_cluster is not None
    rng = np.random.default_rng(0)
    p = {"w": rng.normal(size=(3,)).astype(np.float32)}
    all_clients = dict(clients, late=late)
    run_round(all_clients, lambda c: p, lambda c: 1)
    np.testing.assert_allclose(ps.get_global("s")["params"]["w"], p["w"],
                               rtol=1e-5)
