"""Packaging hygiene: no stale bytecode can shadow source.

A ``.pyc`` committed (or left behind by a deleted module) can be imported
*ahead of* — or instead of — the ``.py`` source, silently resurrecting
dead code.  Two invariants keep that impossible:

  * every imported ``repro`` module resolves from a ``.py`` file, never a
    bytecode cache;
  * the tree contains no legacy-location ``.pyc`` (importable directly)
    and no orphaned ``__pycache__`` entry whose source was deleted.
"""
import pathlib
import sys

import repro

# repro is a namespace package (no top-level __init__): locate via __path__
SRC = pathlib.Path(list(repro.__path__)[0]).resolve()


def test_imported_repro_modules_resolve_from_source():
    import repro.api.federation  # noqa: F401  (pull in the facade chain)
    import repro.core.broker     # noqa: F401
    for name, mod in list(sys.modules.items()):
        if not name.startswith("repro"):
            continue
        origin = getattr(getattr(mod, "__spec__", None), "origin", None)
        if origin in (None, "namespace"):
            continue
        assert origin.endswith(".py"), \
            f"{name} imported from bytecode: {origin}"


def test_no_stray_or_orphaned_bytecode_in_src():
    legacy = [p for p in SRC.rglob("*.py[co]")
              if p.parent.name != "__pycache__"]
    assert not legacy, f"legacy-location bytecode is importable: {legacy}"
    orphans = []
    for pyc in SRC.rglob("__pycache__/*.pyc"):
        stem = pyc.name.split(".")[0]
        if not (pyc.parent.parent / f"{stem}.py").exists():
            orphans.append(pyc)
    assert not orphans, \
        f"orphaned __pycache__ entries (their source is gone): {orphans}"
