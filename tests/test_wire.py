"""Zero-copy TensorBundle data plane: wire round-trips (property-tested),
legacy interop, bit-identity of tree aggregation vs the legacy msgpack
path, streaming-accumulator semantics, reassembly eviction, and the
int8+error-feedback uplink codec."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import Federation
from repro.core.broker import SimBroker
from repro.core.client import _Accumulator, weighted_add
from repro.core.mqttfc import MQTTFC, default_codec
from repro.core.wire import (TensorBundle, TensorStack, decode_body,
                             encode_body, is_wire_payload)

DTYPES = ["<f4", "<f8", "<f2", "<i1", "<i4", ">f4", ">i2", "|u1", "|b1"]


# ---------------------------------------------------------------------------
# TensorBundle round-trips
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(dt=st.sampled_from(DTYPES), ndim=st.integers(0, 3),
       seed=st.integers(0, 10**6), empty=st.booleans())
def test_bundle_roundtrip_property(dt, ndim, seed, empty):
    rng = np.random.default_rng(seed)
    shape = tuple(int(x) for x in rng.integers(1, 5, size=ndim))
    if empty and ndim:
        shape = (0,) + shape[1:]
    a = (rng.normal(size=shape) * 100).astype(np.dtype(dt))
    b = rng.integers(-100, 100, size=(3, 2)).astype(np.int8)
    tb = TensorBundle.from_params({"a": a, "b": b})
    body = encode_body({"params": tb})
    back = decode_body(bytes(body))["params"]
    va, vb = back.view("a"), back.view("b")
    assert va.dtype == a.dtype and va.shape == a.shape
    np.testing.assert_array_equal(va, a)
    np.testing.assert_array_equal(vb, b)


def test_bundle_views_are_zero_copy():
    p = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    tb = TensorBundle.from_params(p)
    v = tb.views()["w"]
    assert v.base is not None                     # a view, not an owner
    # mutating the buffer is visible through the view: shared memory
    memoryview(tb.buffer)[0:4] = np.float32(99.0).tobytes()
    assert v[0, 0] == 99.0


def test_bundle_mixed_dtypes_and_scalars():
    p = {"q": np.ones((4, 3), np.int8), "s": np.float64(2.5) * np.ones(()),
         "h": np.ones((2,), np.float16), "e": np.empty((0, 7), np.float32)}
    back = decode_body(encode_body({"x": TensorBundle.from_params(p)}))["x"]
    for k in p:
        np.testing.assert_array_equal(back.view(k), p[k])
        assert back.view(k).dtype == p[k].dtype


def test_bare_arrays_and_nested_payloads():
    obj = {"a": [np.arange(5), {"deep": np.ones((2, 2), ">f4")}],
           "k": {"w": np.float32(1.5)}, "s": "me"}
    back = decode_body(encode_body(obj))
    np.testing.assert_array_equal(back["a"][0], np.arange(5))
    np.testing.assert_array_equal(back["a"][1]["deep"], np.ones((2, 2)))
    assert back["a"][1]["deep"].dtype == np.dtype(">f4")
    assert back["k"]["w"] == 1.5
    assert is_wire_payload(obj) and not is_wire_payload({"a": [1, "x"]})


def test_tensorstack_strided_views_match_np_stack():
    rng = np.random.default_rng(0)
    rows = [{"w": rng.normal(size=(3, 4)).astype(np.float32),
             "b": rng.integers(-5, 5, size=7).astype(np.int8)}
            for _ in range(5)]
    bundles = [TensorBundle.from_params(r) for r in rows]
    buf = bytearray(b"".join(bytes(b.buffer) for b in bundles))
    ts = TensorStack(bundles[0].schema, 5, buf)
    sv = ts.stacked_views()
    for k in ("w", "b"):
        np.testing.assert_array_equal(
            sv[k], np.stack([r[k] for r in rows]))
    # round-trip through the body codec
    back = decode_body(encode_body({"stack": ts}))["stack"]
    np.testing.assert_array_equal(back.stacked_views()["w"], sv["w"])


# ---------------------------------------------------------------------------
# MQTTFC framing: multi-part, interop, eviction
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(kb=st.integers(1, 64), batch=st.sampled_from([512, 1024, 4096]),
       seed=st.integers(0, 999))
def test_multipart_roundtrip_property(kb, batch, seed):
    b = SimBroker()
    rx = MQTTFC(b, "rx", max_batch_bytes=batch)
    tx = MQTTFC(b, "tx", max_batch_bytes=batch)
    got = []
    rx.bind("t/m", lambda arr: got.append(arr))
    arr = np.random.default_rng(seed).normal(size=(kb * 256,)).astype(np.float32)
    tx.call("t/m", arr)
    assert len(got) == 1
    np.testing.assert_array_equal(got[0], arr)
    assert got[0].dtype == arr.dtype


@pytest.mark.parametrize("tx_fmt,rx_fmt", [("tb", "legacy"), ("legacy", "tb"),
                                           ("tb", "tb")])
def test_wire_format_interop(tx_fmt, rx_fmt):
    """Receivers decode both generations: format rides the frame flags."""
    b = SimBroker()
    rx = MQTTFC(b, "rx", wire_format=rx_fmt, max_batch_bytes=2048)
    tx = MQTTFC(b, "tx", wire_format=tx_fmt, max_batch_bytes=2048)
    got = []
    rx.bind("t/m", lambda d: got.append(d))
    payload = {"params": np.arange(4000, dtype=np.float32), "weight": 2.0}
    tx.call("t/m", payload)
    assert len(got) == 1
    np.testing.assert_array_equal(got[0]["params"], payload["params"])
    assert got[0]["weight"] == 2.0


def test_default_codec_prefers_zstd_when_importable():
    try:
        import zstandard  # noqa: F401
        assert default_codec() == "zstd"
    except ModuleNotFoundError:
        assert default_codec() == "zlib"
    b = SimBroker()
    fc = MQTTFC(b, "x")
    assert fc.codec == default_codec()


def test_quantized_payload_skips_compression():
    b = SimBroker()
    rx = MQTTFC(b, "rx")
    tx = MQTTFC(b, "tx", compress_threshold=0)
    got = []
    rx.bind("t/q", lambda d: got.append(d))
    q = np.zeros(64 * 1024, np.int8)          # highly compressible
    tx.call("t/q", {"params": q}, quantized=True)
    # compression was skipped: wire bytes ~= raw bytes despite zero payload
    assert tx.bytes_sent >= tx.raw_bytes_sent
    np.testing.assert_array_equal(got[0]["params"], q)


def test_reassembly_evicts_stale_calls_on_newer_frame():
    """Per-sender FIFO: a part of call N+1 proves call N's missing parts
    were dropped (QoS-0 loss) — the stale assembly is evicted."""
    b = SimBroker()
    rx = MQTTFC(b, "rx", max_batch_bytes=512)
    tx = MQTTFC(b, "tx", max_batch_bytes=512)
    got = []
    rx.bind("t/m", lambda arr: got.append(arr))

    # drop one mid-call part of the first big call at the transport level
    orig_publish = b.publish
    drop = {"armed": True}

    def lossy_publish(topic, payload, qos=0, retain=False, sender="",
                      _origin=""):
        if drop["armed"] and tx.parts_sent == 3:   # lose exactly one part
            drop["armed"] = False
            return -1
        return orig_publish(topic, payload, qos=qos, retain=retain,
                            sender=sender, _origin=_origin)

    b.publish = lossy_publish
    big = np.random.default_rng(0).normal(size=1024).astype(np.float64)
    tx.call("t/m", big)                       # incomplete: one part lost
    assert got == [] and rx.reassembly_pending() == 1
    tx.call("t/m", big + 1)                   # next call completes
    assert len(got) == 1
    np.testing.assert_array_equal(got[0], big + 1)
    assert rx.reassembly_pending() == 0
    assert rx.reassembly_evictions == 1
    assert rx.wire_stats()["reassembly_evictions"] == 1


def test_reassembly_lru_cap():
    b = SimBroker()
    rx = MQTTFC(b, "rx", max_batch_bytes=256, max_assemblies=4)
    rx.bind("t/m", lambda *a: None)
    # many senders each leave one incomplete assembly behind
    for i in range(8):
        tx = MQTTFC(b, f"tx{i}", max_batch_bytes=256)
        orig = b.publish
        sent = {"n": 0}

        def first_part_only(topic, payload, qos=0, retain=False, sender="",
                            _origin="", _orig=orig, _sent=sent):
            _sent["n"] += 1
            if _sent["n"] > 1:
                return -1
            return _orig(topic, payload, qos=qos, retain=retain,
                         sender=sender, _origin=_origin)

        b.publish = first_part_only
        tx.call("t/m",
                np.random.default_rng(i).normal(size=512))  # incompressible
        b.publish = orig
    assert rx.reassembly_pending() <= 4
    assert rx.reassembly_evictions >= 4


# ---------------------------------------------------------------------------
# Streaming accumulator: bit-identity with the legacy float64 semantics
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 6), seed=st.integers(0, 10**6),
       as_bundle=st.booleans())
def test_accumulator_bit_identical_to_weighted_add(n, seed, as_bundle):
    rng = np.random.default_rng(seed)
    contribs = [({"w": rng.normal(size=(5, 3)).astype(np.float32),
                  "b": rng.normal(size=7).astype(np.float32)},
                 float(rng.integers(1, 9))) for _ in range(n)]
    ref = None
    acc = _Accumulator()
    for i, (p, w) in enumerate(contribs):
        ref = weighted_add(ref, p, w)
        acc.add_sum(TensorBundle.from_params(p) if as_bundle else p, w)
        acc.received += 1
    views = acc.acc_views()
    for k in ref:
        assert np.array_equal(ref[k].view(np.int64), views[k].view(np.int64)), \
            f"{k}: fused accumulate drifted from legacy float64 semantics"


def _run_tree(strategy, wire_format, levels=3, n=9, rounds=2):
    fed = Federation(levels=levels, aggregator_ratio=0.4,
                     wire_format=wire_format)
    clients = [fed.client(f"c{i}") for i in range(n)]
    session = fed.create_session("s", "m", rounds=rounds,
                                 participants=clients, strategy=strategy)
    rngs = {f"c{i}": np.random.default_rng(100 + i) for i in range(n)}

    def train(cid, g, rnd):
        r = rngs[cid]
        return ({"w": r.normal(size=(8, 4)).astype(np.float32),
                 "b": r.normal(size=16).astype(np.float32)},
                int(r.integers(1, 5)))

    for _ in range(rounds):
        session.run_round(train)
    return session.global_params(), session


@pytest.mark.parametrize("strategy", ["fedavg", "trimmed_mean",
                                      "coordinate_median", "fedprox"])
@pytest.mark.parametrize("levels", [1, 3])
def test_global_bit_identical_tb_vs_legacy(strategy, levels):
    """The TensorBundle path produces bit-identical globals to the legacy
    msgpack path, for sum and stack strategies, across tree shapes."""
    g_tb, _ = _run_tree(strategy, "tb", levels=levels)
    g_leg, _ = _run_tree(strategy, "legacy", levels=levels)
    assert g_tb.keys() == g_leg.keys()
    for k in g_tb:
        assert g_tb[k].dtype == g_leg[k].dtype
        assert np.array_equal(np.ascontiguousarray(g_tb[k]).view(np.int32),
                              np.ascontiguousarray(g_leg[k]).view(np.int32)), \
            f"{strategy}/levels={levels}: {k} differs between wire formats"


def test_stack_peak_acc_bytes_has_no_duplicate_stacked_copy():
    """Stack strategies hold ONE copy of the gathered rows; finalize uses
    strided views.  The pre-TensorBundle implementation held the decoded
    entries PLUS a per-key np.stack duplicate (~2x)."""
    _g, session = _run_tree("trimmed_mean", "tb", levels=1, n=8, rounds=1)
    root_peaks = [cl.models.get("s").peak_acc_bytes
                  for cl in session.participants.values()]
    peak = max(root_peaks)
    row_bytes = (8 * 4 + 16) * 4               # one f32 contribution
    n_rows = 8
    assert peak >= n_rows * row_bytes          # the rows are really held
    assert peak <= int(1.25 * n_rows * row_bytes), \
        "stack accumulator duplicated the gathered rows"


def test_sum_accumulator_is_preallocated_and_in_place():
    acc = _Accumulator()
    p = {"w": np.ones((64, 64), np.float32)}
    acc.add_sum(TensorBundle.from_params(p), 2.0)
    acc.received += 1
    buf_id = acc.flat.__array_interface__["data"][0]
    for _ in range(5):
        acc.add_sum(TensorBundle.from_params(p), 1.0)
        acc.received += 1
    assert acc.flat.__array_interface__["data"][0] == buf_id
    np.testing.assert_allclose(acc.acc_views()["w"], 7.0)
    # w=1.0 merges never needed the scratch buffer: one flat f64 acc only
    assert acc.scratch is None
    assert acc.alloc_bytes == acc.flat.nbytes
    acc.add_sum(TensorBundle.from_params(p), 3.0)   # weighted: scratch now
    acc.received += 1
    assert acc.alloc_bytes == acc.flat.nbytes + acc.scratch.nbytes


# ---------------------------------------------------------------------------
# int8 + error-feedback uplink codec
# ---------------------------------------------------------------------------

def test_int8_uplink_roundtrip_accuracy():
    fed = Federation(levels=1, uplink_codec="int8_ef")
    clients = [fed.client(f"c{i}") for i in range(4)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)
    rng = np.random.default_rng(0)
    models = {f"c{i}": {"w": rng.normal(size=(16, 8)).astype(np.float32)}
              for i in range(4)}
    g = session.run_round(lambda cid, _g, _r: (models[cid], 1))
    ref = np.mean([models[c]["w"] for c in models], axis=0)
    # int8 per-row absmax: error bounded by one quantization step
    step = max(np.abs(models[c]["w"]).max() for c in models) / 127.0
    assert np.max(np.abs(g["w"] - ref)) <= step * 1.5


def test_int8_uplink_error_feedback_reduces_drift():
    """With error feedback the client's residual is carried forward, so a
    constant model's quantization error does not accumulate over rounds."""
    from repro.dist.compression import (dequantize_int8,
                                        quantize_with_error_feedback)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(32, 16)).astype(np.float32)
    err = np.zeros_like(x)
    deq_sum = np.zeros_like(x)
    rounds = 50
    for _ in range(rounds):
        q, scale, err = quantize_with_error_feedback(x, err, xp=np)
        deq_sum += dequantize_int8(q, scale, xp=np)
    # the mean of the dequantized stream converges to x (EF property)
    drift = np.max(np.abs(deq_sum / rounds - x))
    naive_step = np.abs(x).max() / 127.0
    assert drift < naive_step / 2


def test_int8_uplink_matches_compiled_quantizer():
    """Host (numpy) quantizer is the same function the compiled
    ``compressed`` schedule uses — same q/scale on the same input."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.dist.compression import quantize_int8
    x = np.random.default_rng(2).normal(size=(8, 16)).astype(np.float32)
    q_np, s_np = quantize_int8(x, xp=np)
    q_j, s_j = quantize_int8(jnp.asarray(x))
    np.testing.assert_array_equal(q_np, np.asarray(q_j))
    np.testing.assert_allclose(s_np, np.asarray(s_j), rtol=1e-6)


def test_int8_uplink_on_legacy_wire_format():
    """uplink_codec and wire_format are independent knobs: quantized
    uplinks must also work over the legacy msgpack wire."""
    fed = Federation(levels=1, wire_format="legacy", uplink_codec="int8_ef")
    clients = [fed.client(f"c{i}") for i in range(3)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)
    rng = np.random.default_rng(3)
    m = {"w": rng.normal(size=(8, 8)).astype(np.float32)}
    g = session.run_round(lambda cid, _g, _r: (m, 1))
    step = np.abs(m["w"]).max() / 127.0
    assert np.max(np.abs(g["w"] - m["w"])) <= step * 1.5


def test_decoded_views_are_read_only():
    """Uncompressed single-part frames are shared by every subscriber and
    the retained store: decoded views must refuse in-place mutation."""
    b = SimBroker()
    rx1 = MQTTFC(b, "rx1", compress_threshold=1 << 30)
    rx2 = MQTTFC(b, "rx2", compress_threshold=1 << 30)
    tx = MQTTFC(b, "tx", compress_threshold=1 << 30)
    got = {}
    rx1.bind("t/m", lambda d: got.setdefault("r1", d))
    rx2.bind("t/m", lambda d: got.setdefault("r2", d))
    tx.call("t/m", {"params": TensorBundle.from_params(
        {"w": np.arange(64, dtype=np.float32)})})
    v1 = got["r1"]["params"].view("w")
    with pytest.raises(ValueError):
        v1[0] = 99.0
    np.testing.assert_array_equal(got["r2"]["params"].view("w"),
                                  np.arange(64, dtype=np.float32))
