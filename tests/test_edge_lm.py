"""Edge-LM property wall (PR 10): the bandwidth-frugal large-model path.

Locks down every lossy piece end-to-end:

  * qagg kernel: Pallas vs ``ref.py`` oracle (bit-exact) and vs a hand
    dequantize+weighted-sum oracle.
  * host fused int8 accumulator ≡ qagg kernel on the same contributions
    (the host MQTT path and the compiled ``compressed`` schedule consume
    identical codec output).
  * host path ≡ flat strategy reference on DEQUANTIZED contributions for
    every registered strategy with the int8 uplink codec enabled.
  * top-k delta-coded uplink: round-0 absolute semantics, density/byte
    accounting (≥10x in-test), damped-EF stability on a constant-target
    federation (the ringing regression the decay constant exists for).
  * int8 downlink: clients and the ParameterServer mirror see f32 params
    within one quantization step of the true global.
  * ParamFilter partial updates: only adapter leaves hit the wire, the
    frozen base never moves, downlink merge restores the full set.
  * combined mode (filter + topk uplink + int8 downlink) stays sane.
  * codec observability series exported for the CI scrape gate.
  * ``examples/federated_lm.py`` smoke (subprocess, real jax mesh).
  * the committed ``BENCH_pr10.json`` gates (≥10x bytes, time-to-target
    ≤1.25x, kernel parity) — a regenerated artifact must still pass.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import Federation, list_strategies
from repro.core.broker import SimBroker
from repro.core.client import _Accumulator
from repro.core.parameter_server import ParameterServer
from repro.dist import compression as C

from tests.test_api import flat_reference, make_session

pytestmark = pytest.mark.edge_lm

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# qagg kernel: Pallas ≡ ref ≡ hand oracle
# ---------------------------------------------------------------------------

def _qagg_case(seed, shape):
    rng = np.random.default_rng(seed)
    q = rng.integers(-127, 128, shape).astype(np.int8)
    s = rng.uniform(0.5, 2.0, shape[:-1] + (1,)).astype(np.float32) / 127
    w = rng.uniform(0.5, 2.0, shape[0]).astype(np.float32)
    return q, s, w


@pytest.mark.parametrize("shape", [(4, 64, 256), (3, 33, 7), (8, 1, 1024),
                                   (1, 5, 5), (2, 128, 128)])
def test_qagg_pallas_matches_ref_bit_exact(shape):
    import jax.numpy as jnp
    from repro.kernels.fedavg.ops import qagg
    q, s, w = _qagg_case(sum(shape), shape)
    got = np.asarray(qagg(jnp.asarray(q), jnp.asarray(s), jnp.asarray(w),
                          force="pallas"))
    ref = np.asarray(qagg(jnp.asarray(q), jnp.asarray(s), jnp.asarray(w),
                          force="ref"))
    np.testing.assert_array_equal(got, ref)


def test_qagg_matches_hand_dequantize_oracle():
    import jax.numpy as jnp
    from repro.kernels.fedavg.ops import qagg
    q, s, w = _qagg_case(3, (5, 16, 64))
    got = np.asarray(qagg(jnp.asarray(q), jnp.asarray(s), jnp.asarray(w),
                          force="pallas"))
    want = np.zeros((16, 64), np.float32)
    for k in range(5):
        want = want + (q[k].astype(np.float32) * s[k]) * w[k]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_host_fused_accumulator_matches_qagg_kernel():
    """The host MQTT path's streaming f64 consume and the compiled path's
    qagg kernel must agree on identical codec output."""
    import jax.numpy as jnp
    from repro.kernels.fedavg.ops import qagg
    rng = np.random.default_rng(11)
    n_clients, shape = 4, (24, 96)
    qs, ss = [], []
    acc = _Accumulator()
    for _ in range(n_clients):
        x = rng.normal(size=shape).astype(np.float32) * 3
        q, s = C.quantize_int8(x, xp=np)
        qs.append(q)
        ss.append(np.asarray(s, np.float32))
        acc.add_sum_quantized({"w": q}, {"w": ss[-1]}, 1.0)
        acc.received += 1
    host = np.asarray(acc.acc_views()["w"], np.float32)
    kern = np.asarray(qagg(jnp.asarray(np.stack(qs)),
                           jnp.asarray(np.stack(ss)),
                           jnp.ones((n_clients,), jnp.float32),
                           force="pallas"))
    np.testing.assert_allclose(host, kern, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# host path ≡ flat reference, every strategy, int8 uplink codec on
# ---------------------------------------------------------------------------

def _dequant_oracle(p):
    q, s = C.quantize_int8(np.asarray(p, np.float32), xp=np)
    return C.dequantize_int8(q, np.asarray(s, np.float32), xp=np)


@pytest.mark.parametrize("strategy", sorted(list_strategies()))
def test_every_strategy_tree_equals_flat_with_int8_uplink(strategy):
    """With ``uplink_codec='int8_ef'`` the cluster tree must equal the flat
    strategy reference applied to the DEQUANTIZED contributions (round 0:
    EF residual is zero, so the wire carries exactly quantize_int8)."""
    n = 6
    fed, session = make_session(n, strategy, levels=2, ratio=0.4, rounds=1,
                                uplink_codec="int8_ef")
    rng = np.random.default_rng(17)
    params = {f"c{i}": {"w": rng.normal(size=(6, 5)).astype(np.float32),
                        "b": rng.normal(size=(3,)).astype(np.float32)}
              for i in range(n)}
    weights = {f"c{i}": float(rng.integers(1, 5)) for i in range(n)}
    session.run_round(lambda cid, g, r: (params[cid], int(weights[cid])))
    got = session.global_params()
    deq = {c: _dequant_oracle_params(p) for c, p in params.items()}
    want = flat_reference(strategy, deq, weights)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-6)


def _dequant_oracle_params(p):
    return {k: _dequant_oracle(v) for k, v in p.items()}


def test_topk_round0_tree_equals_flat_on_densified_contributions():
    """Round 0 top-k (no global yet → absolute values): the tree must equal
    fedavg over the densified sparse payloads."""
    n, density = 5, 0.25
    fed, session = make_session(n, "fedavg", levels=2, ratio=0.4, rounds=1,
                                uplink_codec="topk_int8_ef",
                                topk_density=density)
    rng = np.random.default_rng(23)
    params = {f"c{i}": {"w": rng.normal(size=(8, 16)).astype(np.float32)}
              for i in range(n)}
    weights = {f"c{i}": float(rng.integers(1, 4)) for i in range(n)}
    session.run_round(lambda cid, g, r: (params[cid], int(weights[cid])))
    got = session.global_params()

    def densified(x):
        idx, q, s, _ = C.quantize_topk_int8_ef(
            x, np.zeros_like(x), density, xp=np)
        return C.densify_topk(idx, q, s, x.shape, xp=np)

    dens = {c: {k: densified(v) for k, v in p.items()}
            for c, p in params.items()}
    want = flat_reference("fedavg", dens, weights)
    np.testing.assert_allclose(got["w"], want["w"], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# top-k delta coding: stability + byte accounting
# ---------------------------------------------------------------------------

def test_topk_delta_constant_target_converges_monotonically():
    """Damped-EF regression probe: every client pushes the same fixed
    params each round; the delta-coded sparse uplink must drive the global
    monotonically toward it.  (Undamped EF carry double-counts un-sent
    mass against the self-correcting delta and RINGS on this probe — this
    test pins the _DELTA_EF_DECAY fix.)"""
    target = {"w": np.random.default_rng(7).standard_normal((64, 32))
              .astype(np.float32)}
    fed = Federation(levels=1, uplink_codec="topk_int8_ef",
                     topk_density=0.05)
    clients = [fed.client(f"c{i}") for i in range(3)]
    session = fed.create_session("s", "m", rounds=8, participants=clients)
    devs = []
    session.on_global_update = lambda p, v: devs.append(
        float(np.max(np.abs(p["w"] - target["w"]))))
    session.run(lambda cid, g, r: (target, 1),
                initial_params={"w": np.zeros((64, 32), np.float32)})
    assert devs[-1] < 0.75 * devs[0], devs
    assert all(b <= a * 1.05 for a, b in zip(devs, devs[1:])), devs


def test_topk_uplink_bytes_reduced_10x_and_density_accounted():
    def one_round_bytes(codec):
        fed = Federation(levels=1, uplink_codec=codec, topk_density=0.01)
        clients = [fed.client(f"c{i}") for i in range(2)]
        session = fed.create_session("s", "m", rounds=1,
                                     participants=clients)
        m = {"w": np.random.default_rng(1)
             .standard_normal((512, 256)).astype(np.float32)}
        session.run_round(lambda cid, g, r: (m, 1))
        return fed, sum(fed.clients[c].codec_stats["uplink_bytes"]
                        for c in fed.clients)

    _, plain = one_round_bytes(None)
    fed, topk = one_round_bytes("topk_int8_ef")
    assert plain / topk >= 10.0, (plain, topk)
    for c in fed.clients.values():
        assert c.codec_stats["topk_density"] == pytest.approx(0.01, rel=0.1)


def test_topk_warmup_rounds_ship_dense_then_sparse():
    fed = Federation(levels=1, uplink_codec="topk_int8_ef",
                     topk_density=0.02, topk_warmup_rounds=1)
    clients = [fed.client(f"c{i}") for i in range(2)]
    session = fed.create_session("s", "m", rounds=2, participants=clients)
    m = {"w": np.zeros((64, 64), np.float32)}
    per_round = []
    last = [0]

    def train(cid, g, r):
        return m, 1

    session.run_round(train)
    per_round.append(sum(f.codec_stats["uplink_bytes"]
                         for f in fed.clients.values()) - last[0])
    last[0] += per_round[-1]
    session.run_round(train)
    per_round.append(sum(f.codec_stats["uplink_bytes"]
                         for f in fed.clients.values()) - last[0])
    # warm-up round ships dense int8 (~1 byte/param + scales); round 1
    # ships ~2% of coordinates (int32 idx + int8 val)
    assert per_round[0] > 5 * per_round[1], per_round


# ---------------------------------------------------------------------------
# int8 downlink: clients + ParameterServer mirror
# ---------------------------------------------------------------------------

def test_int8_downlink_clients_and_mirror_within_one_quant_step():
    broker = SimBroker()
    fed = Federation(transport=broker, levels=1, downlink_codec="int8")
    ps = ParameterServer(broker, "mirror2")     # a second, late reader
    clients = [fed.client(f"c{i}") for i in range(3)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)
    rng = np.random.default_rng(5)
    params = {c.client_id: {"w": rng.normal(size=(16, 32))
                            .astype(np.float32)} for c in clients}
    session.run_round(lambda cid, g, r: (params[cid], 1))
    # fedavg, equal weights → the true f32 global is the plain mean
    true = np.mean([params[c.client_id]["w"] for c in clients], axis=0)
    tol = float(np.max(np.abs(true))) / 127 + 1e-6
    mirror = ps.get_global("s")
    assert mirror is not None and mirror["version"] >= 1
    mw = mirror["params"]["w"]
    assert mw.dtype == np.float32            # mirror dequantizes for readers
    np.testing.assert_allclose(mw, true, atol=tol)
    for c in clients:
        got = c.models.get("s").params["w"]
        np.testing.assert_allclose(got, true, atol=tol)
    # all readers decode the SAME retained int8 frames — bit-identical
    for c in clients:
        np.testing.assert_array_equal(c.models.get("s").params["w"], mw)


# ---------------------------------------------------------------------------
# ParamFilter partial updates
# ---------------------------------------------------------------------------

def _adapter_params(seed):
    rng = np.random.default_rng(seed)
    return {"base/w": rng.normal(size=(12, 12)).astype(np.float32),
            "head/lora_A": rng.normal(size=(12, 2)).astype(np.float32),
            "head/lora_B": rng.normal(size=(2, 12)).astype(np.float32)}


def test_update_filter_ships_only_adapters_and_merges_over_base():
    broker = SimBroker()
    fed = Federation(transport=broker, levels=1,
                     update_filter="*/lora_A,*/lora_B")
    ps = ParameterServer(broker, "mirror2")
    clients = [fed.client(f"c{i}") for i in range(3)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)
    init = _adapter_params(0)
    locals_ = {c.client_id: _adapter_params(i + 1)
               for i, c in enumerate(clients)}
    session.run(lambda cid, g, r: (locals_[cid], 1), rounds=1,
                initial_params=init)
    # the aggregated broadcast carries ONLY the filtered leaves
    mirror = ps.get_global("s")["params"]
    assert set(mirror) == {"head/lora_A", "head/lora_B"}
    want_a = np.mean([locals_[c]["head/lora_A"] for c in locals_], axis=0)
    for c in clients:
        merged = c.models.get("s").params
        assert set(merged) == set(init)
        # each client keeps its OWN base bit-exactly: had the base ridden
        # the wire, the broadcast would have forced all three identical
        np.testing.assert_array_equal(merged["base/w"],
                                      locals_[c.client_id]["base/w"])
        np.testing.assert_allclose(merged["head/lora_A"], want_a,
                                   rtol=1e-5, atol=1e-6)


def test_update_filter_uplink_bytes_scale_with_adapter_fraction():
    def bytes_with(filt):
        fed = Federation(levels=1, update_filter=filt)
        clients = [fed.client(f"c{i}") for i in range(2)]
        session = fed.create_session("s", "m", rounds=1,
                                     participants=clients)
        session.run(lambda cid, g, r: (_adapter_params(9), 1), rounds=1,
                    initial_params=_adapter_params(0))
        return sum(f.codec_stats["uplink_bytes"]
                   for f in fed.clients.values())

    full, part = bytes_with(None), bytes_with("*/lora_A,*/lora_B")
    # adapters are 48 of 192 f32 params — the partial uplink must shrink
    # proportionally (allow framing slack)
    assert part < 0.35 * full, (part, full)


def test_combined_filter_topk_uplink_int8_downlink_round_trips():
    fed = Federation(levels=1, update_filter="*/lora_A,*/lora_B",
                     uplink_codec="topk_int8_ef", topk_density=0.5,
                     downlink_codec="int8")
    clients = [fed.client(f"c{i}") for i in range(3)]
    session = fed.create_session("s", "m", rounds=3, participants=clients)
    init = _adapter_params(0)
    target = _adapter_params(42)
    session.run(lambda cid, g, r: (target, 1), initial_params=init)
    for c in clients:
        merged = c.models.get("s").params
        # the base stays whatever local training produced — no codec ever
        # touched it (wire carries only the two adapter leaves)
        np.testing.assert_array_equal(merged["base/w"], target["base/w"])
        # lossy uplink+downlink still tracks the shared adapter target
        err = np.max(np.abs(merged["head/lora_A"] - target["head/lora_A"]))
        assert err < 0.5 * np.max(np.abs(init["head/lora_A"]
                                         - target["head/lora_A"])), err
        assert np.isfinite(merged["head/lora_B"]).all()


# ---------------------------------------------------------------------------
# observability: codec series exported for the CI scrape gate
# ---------------------------------------------------------------------------

def test_codec_metrics_exported_with_labels():
    fed = Federation(levels=1, metrics=True, uplink_codec="topk_int8_ef",
                     topk_density=0.05)
    clients = [fed.client(f"c{i}") for i in range(2)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)
    m = {"w": np.random.default_rng(2)
         .standard_normal((32, 32)).astype(np.float32)}
    session.run_round(lambda cid, g, r: (m, 1))
    text = fed.metrics.render_prom()
    assert 'sdflmq_wire_uplink_bytes{' in text
    assert 'codec="topk_int8_ef"' in text
    assert "sdflmq_codec_ef_residual_norm" in text
    assert "sdflmq_topk_density" in text


# ---------------------------------------------------------------------------
# federated_lm example smoke (subprocess: fresh jax device mesh)
# ---------------------------------------------------------------------------

def _run_example(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples/federated_lm.py"),
         "--clients", "2", "--rounds", "2", "--seq", "32",
         "--batch-per-client", "2", *extra],
        capture_output=True, text=True, timeout=560, env=env, cwd=ROOT)
    assert p.returncode == 0, \
        f"STDOUT:\n{p.stdout[-3000:]}\nSTDERR:\n{p.stderr[-3000:]}"
    return p.stdout


@pytest.mark.slow
def test_federated_lm_example_smokes():
    out = _run_example()
    assert "round" in out.lower() and "loss" in out.lower()


@pytest.mark.slow
def test_federated_lm_example_smokes_with_update_filter():
    # attention-only fine-tuning: the qwen2 decls carry no LoRA leaves, so
    # partial-update the attn block (same ParamFilter machinery)
    out = _run_example("--update-filter", "*/attn/*")
    assert "loss" in out.lower()


# ---------------------------------------------------------------------------
# committed benchmark artifact gates
# ---------------------------------------------------------------------------

def test_bench_pr10_artifact_gates_hold():
    path = os.path.join(ROOT, "BENCH_pr10.json")
    rows = json.load(open(path))
    codec = rows["edge_lm_uplink_codec"]
    assert codec["reduction_x"] >= 10.0 and codec["gate_10x"]
    e2e = rows["edge_lm_uplink_e2e"]
    assert e2e["reduction_x"] >= 10.0 and e2e["gate_10x"]
    kern = rows["edge_lm_kernel_parity"]
    assert kern["bit_exact"] and kern["max_abs_diff"] == 0.0
    conv = rows["edge_lm_convergence"]
    assert conv["gate_10x"] and conv["reduction_x"] >= 10.0
    assert conv["gate_time_1_25x"]
    assert conv["time_to_target_ratio"] <= 1.25
    assert conv["topk_rounds_to_target"] <= len(conv["topk_curve"])
