"""Clustering + topology-compiler tests, incl. hypothesis invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.clustering import ClusterTree, build_tree, validate_tree
from repro.core.topology import compile_tree, flat_schedule, validate_schedule


def _clients(n):
    return [f"c{i}" for i in range(n)]


class TestBuildTree:
    @pytest.mark.parametrize("n,ratio,levels", [
        (2, 0.3, 3), (5, 0.3, 3), (8, 0.5, 3), (16, 0.3, 3), (16, 0.3, 2),
        (40, 0.3, 3), (100, 0.2, 4), (3, 0.9, 2), (1, 0.5, 3),
    ])
    def test_invariants(self, n, ratio, levels):
        cs = _clients(n)
        tree = build_tree("s", cs, cs, ratio, levels)
        assert validate_tree(tree, cs) == []

    def test_assignments_cover_everyone(self):
        cs = _clients(12)
        tree = build_tree("s", cs, cs, 0.3, 3)
        asg = tree.assignments()
        assert set(asg) == set(cs)
        # every client trains exactly one leaf cluster
        for a in asg.values():
            assert a.train_cluster is not None
        # total expected inputs at level 0 == number of clients
        total = sum(d.expected for a in asg.values() for d in a.duties
                    if d.level == 0)
        assert total == len(cs)
        # exactly one root duty
        roots = [d for a in asg.values() for d in a.duties if d.parent is None]
        assert len(roots) == 1

    def test_ranked_heads_get_duty(self):
        cs = _clients(10)
        ranked = ["c7", "c3"] + [c for c in cs if c not in ("c7", "c3")]
        tree = build_tree("s", cs, ranked, 0.2, 3)
        heads0 = {c.head for c in tree.levels[0]}
        assert heads0 == {"c7", "c3"}

    def test_describe_roundtrip(self):
        cs = _clients(9)
        tree = build_tree("s", cs, cs, 0.3, 3)
        back = ClusterTree.from_describe(tree.describe())
        assert validate_tree(back, cs) == []
        assert back.describe() == tree.describe()

    @settings(max_examples=60, deadline=None)
    @given(n=st.integers(1, 60), ratio=st.floats(0.05, 0.95),
           levels=st.integers(1, 5), seed=st.integers(0, 5))
    def test_property_random_trees_valid(self, n, ratio, levels, seed):
        rng = np.random.default_rng(seed)
        cs = _clients(n)
        ranked = list(rng.permutation(cs))
        tree = build_tree("s", cs, ranked, ratio, levels)
        assert validate_tree(tree, cs) == []
        asg = tree.assignments()
        assert set(asg) == set(cs)


class TestScheduleCompile:
    @pytest.mark.parametrize("n,ratio,levels", [
        (4, 0.5, 3), (8, 0.3, 3), (16, 0.3, 3), (16, 0.25, 4), (2, 0.5, 2),
    ])
    def test_groups_partition_axis(self, n, ratio, levels):
        cs = _clients(n)
        tree = build_tree("s", cs, cs, ratio, levels)
        sched = compile_tree(tree)
        assert validate_schedule(sched) == []
        assert sched.n_clients == n

    def test_weighted_sum_equivalence_numpy(self):
        """Simulate the masked grouped-psum levels in numpy and check the
        tree reproduces the flat weighted sum exactly."""
        rng = np.random.default_rng(0)
        for n, ratio, levels in [(8, 0.3, 3), (16, 0.3, 3), (12, 0.5, 4)]:
            cs = _clients(n)
            tree = build_tree("s", cs, cs, ratio, levels)
            sched = compile_tree(tree)
            w = rng.uniform(0.5, 3.0, n)
            theta = rng.normal(size=(n, 7))
            contrib = theta * w[:, None]
            tw = w.copy()
            for lvl, groups in enumerate(sched.level_groups):
                if lvl > 0:
                    mask = np.asarray(sched.head_masks[lvl - 1], float)
                    contrib = contrib * mask[:, None]
                    tw = tw * mask
                newc = np.zeros_like(contrib)
                newt = np.zeros_like(tw)
                for g in groups:
                    idx = list(g)
                    newc[idx] = contrib[idx].sum(0)
                    newt[idx] = tw[idx].sum()
                contrib, tw = newc, newt
            got = contrib[0] / tw[0]
            want = (theta * w[:, None]).sum(0) / w.sum()
            np.testing.assert_allclose(got, want, rtol=1e-12)

    def test_signature_stability(self):
        cs = _clients(8)
        t1 = build_tree("s", cs, cs, 0.3, 3)
        t2 = build_tree("s", cs, cs, 0.3, 3)
        assert compile_tree(t1).signature() == compile_tree(t2).signature()
        assert flat_schedule(8).signature() != compile_tree(t1).signature()
