"""Multi-device integration tests (compiled FL data plane, aggregation
schedule equivalence, e2e trainer, dry-run micro-cells).

These need >1 XLA device; jax locks the device count at first init, so
each test runs in a fresh subprocess with XLA_FLAGS set.  The driver
scripts double as dev-loop tools in scripts/.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=ROOT)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout[-3000:]}\nSTDERR:\n{p.stderr[-3000:]}"
    return p.stdout


@pytest.mark.slow
def test_fl_step_schedules_agree():
    out = run_sub(open(os.path.join(ROOT, "scripts/smoke_flstep.py")).read())
    assert "ALL FL-STEP CHECKS PASSED" in out


@pytest.mark.slow
def test_compressed_and_rsag_schedules_match_flat():
    code = '''
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ShapeConfig, get_arch, smoke_config
from repro.core.fl_step import build_fl_round_step, init_state
from repro.core.topology import AggSchedule, flat_schedule
from repro.models import inputs as minputs

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = smoke_config(get_arch("hymba-1.5b"))
shape = ShapeConfig("t", 32, 8, "train")
key = jax.random.PRNGKey(0)
with mesh:
    state = init_state(cfg, mesh, key)
    batch = minputs.make_batch(cfg, shape, key, clients=4)
    w = jnp.array([1.0, 2.0, 3.0, 4.0])
    outs = {}
    for kind in ("flat", "rs_ag", "compressed"):
        step = jax.jit(build_fl_round_step(cfg, mesh, AggSchedule(kind, 4)))
        s, m = step(state, batch, w)
        outs[kind] = jax.device_get(s["params"])
for kind in ("rs_ag", "compressed"):
    for a, b in zip(jax.tree_util.tree_leaves(outs[kind]),
                    jax.tree_util.tree_leaves(outs["flat"])):
        tol = 2e-2 if kind == "compressed" else 5e-3
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol)
print("SCHEDULES MATCH")
'''
    assert "SCHEDULES MATCH" in run_sub(code)


@pytest.mark.slow
def test_e2e_trainer_with_failure_and_resume():
    code = '''
import jax, numpy as np
from repro.configs.base import get_arch, smoke_config
from repro.ft.failures import FailurePlan
from repro.launch.mesh import make_host_mesh
from repro.launch.train import SDFLMQTrainer
import tempfile, os

cfg = smoke_config(get_arch("qwen1.5-4b"))
mesh = make_host_mesh(data=4, model=2)
ck = tempfile.mkdtemp()
plan = FailurePlan(fail_at={2: ["c3"]})
tr = SDFLMQTrainer(cfg, mesh, 4, 4, 2, 32, ckpt_dir=ck,
                   failure_plan=plan)
ms = tr.run()
assert len(ms) == 4
assert ms[-1]["n_clients"] == 3, ms[-1]
assert all(np.isfinite(m["loss"]) for m in ms)
# losses should broadly decrease
assert ms[-1]["loss"] <= ms[0]["loss"] + 0.1
# resume: new trainer starts from checkpointed round
tr2 = SDFLMQTrainer(cfg, mesh, 4, 4, 2, 32, ckpt_dir=ck)
assert tr2.start_round == 4
print("E2E OK")
'''
    assert "E2E OK" in run_sub(code)


@pytest.mark.slow
def test_dryrun_micro_cell_both_meshes():
    code = '''
from repro.launch.dryrun import lower_cell
rec = lower_cell("hymba-1.5b", "decode_32k", False)
assert rec["status"] == "ok", rec
rec2 = lower_cell("hymba-1.5b", "decode_32k", True)
assert rec2["status"] == "ok", rec2
assert rec2["n_devices"] == 512
print("DRYRUN MICRO OK")
'''
    # dryrun sets its own XLA_FLAGS on import; need 512 here
    assert "DRYRUN MICRO OK" in run_sub(code, devices=512)


@pytest.mark.slow
def test_moe_impls_match_auto():
    out = run_sub(open(os.path.join(ROOT, "scripts/smoke_moe_a2a.py")).read())
    assert "MOE A2A OK" in out


@pytest.mark.slow
def test_compiled_strategies_match_flat_reference():
    """Every compiled-capable strategy, run as mesh collectives through
    aggregate_params, must match the same strategy's numpy flat reference —
    the same registry the host MQTT path consumes."""
    code = '''
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.api.strategies import get_strategy
from repro.core.aggregation import aggregate_params
from repro.core.clustering import build_tree
from repro.core.topology import compile_tree, flat_schedule

mesh = jax.make_mesh((4, 2), ("data", "model"))
n = 4
rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.normal(size=(n, 8, 6)).astype(np.float32)),
          "b": jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))}
specs = {"w": P("data", None, None), "b": P("data", None)}
weights = jnp.asarray([1.0, 2.0, 3.0, 4.0])
ref = {"w": jnp.zeros((n, 8, 6), jnp.float32), "b": jnp.ones((n, 5), jnp.float32)}
tree = compile_tree(build_tree("s", [f"c{i}" for i in range(n)],
                               [f"c{i}" for i in range(n)], 0.5, 3))
pw = np.asarray(params["w"]); pb = np.asarray(params["b"]); wv = np.asarray(weights)

for sched in (flat_schedule(n), tree):
    for name in ("fedavg", "fedprox", "trimmed_mean", "coordinate_median"):
        strat = get_strategy(name)
        with mesh:
            out = jax.jit(lambda p, w, r: aggregate_params(
                p, w, mesh, "data", sched, specs, strategy=name,
                ref_params=r if strat.needs_ref else None))(params, weights, ref)
        if strat.reduction == "stack":
            want_w = strat.combine({"w": pw}, wv, np)["w"]
            want_b = strat.combine({"b": pb}, wv, np)["b"]
        else:
            cw = np.stack([np.asarray(strat.premap(
                {"w": pw[i], "b": pb[i]},
                {"w": np.zeros((8, 6), np.float32), "b": np.ones(5, np.float32)}
                if strat.needs_ref else None, np)["w"]) for i in range(n)])
            cb = np.stack([np.asarray(strat.premap(
                {"w": pw[i], "b": pb[i]},
                {"w": np.zeros((8, 6), np.float32), "b": np.ones(5, np.float32)}
                if strat.needs_ref else None, np)["b"]) for i in range(n)])
            want_w = (cw * wv[:, None, None]).sum(0) / wv.sum()
            want_b = (cb * wv[:, None]).sum(0) / wv.sum()
        for i in range(n):
            np.testing.assert_allclose(np.asarray(out["w"])[i], want_w,
                                       rtol=2e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(out["b"])[i], want_b,
                                       rtol=2e-5, atol=1e-6)
print("COMPILED STRATEGIES OK")
'''
    assert "COMPILED STRATEGIES OK" in run_sub(code)


@pytest.mark.slow
def test_compiled_robust_combine_masks_dead_mesh_rows():
    """Churn-aware masking regression: a departed client's stale mesh row
    (carried at zero weight) must not shift the compiled trimmed-mean /
    coordinate-median statistics — the combine must equal the numpy
    reference over the *live* subset, even when the dead row holds
    adversarially huge garbage."""
    code = '''
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.api.strategies import get_strategy
from repro.core.aggregation import aggregate_params
from repro.core.topology import flat_schedule

mesh = jax.make_mesh((4, 2), ("data", "model"))
n = 4
rng = np.random.default_rng(1)
pw = rng.normal(size=(n, 8, 6)).astype(np.float32)
pb = rng.normal(size=(n, 5)).astype(np.float32)
# client 3 departed: its row holds huge stale garbage at zero weight
pw[3] = 1e6 * rng.normal(size=(8, 6)).astype(np.float32)
pb[3] = -1e6 * np.ones(5, np.float32)
params = {"w": jnp.asarray(pw), "b": jnp.asarray(pb)}
specs = {"w": P("data", None, None), "b": P("data", None)}
weights = jnp.asarray([1.0, 2.0, 3.0, 0.0])
sched = flat_schedule(n)

for name in ("trimmed_mean", "coordinate_median"):
    strat = get_strategy(name)
    with mesh:
        out = jax.jit(lambda p, w: aggregate_params(
            p, w, mesh, "data", sched, specs, strategy=name))(params, weights)
    # oracle: the strategy over the live rows only
    want_w = np.asarray(strat.combine({"w": pw[:3]},
                                      np.asarray([1.0, 2.0, 3.0]), np)["w"])
    want_b = np.asarray(strat.combine({"b": pb[:3]},
                                      np.asarray([1.0, 2.0, 3.0]), np)["b"])
    for i in range(n):
        np.testing.assert_allclose(np.asarray(out["w"])[i], want_w,
                                   rtol=2e-5, atol=1e-6, err_msg=name)
        np.testing.assert_allclose(np.asarray(out["b"])[i], want_b,
                                   rtol=2e-5, atol=1e-6, err_msg=name)
    assert np.abs(np.asarray(out["w"])).max() < 1e4, name
print("MASKED ROBUST COMBINE OK")
'''
    assert "MASKED ROBUST COMBINE OK" in run_sub(code)
