"""Async-FL property/unit wall (repro.api.async_fl).

Pins the FedBuff semantics:
  (a) staleness bound = inf with K = cohort reproduces the synchronous
      round protocol *bit-identically* (fedavg and fedprox, any topology);
  (b) contributions older than the staleness bound are always rejected and
      counted — never folded into a buffer;
  (c) staleness-discount weights are order-invariant for a fixed admitted
      set (the buffer is a weighted mean, not a sequence);
plus per-client pacing, gossip merge rules, the poly discount variants,
and the churn-aware masked robust combines shared with the compiled path.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import Federation
from repro.api.async_fl import AsyncBuffer, AsyncConfig, head_share, \
    resolve_discount
from repro.api.strategies import get_strategy
from repro.core import topics as T


def make_pair(n, strategy, rounds, levels=3, ratio=0.4, k=None, bound=None,
              **async_kw):
    """A synchronous session and its async twin on separate federations."""
    def mk(async_mode):
        fed = Federation(aggregator_ratio=ratio, levels=levels)
        clients = [fed.client(f"c{i}") for i in range(n)]
        return fed, fed.create_session(
            "s", "m", rounds=rounds, participants=clients,
            strategy=strategy, async_mode=async_mode)
    sync = mk(None)
    asyn = mk(dict(buffer_k=k if k is not None else n,
                   staleness_bound=bound, **async_kw))
    return sync, asyn


def drift_train(n, seed):
    rng = np.random.default_rng(seed)
    drift = {f"c{i}": rng.normal(size=(5,)).astype(np.float32)
             for i in range(n)}
    weights = {f"c{i}": int(rng.integers(1, 9)) for i in range(n)}

    def train(cid, g, r):
        base = np.zeros(5, np.float32) if g is None else np.asarray(g["w"])
        return {"w": (base * np.float32(0.6) + drift[cid])}, weights[cid]
    return train


# ---------------------------------------------------------------------------
# (a) Async == sync bit-identity at K = cohort, bound = inf
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.integers(3, 10), seed=st.integers(0, 100),
       strategy=st.sampled_from(["fedavg", "fedprox"]))
def test_property_async_equivalence_bit_identical(n, seed, strategy):
    """With an unlimited staleness bound and buffer K = cohort size, every
    trigger point and every accumulation order coincides with the
    synchronous path: the minted globals must be bit-identical, version by
    version."""
    rng = np.random.default_rng(seed)
    levels = int(rng.integers(2, 4))
    ratio = float(rng.uniform(0.25, 0.6))
    rounds = 2
    (f1, s1), (f2, s2) = make_pair(n, strategy, rounds,
                                   levels=levels, ratio=ratio)
    train = drift_train(n, seed)
    init = {"w": np.zeros(5, np.float32)}
    sync_g, async_g = [], []
    s1.on_global_update = lambda p, v: sync_g.append((v, np.array(p["w"])))
    s2.on_global_update = lambda p, v: async_g.append((v, np.array(p["w"])))
    s1.run(train, initial_params=init)
    rep = s2.run_async(train, initial_params=init, max_time_s=60.0)
    assert rep.final_state == "terminated" and not rep.stalled
    assert [v for v, _ in async_g] == [v for v, _ in sync_g]
    for (_, a), (_, b) in zip(sync_g, async_g):
        np.testing.assert_array_equal(a, b)
    assert rep.rejected_stale == 0


def test_async_equivalence_legacy_wire_too():
    """The bit-identity also holds on the legacy msgpack wire."""
    def mk(async_mode):
        fed = Federation(aggregator_ratio=0.4, wire_format="legacy")
        clients = [fed.client(f"c{i}") for i in range(5)]
        return fed.create_session("s", "m", rounds=2, participants=clients,
                                  async_mode=async_mode)
    train = drift_train(5, 3)
    init = {"w": np.zeros(5, np.float32)}
    s1, s2 = mk(None), mk(dict(buffer_k=5))
    got = {}
    s1.on_global_update = lambda p, v: got.setdefault(("s", v), np.array(p["w"]))
    s2.on_global_update = lambda p, v: got.setdefault(("a", v), np.array(p["w"]))
    s1.run(train, initial_params=init)
    s2.run_async(train, initial_params=init)
    for v in (1, 2):
        np.testing.assert_array_equal(got[("s", v)], got[("a", v)])


# ---------------------------------------------------------------------------
# (b) Bounded staleness: older-than-bound is always rejected and counted
# ---------------------------------------------------------------------------

def _root_and_cluster(fed, session):
    """The root aggregator client + its root duty's cluster id."""
    desc = session.tree().describe()
    top = desc["levels"][-1][0]
    root = session.participants[top["head"]]
    return root, top["id"]


def _async_session(n=6, strategy="fedavg", k=None, bound=None, rounds=50,
                   **kw):
    fed = Federation(aggregator_ratio=0.4)
    clients = [fed.client(f"c{i}") for i in range(n)]
    session = fed.create_session(
        "s", "m", rounds=rounds, participants=clients, strategy=strategy,
        async_mode=dict(buffer_k=k if k is not None else n,
                        staleness_bound=bound, **kw))
    return fed, session


@settings(max_examples=15, deadline=None)
@given(bound=st.integers(0, 5), seed=st.integers(0, 1000))
def test_property_stale_beyond_bound_always_rejected_and_counted(bound, seed):
    rng = np.random.default_rng(seed)
    fed, session = _async_session(n=6, bound=bound)
    root, cid = _root_and_cluster(fed, session)
    ctx = root.models.sessions["s"]
    ctx.global_version = now = 10
    topic = T.cluster_agg("s", cid)
    rejected = admitted = 0
    for i in range(5):                  # < buffer_k: no flush interference
        stamp = now - int(rng.integers(0, 9))
        root._on_cluster_input(topic, {
            "params": {"w": np.ones(3, np.float32)}, "weight": 1.0,
            "sender": f"x{i}", "partial": False, "round": stamp})
        if now - stamp > bound:
            rejected += 1
        else:
            admitted += 1
        buf = ctx.async_bufs[cid]
        assert buf.rejected_stale == rejected
        assert buf.contribs == admitted
        assert ctx.async_rejected == rejected
        assert ctx.async_admitted == admitted
    acc = ctx.accs[cid]
    assert acc.received == admitted     # nothing stale touched the buffer


def test_stale_partial_rejected_by_min_stamp():
    """A partial held in transit past the bound (partition heal) is dropped
    whole — its contribution count lands in the rejection counters."""
    fed, session = _async_session(n=6, bound=1)
    root, cid = _root_and_cluster(fed, session)
    ctx = root.models.sessions["s"]
    ctx.global_version = 10
    root._on_cluster_input(T.cluster_agg("s", cid), {
        "params": {"w": np.ones(3, np.float32)}, "weight": 2.0,
        "sender": "h", "partial": True, "round": 8, "contribs": 3,
        "stamp": 8})
    assert ctx.async_rejected == 3
    assert cid not in ctx.accs or ctx.accs[cid].received == 0


# ---------------------------------------------------------------------------
# (c) Staleness-discount weights are order-invariant
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), a=st.floats(0.1, 2.0))
def test_property_discount_weights_order_invariant(seed, a):
    """For a fixed admitted set, feeding the root buffer in any order mints
    the same global (weighted mean) and the same total weight."""
    rng = np.random.default_rng(seed)
    m = 5
    contribs = [({"w": rng.normal(size=(4,)).astype(np.float32)},
                 float(rng.integers(1, 7)), 10 - int(rng.integers(0, 4)))
                for _ in range(m)]

    def run(order):
        fed, session = _async_session(n=6, k=m, staleness_weight="poly",
                                      poly_a=a)
        root, cid = _root_and_cluster(fed, session)
        ctx = root.models.sessions["s"]
        ctx.global_version = 10
        for i in order:
            p, w, stamp = contribs[i]
            root._on_cluster_input(T.cluster_agg("s", cid), {
                "params": p, "weight": w, "sender": f"x{i}",
                "partial": False, "round": stamp})
        g = fed.param_server.get_global("s")
        assert g is not None            # m-th admission triggered the mint
        return np.array(g["params"]["w"])

    fwd = run(list(range(m)))
    perm = list(rng.permutation(m))
    np.testing.assert_allclose(run(perm), fwd, rtol=1e-6, atol=1e-7)
    # oracle: the discounted weighted mean, any order
    lam = lambda s: (1.0 + s) ** (-a)
    num = sum(np.asarray(p["w"], np.float64) * w * lam(10 - st_)
              for p, w, st_ in contribs)
    den = sum(w * lam(10 - st_) for _, w, st_ in contribs)
    np.testing.assert_allclose(fwd, (num / den).astype(np.float32),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Discount plumbing
# ---------------------------------------------------------------------------

def test_poly_staleness_strategy_variants():
    fa = get_strategy("fedavg_poly")
    fp = get_strategy("fedprox_poly")
    assert fa.staleness_discount(0) == 1.0
    assert fa.staleness_discount(3) == pytest.approx(4.0 ** -0.5)
    assert fp.staleness_discount(3) == pytest.approx(4.0 ** -0.5)
    assert fp.needs_ref                      # still fedprox underneath
    # base strategies stay constant-discount (bit-identity anchor)
    assert get_strategy("fedavg").staleness_discount(99) == 1.0


def test_resolve_discount_precedence():
    strat = get_strategy("fedavg_poly")
    assert resolve_discount({"weight": "strategy"}, strat)(3) \
        == pytest.approx(4.0 ** -0.5)
    assert resolve_discount({"weight": "constant"}, strat)(3) == 1.0
    assert resolve_discount({"weight": "poly", "poly_a": 1.0},
                            get_strategy("fedavg"))(3) == pytest.approx(0.25)
    with pytest.raises(KeyError):
        resolve_discount({"weight": "nope"}, strat)


def test_head_share_reduces_to_sync_trigger_at_full_k():
    assert head_share(3, 6, 6) == 3          # K = cohort -> expected
    assert head_share(3, 3, 6) == 2          # proportional share
    assert head_share(3, 1, 6) == 1
    assert head_share(5, 2, 20) == 1         # never below 1
    assert head_share(3, 99, 6) == 3         # never above expected


# ---------------------------------------------------------------------------
# Pacing
# ---------------------------------------------------------------------------

def test_per_client_pacing_decouples_cadence():
    """A straggler with a 6x period trains ~6x less often — and the
    federation keeps minting instead of blocking on it."""
    fed, session = _async_session(n=5, k=2, rounds=12,
                                  base_period_s=1.0,
                                  periods={"c4": 6.0})
    fires = {f"c{i}": 0 for i in range(5)}
    params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
              for i in range(5)}

    def train(cid, g, r):
        fires[cid] += 1
        return params[cid], 1

    rep = session.run_async(train, max_time_s=60.0,
                            initial_params={"w": np.zeros(3, np.float32)})
    # a flush already triggered in the final cascade may mint one version
    # past the budget before the termination broadcast lands — that race is
    # inherent to K-of-N (and harmless)
    assert rep.final_state == "terminated" and rep.updates >= 12
    assert fires["c4"] <= fires["c0"] // 3   # straggler paced down
    assert fires["c0"] >= 5                  # fast clients kept going


def test_pacing_jitter_is_seeded_and_deterministic():
    def timeline(seed):
        fed, session = _async_session(n=4, k=2, rounds=6,
                                      period_jitter_s=0.3, seed=seed)
        params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
                  for i in range(4)}
        rep = session.run_async(lambda c, g, r: (params[c], 1),
                                max_time_s=60.0,
                                initial_params={"w": np.zeros(3, np.float32)})
        return rep.timeline
    t_a, t_b, t_c = timeline(7), timeline(7), timeline(8)
    assert t_a == t_b                        # same seed: same schedule
    assert t_a != t_c                        # different seed: different


# ---------------------------------------------------------------------------
# Gossip merge rules
# ---------------------------------------------------------------------------

def _gossip_session():
    fed, session = _async_session(n=6, k=3, gossip_period_s=1.0)
    a, b = session.participants["c2"], session.participants["c3"]
    return fed, session, a, b


def test_gossip_adopts_strictly_newer_version():
    fed, session, a, b = _gossip_session()
    ctx = a.models.sessions["s"]
    ctx.global_version, ctx.site_seq = 2, 0
    ctx.view_params = {"w": np.zeros(3, np.float32)}
    a._on_gossip(T.gossip("s", "c3"),
                 {"params": {"w": np.full(3, 7.0, np.float32)},
                  "version": 5, "site_seq": 2, "sender": "c3"})
    assert ctx.global_version == 5 and ctx.site_seq == 2
    np.testing.assert_array_equal(ctx.view_params["w"], np.full(3, 7.0))
    assert ctx.gossip_adopts == 1


def test_gossip_same_version_site_models_average_symmetrically():
    fed, session, a, b = _gossip_session()
    ctx = a.models.sessions["s"]
    ctx.global_version, ctx.site_seq = 4, 1
    ctx.view_params = {"w": np.full(3, 2.0, np.float32)}
    a._on_gossip(T.gossip("s", "c3"),
                 {"params": {"w": np.full(3, 6.0, np.float32)},
                  "version": 4, "site_seq": 3, "sender": "c3"})
    np.testing.assert_array_equal(ctx.view_params["w"], np.full(3, 4.0))
    assert ctx.site_seq == 3 and ctx.gossip_merges == 1
    # older version is ignored outright
    a._on_gossip(T.gossip("s", "c3"),
                 {"params": {"w": np.full(3, 99.0, np.float32)},
                  "version": 3, "site_seq": 9, "sender": "c3"})
    np.testing.assert_array_equal(ctx.view_params["w"], np.full(3, 4.0))
    # own gossip echo is ignored
    a._on_gossip(T.gossip("s", "c2"),
                 {"params": {"w": np.full(3, 50.0, np.float32)},
                  "version": 9, "site_seq": 0, "sender": "c2"})
    assert ctx.global_version == 4


def test_gossip_adopted_version_still_accepts_its_real_global():
    """Learning a version through gossip must not mask the real global of
    the same version: that publish carries the strategy reference (fedprox)
    and any server state (fedadam) the gossip message did not."""
    fed, session, a, b = _gossip_session()
    ctx = a.models.sessions["s"]
    ctx.strategy = "fedprox"                 # needs_ref strategy
    ctx.global_version, ctx.site_seq = 2, 0
    a._on_gossip(T.gossip("s", "c3"),
                 {"params": {"w": np.full(3, 7.0, np.float32)},
                  "version": 5, "site_seq": 0, "sender": "c3"})
    assert ctx.global_version == 5 and ctx.version_from_gossip
    # the real v5 global arrives later (e.g. released by heal): processed
    a._on_global(T.global_model("s"),
                 {"params": {"w": np.full(3, 7.0, np.float32)},
                  "version": 5, "round": 5})
    assert not ctx.version_from_gossip
    assert ctx.global_params is not None     # proximal reference refreshed
    np.testing.assert_array_equal(ctx.global_params["w"], np.full(3, 7.0))
    # ...but only once: the next same-version echo is dropped again
    a._on_global(T.global_model("s"),
                 {"params": {"w": np.full(3, 9.0, np.float32)},
                  "version": 5, "round": 5})
    np.testing.assert_array_equal(ctx.params["w"], np.full(3, 7.0))


def test_run_async_timeout_cancels_pacing_timers():
    """Exiting on the time budget must quiesce the shared clock: no live
    pacing/gossip timer series may keep publishing for the session."""
    fed, session = _async_session(n=4, k=2, rounds=0,   # rounds=0: no
                                  gossip_period_s=1.0)  # version budget
    params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
              for i in range(4)}
    rep = session.run_async(lambda c, g, r: (params[c], 1),
                            max_time_s=5.0,
                            initial_params={"w": np.zeros(3, np.float32)})
    assert rep.timed_out and session.state == "running"
    assert all(t.cancelled for t in list(session._pacers.values())
               + list(session._gossipers.values()))
    v_exit = session.global_version()
    # advance past the coordinator's (harmless) waiting-time expiry timer:
    # nothing else may fire — no training, no mints, an empty heap after
    fed.clock.advance(200.0)
    assert session.global_version() == v_exit
    assert fed.clock.pending() == 0, "timer series leaked past run_async"


def test_real_global_supersedes_site_model():
    fed, session, a, b = _gossip_session()
    ctx = a.models.sessions["s"]
    ctx.global_version, ctx.site_seq = 4, 3
    ctx.view_params = {"w": np.full(3, 2.0, np.float32)}
    a._on_global(T.global_model("s"),
                 {"params": {"w": np.full(3, 1.0, np.float32)},
                  "version": 5, "round": 5})
    assert ctx.global_version == 5 and ctx.site_seq == 0
    np.testing.assert_array_equal(ctx.view_params["w"], np.full(3, 1.0))
    # a stale global echo (async mode) does not regress the view
    a._on_global(T.global_model("s"),
                 {"params": {"w": np.full(3, 9.0, np.float32)},
                  "version": 4, "round": 4})
    assert ctx.global_version == 5
    np.testing.assert_array_equal(ctx.view_params["w"], np.full(3, 1.0))


# ---------------------------------------------------------------------------
# Churn-aware masked robust combines (shared with the compiled path)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 12), seed=st.integers(0, 500),
       name=st.sampled_from(["trimmed_mean", "coordinate_median"]))
def test_property_masked_combine_ignores_dead_rows(n, seed, name):
    """combine_masked over n rows with d dead ones == combine over the live
    subset — a departed client's stale row cannot shift the statistic."""
    rng = np.random.default_rng(seed)
    strat = get_strategy(name)
    live = int(rng.integers(1, n + 1))
    vals = rng.normal(size=(n, 4, 2)).astype(np.float32)
    vals[live:] = 1e6 * rng.normal(size=(n - live, 4, 2)).astype(np.float32)
    w = np.zeros(n, np.float64)
    w[:live] = rng.uniform(0.5, 5.0, size=live)
    perm = rng.permutation(n)
    got = strat.combine_masked({"x": vals[perm]}, w[perm], np)["x"]
    want = strat.combine({"x": vals[:live]}, w[:live], np)["x"]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_masked_combine_all_dead_yields_zeros_not_sentinel():
    vals = np.full((4, 3), 1e6, np.float32)
    w = np.zeros(4, np.float64)
    for name in ("trimmed_mean", "coordinate_median"):
        got = get_strategy(name).combine_masked({"x": vals}, w, np)["x"]
        np.testing.assert_array_equal(got, np.zeros(3, np.float32))


def test_masked_combine_matches_unmasked_when_all_alive():
    rng = np.random.default_rng(0)
    vals = rng.normal(size=(8, 5)).astype(np.float32)
    w = np.ones(8, np.float64)
    for name in ("trimmed_mean", "coordinate_median"):
        strat = get_strategy(name)
        np.testing.assert_allclose(
            strat.combine_masked({"x": vals}, w, np)["x"],
            strat.combine({"x": vals}, w, np)["x"], rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------

def test_async_cfg_rides_topology_broadcast():
    fed, session = _async_session(n=4, k=3, bound=2, gossip_period_s=2.0)
    for cl in session.participants.values():
        acfg = cl.models.sessions["s"].async_cfg
        assert acfg is not None
        assert acfg["k"] == 3 and acfg["bound"] == 2
        assert acfg["cohort"] == 4
        assert acfg["gossip_period_s"] == 2.0


def test_sync_round_api_is_guarded():
    fed, session = _async_session(n=3, k=2)
    with pytest.raises(RuntimeError):
        session.run_round(lambda c, g, r: ({"w": np.zeros(2)}, 1))
    with pytest.raises(RuntimeError):
        session.run(lambda c, g, r: ({"w": np.zeros(2)}, 1))


def test_async_buffer_cycle_counters():
    acc = object.__new__(type("X", (), {}))  # placeholder accumulator ref
    buf = AsyncBuffer(acc)
    buf.contribs += 2
    buf.note_stamp(5)
    buf.note_stamp(3)
    buf.note_stamp(7)
    assert buf.min_stamp == 3 and buf.contribs == 2
    buf.start_cycle()
    assert buf.min_stamp is None and buf.contribs == 0
    assert buf.acc is acc
