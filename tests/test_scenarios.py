"""Scenario/chaos regression suite: the virtual-time event-driven transport
under reordering, partition-and-heal, straggler-deadline cuts, and mid-round
churn — for both a sum-reduction strategy (fedavg) and a robust stack
strategy (trimmed_mean).  Everything runs on fixed seeds; the matrix must be
deterministic and fast (the whole module is in the ``scenario`` CI job)."""
import numpy as np
import pytest

from repro.api import Federation, LatencyTransport, SimClock, scenarios
from repro.core.broker import SimBroker
from repro.core.mqttfc import MQTTFC
from repro.core.stats import StatsSimulator

pytestmark = pytest.mark.scenario


# ---------------------------------------------------------------------------
# SimClock semantics
# ---------------------------------------------------------------------------

class TestSimClock:
    def test_events_fire_in_timestamp_order(self):
        c, out = SimClock(), []
        c.schedule(2.0, lambda: out.append("b"))
        c.schedule(1.0, lambda: out.append("a"))
        c.schedule(3.0, lambda: out.append("c"))
        c.run_until_idle()
        assert out == ["a", "b", "c"]
        assert c.now == 3.0

    def test_same_time_is_fifo(self):
        c, out = SimClock(), []
        for i in range(5):
            c.schedule(1.0, lambda i=i: out.append(i))
        c.run_until_idle()
        assert out == [0, 1, 2, 3, 4]

    def test_events_can_schedule_events(self):
        c, out = SimClock(), []

        def cascade():
            out.append("first")
            c.schedule(c.now + 1.0, lambda: out.append("second"))

        c.schedule(1.0, cascade)
        c.run_until_idle()
        assert out == ["first", "second"] and c.now == 2.0

    def test_advance_to_respects_limit_and_fires_timers(self):
        c, out = SimClock(), []
        c.schedule(1.0, lambda: out.append("m1"))
        c.schedule(1.5, lambda: out.append("t"), timer=True)
        c.schedule(2.0, lambda: out.append("m2"))
        c.advance_to(1.6)
        assert out == ["m1", "t"] and c.now == 1.6
        c.advance_to(5.0)
        assert out == ["m1", "t", "m2"]

    def test_run_until_idle_leaves_timers_armed(self):
        c, out = SimClock(), []
        c.schedule(0.5, lambda: out.append("timer"), timer=True)
        c.schedule(1.0, lambda: out.append("msg"))
        c.run_until_idle()
        assert out == ["msg"]
        c.advance(0.0)           # explicit time control fires the late timer
        assert out == ["msg", "timer"]

    def test_cancel(self):
        c, out = SimClock(), []
        ev = c.schedule(1.0, lambda: out.append("x"))
        ev.cancel()
        c.run_until_idle()
        assert out == [] and c.pending() == 0

    def test_call_when_idle_waits_for_message_queue(self):
        c, out = SimClock(), []
        c.schedule(1.0, lambda: out.append("m"))
        c.call_when_idle(lambda: out.append("idle"))
        c.run_until_idle()
        assert out == ["m", "idle"]

    def test_time_never_flows_backwards(self):
        c = SimClock(now=5.0)
        ev = c.schedule(1.0, lambda: None)    # past: clamped to now
        assert ev.time == 5.0
        c.run_until_idle()
        assert c.now == 5.0


# ---------------------------------------------------------------------------
# Genuine reordering (acceptance criterion)
# ---------------------------------------------------------------------------

def test_transport_reorders_under_asymmetric_delay():
    """Two messages published A,B arrive B,A when A's link is slower."""
    clock = SimClock()
    lt = LatencyTransport(SimBroker(), clock=clock)
    lt.set_link("A", delay_s=0.5)
    lt.set_link("B", delay_s=0.05)
    got = []
    lt.connect("rx", lambda m: got.append(m.payload))
    lt.subscribe("rx", "t/#", qos=1)
    with clock.hold():
        lt.publish("t/m", b"from-A", qos=1, sender="A")
        lt.publish("t/m", b"from-B", qos=1, sender="B")
        assert got == []                       # queued, not delivered
        clock.run_until_idle()
    assert got == [b"from-B", b"from-A"]       # B overtook A
    assert clock.now == pytest.approx(0.5)


def test_round_reorders_updates_and_still_aggregates_both():
    """Session-level acceptance: c0's update is published first but arrives
    last; the round's global is still the exact mean of every update."""
    fed = Federation(aggregator_ratio=0.5)
    fed.transport.set_link("c0", delay_s=0.3)      # slow uplink
    clients = [fed.client(f"c{i}") for i in range(3)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)

    arrivals = []
    probe = MQTTFC(fed.transport, "probe")
    probe.subscribe_raw(
        "sdflmq/session/+/cluster/+/agg",
        lambda t, p: (not p["a"][0].get("partial")
                      and arrivals.append(p["a"][0]["sender"])))

    params = {f"c{i}": {"w": np.full(4, float(i), np.float32)}
              for i in range(3)}
    report = scenarios.play(session, lambda cid, g, r: (params[cid], 1),
                            rounds=1, round_time_s=1.0)
    assert arrivals[0] != "c0" and arrivals[-1] == "c0"   # published first,
    assert sorted(arrivals) == ["c0", "c1", "c2"]          # arrived last
    want = np.mean([params[c]["w"] for c in params], axis=0)
    np.testing.assert_allclose(session.global_params()["w"], want, rtol=1e-6)
    assert report.final_state == "terminated" and not report.stalled


def test_qos1_retransmission_arrives_late_not_just_billed():
    """A drawn drop on a QoS-1 link means the message arrives at 2x latency
    — genuinely after a message sent later on a clean link."""
    clock = SimClock()
    lt = LatencyTransport(SimBroker(), clock=clock, seed=3)
    lt.set_link("lossy", delay_s=0.1, drop_p=1.0)
    lt.set_link("clean", delay_s=0.15)
    got = []
    lt.connect("rx", lambda m: got.append(m.payload))
    lt.subscribe("rx", "t/#", qos=1)
    with clock.hold():
        lt.publish("t/m", b"lossy-first", qos=1, sender="lossy")
        lt.publish("t/m", b"clean-second", qos=1, sender="clean")
        clock.run_until_idle()
    assert got == [b"clean-second", b"lossy-first"]   # 0.15 < 0.2
    assert lt.sys_stats()["links"]["lossy"]["retransmits"] == 1


# ---------------------------------------------------------------------------
# Partitions
# ---------------------------------------------------------------------------

def test_partition_holds_until_heal():
    clock = SimClock()
    lt = LatencyTransport(SimBroker(), clock=clock)
    got = []
    lt.connect("rx", lambda m: got.append(m.payload))
    lt.subscribe("rx", "t/#", qos=1)
    lt.connect("tx", lambda m: None)
    lt.partition(["tx"], ["rx"])
    lt.publish("t/m", b"held", qos=1, sender="tx")
    assert got == [] and lt.partition_held == 1
    lt.publish("t/m", b"lost", qos=0, sender="tx")
    assert lt.partition_dropped == 1               # QoS 0 across the cut dies
    lt.heal()
    assert got == [b"held"]                        # QoS 1 waited for heal

    # ungrouped actors keep connectivity both ways
    lt.partition(["tx"], ["other"])
    lt.publish("t/m", b"through", qos=1, sender="tx")
    assert got == [b"held", b"through"]


def test_partition_and_heal_session_reconverges():
    """Rounds keep completing during a client-group partition (the
    coordinator stays reachable); held contributions from the partition
    window are stale-dropped after heal instead of corrupting later
    rounds, and the post-heal global re-includes both groups."""
    n, rounds = 6, 6
    fed = Federation(latency=dict(delay_s=0.01, seed=11), aggregator_ratio=0.4)
    sim = StatsSimulator([f"c{i}" for i in range(n)], seed=5)
    clients = [fed.client(f"c{i}", stats=sim.sample(f"c{i}", 0))
               for i in range(n)]
    session = fed.create_session("s", "m", rounds=rounds,
                                 participants=clients)
    groups = [[f"c{i}" for i in range(3)], [f"c{i}" for i in range(3, n)]]
    # per-client constant updates: group A avg = 1.0, group B avg = 4.0
    params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
              for i in range(n)}
    versions = []
    session.on_global_update = lambda p, v: versions.append(
        (v, float(np.mean(p["w"]))))

    report = scenarios.play(
        session, lambda cid, g, r: (params[cid], 1),
        events=[scenarios.partition(groups, t0=1.5, t1=3.5)],
        rounds=rounds, round_time_s=1.0)

    assert report.final_state == "terminated" and not report.stalled
    assert report.partition_held > 0
    g = session.global_params()["w"]
    assert np.isfinite(g).all()
    # after heal the global is again the all-client mean
    np.testing.assert_allclose(g, np.mean([p["w"] for p in params.values()],
                                          axis=0), rtol=1e-5)
    assert report.stale_dropped > 0      # held traffic was discarded, not
    assert versions[-1][0] >= 4          # folded into a later round


# ---------------------------------------------------------------------------
# Straggler deadline cut
# ---------------------------------------------------------------------------

def test_deadline_cut_excludes_straggler_and_round_completes():
    n = 5
    fed = Federation(latency=dict(delay_s=0.01, seed=1), aggregator_ratio=0.4,
                     round_deadline_s=0.5, flush_spacing_s=0.05)
    sim = StatsSimulator([f"c{i}" for i in range(n)], seed=5)
    # pin the straggler to a leaf-trainer role so the cut removes exactly
    # its contribution (a straggling *head* would cost its whole subtree)
    clients = [fed.client(f"c{i}", stats=sim.sample(f"c{i}", 0),
                          preferred_role="trainer" if i == n - 1
                          else "aggregator")
               for i in range(n)]
    session = fed.create_session("s", "m", rounds=2, participants=clients)
    fed.transport.set_link("c4", delay_s=2.0)      # way past the deadline
    params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
              for i in range(n)}
    seen = []
    session.on_global_update = lambda p, v: seen.append(np.array(p["w"]))

    report = scenarios.play(session, lambda cid, g, r: (params[cid], 1),
                            rounds=2, round_time_s=1.0)
    assert report.deadline_cuts >= 1
    assert report.final_state == "terminated" and not report.stalled
    # the cut round's global renormalizes over the responsive subset
    live = [params[f"c{i}"]["w"] for i in range(n - 1)]
    np.testing.assert_allclose(seen[0], np.mean(live, axis=0), rtol=1e-5)
    assert all(np.isfinite(g).all() for g in seen)


# ---------------------------------------------------------------------------
# The scenario matrix (headline deliverable)
# ---------------------------------------------------------------------------

def _matrix_session(strategy, n=6, rounds=5, **fed_kw):
    fed_kw.setdefault("latency", dict(delay_s=0.01, jitter_s=0.005, seed=42))
    fed = Federation(aggregator_ratio=0.4, **fed_kw)
    sim = StatsSimulator([f"c{i}" for i in range(n + 2)], seed=9)
    clients = [fed.client(f"c{i}", stats=sim.sample(f"c{i}", 0))
               for i in range(n)]
    session = fed.create_session("s", "m", rounds=rounds,
                                 participants=clients, strategy=strategy,
                                 capacity=(n, n + 2))
    session.start()
    return fed, session


def _matrix_events(kind, fed, n):
    if kind == "reorder":
        for i in range(n):                      # reversed arrival order
            fed.transport.set_link(f"c{i}", delay_s=0.01 * (n - i))
        return []
    if kind == "partition_heal":
        return [scenarios.partition(
            [[f"c{i}" for i in range(n // 2)],
             [f"c{i}" for i in range(n // 2, n)]], t0=1.5, t1=3.5)]
    if kind == "deadline_cut":
        fed.transport.set_link("c5", delay_s=2.0)
        return []
    if kind == "churn":
        return [scenarios.churn(fail_at={1: ["c5"]},
                                join_at={3: ["c6"]},
                                straggle_at={2: {"c1": 0.3}})]
    if kind == "dup_storm":
        # an at-least-once link: QoS-1 frames genuinely redelivered, one
        # list-form event degrading all three links at once
        return [scenarios.flaky_link(["c0", "c1", "c2"], dup_p=0.5,
                                     jitter_s=0.01, t0=0.5)]
    raise AssertionError(kind)


@pytest.mark.parametrize("strategy", ["fedavg", "trimmed_mean"])
@pytest.mark.parametrize("kind", ["reorder", "partition_heal",
                                  "deadline_cut", "churn", "dup_storm"])
def test_scenario_matrix_completes_with_finite_globals(kind, strategy):
    rounds = 5
    fed_kw = {}
    if kind == "deadline_cut":
        fed_kw = dict(round_deadline_s=0.5, flush_spacing_s=0.05)
    fed, session = _matrix_session(strategy, rounds=rounds, **fed_kw)
    events = _matrix_events(kind, fed, n=6)

    rng = np.random.default_rng(17)
    drift = {f"c{i}": rng.normal(size=(4,)).astype(np.float32)
             for i in range(8)}

    def train(cid, g, r):
        base = np.zeros(4, np.float32) if g is None else np.asarray(g["w"])
        upd = drift.get(cid, np.zeros(4, np.float32))
        return {"w": (base + upd).astype(np.float32)}, 1 + int(cid[1:])

    report = scenarios.play(session, train, events=events, rounds=rounds,
                            round_time_s=1.0,
                            initial_params={"w": np.zeros(4, np.float32)})
    assert not report.stalled
    assert report.final_state == "terminated"
    assert report.rounds_completed == rounds
    g = session.global_params()
    assert g is not None and np.isfinite(g["w"]).all()
    if kind == "churn":
        assert "c5" not in session.contributors()
        assert "c6" in session.contributors()
    if kind == "dup_storm":
        links = fed.transport.sys_stats()["links"]
        assert sum(s["duplicates"] for s in links.values()) > 0
        drops = sum(cl.fc.wire_stats()["duplicate_drops"]
                    for cl in fed.clients.values())
        drops += fed.coordinator.fc.wire_stats()["duplicate_drops"]
        assert drops > 0, "duplicates were delivered but never deduped"


def test_scenario_runs_are_deterministic():
    """Same seeds, same scenario -> bit-identical globals and identical
    report counters (per-link RNG streams, virtual-time event order)."""
    def run():
        fed, session = _matrix_session("fedavg", rounds=4)
        events = _matrix_events("partition_heal", fed, n=6)
        params = {f"c{i}": {"w": np.full(4, float(i) + 0.25, np.float32)}
                  for i in range(6)}
        report = scenarios.play(session, lambda c, g, r: (params[c], 1),
                                events=events, rounds=4, round_time_s=1.0)
        return (session.global_params()["w"], report.partition_held,
                report.stale_dropped, report.virtual_time_s,
                session.global_version())
    g1, held1, stale1, t1, v1 = run()
    g2, held2, stale2, t2, v2 = run()
    np.testing.assert_array_equal(g1, g2)
    assert (held1, stale1, t1, v1) == (held2, stale2, t2, v2)


def test_zero_delay_event_path_is_bit_identical_to_immediate_pump():
    """Acceptance: with all link models at zero delay/jitter/loss, draining
    a held queue produces bit-identical globals to the auto-pump path."""
    n = 7
    rng = np.random.default_rng(0)
    params = {f"c{i}": {"w": rng.normal(size=(8, 2)).astype(np.float32)}
              for i in range(n)}
    weights = {f"c{i}": float(rng.integers(1, 30)) for i in range(n)}

    def run(held):
        fed = Federation(aggregator_ratio=0.4)
        clients = [fed.client(f"c{i}") for i in range(n)]
        session = fed.create_session("s", "m", rounds=1,
                                     participants=clients)
        train = lambda cid, g, r: (params[cid], int(weights[cid]))
        if held:
            with fed.clock.hold():
                session.run_round_async(train)
                fed.clock.run_until_idle()
        else:
            session.run_round(train)
        return session.global_params()["w"]

    np.testing.assert_array_equal(run(held=False), run(held=True))


# ---------------------------------------------------------------------------
# Async federation under chaos (repro.api.async_fl)
# ---------------------------------------------------------------------------

_TARGETS = {f"c{i}": float(i) for i in range(8)}


def _pull_train(cid, g, r):
    """Contractive dynamics: pull the global toward this client's target —
    the fixed point of the admitted mix, so reconvergence is measurable."""
    base = np.zeros(4, np.float32) if g is None else np.asarray(g["w"])
    tgt = np.full(4, _TARGETS.get(cid, 3.0), np.float32)
    return {"w": (base + np.float32(0.4) * (tgt - base))}, 1


def _async_session(strategy, n=6, versions=12, seed=7, gossip=0.0,
                   **async_kw):
    fed = Federation(latency=dict(delay_s=0.01, jitter_s=0.005, seed=42),
                     aggregator_ratio=0.4)
    sim = StatsSimulator([f"c{i}" for i in range(n + 2)], seed=9)
    clients = [fed.client(f"c{i}", stats=sim.sample(f"c{i}", 0))
               for i in range(n)]
    async_kw.setdefault("buffer_k", 3)
    async_kw.setdefault("staleness_bound", 4)
    async_kw.setdefault("base_period_s", 1.0)
    async_kw.setdefault("period_jitter_s", 0.1)
    async_kw.setdefault("seed", seed)
    session = fed.create_session(
        "s", "m", rounds=versions, participants=clients, strategy=strategy,
        capacity=(n, n + 2),
        async_mode=dict(gossip_period_s=gossip, **async_kw))
    session.start()
    return fed, session


def _async_events(kind, fed, session, n=6):
    if kind == "reorder":
        for i in range(n):                  # reversed arrival order
            fed.transport.set_link(f"c{i}", delay_s=0.01 * (n - i))
        return []
    if kind == "partition_heal":
        return [scenarios.partition(
            [[f"c{i}" for i in range(n // 2)],
             [f"c{i}" for i in range(n // 2, n)]], t0=2.0, t1=6.0)]
    if kind == "churn":
        return [scenarios.churn(fail_at={2: ["c5"]}, join_at={4: ["c6"]},
                                straggle_at={3: {"c1": 0.3}})]
    raise AssertionError(kind)


@pytest.mark.parametrize("strategy", ["fedavg", "trimmed_mean"])
@pytest.mark.parametrize("kind", ["reorder", "partition_heal", "churn"])
def test_async_scenario_matrix_completes_with_finite_globals(kind, strategy):
    versions = 10
    fed, session = _async_session(strategy, versions=versions)
    events = _async_events(kind, fed, session)
    report = scenarios.play_async(
        session, _pull_train, events=events, max_time_s=200.0,
        initial_params={"w": np.zeros(4, np.float32)})
    assert not report.stalled and not report.timed_out
    assert report.final_state == "terminated"
    assert report.updates >= versions
    g = session.global_params()
    assert g is not None and np.isfinite(g["w"]).all()
    assert report.admitted > 0
    if kind == "churn":
        assert "c5" not in session.contributors()
        assert "c6" in session.contributors()


def test_async_gossip_under_partition_reconverges():
    """2-site partition with head gossip: the root's side keeps minting
    real globals, the other side keeps converging on gossiped site models,
    and after heal the federation reconverges to within tolerance of the
    never-partitioned run — deterministically."""
    def run(partitioned):
        fed, session = _async_session("fedavg", versions=25, gossip=1.5,
                                      period_jitter_s=0.0)
        tail = []
        session.on_global_update = \
            lambda p, v: tail.append((v, float(np.mean(p["w"]))))
        # partition along the leaf-cluster boundary: the side without the
        # root is a complete cluster with its own head
        desc = session.tree().describe()
        root = desc["levels"][-1][0]["head"]
        other = next(c for c in desc["levels"][0] if root not in c["members"])
        side_b = list(other["members"])
        side_a = [c for c in session.contributors() if c not in side_b]
        events = [scenarios.partition([side_a, side_b], t0=2.0, t1=8.0)] \
            if partitioned else []
        report = scenarios.play_async(
            session, _pull_train, events=events, max_time_s=300.0,
            initial_params={"w": np.zeros(4, np.float32)})
        return session, report, tail

    s0, r0, tail0 = run(False)
    s1, r1, tail1 = run(True)
    assert r1.final_state == "terminated" and not r1.stalled
    # rounds kept completing through the partition window
    assert r1.partition_held > 0
    assert r1.site_updates > 0          # the root-less side kept updating
    assert r1.gossip_merges + r1.gossip_adopts > 0
    assert r1.rejected_stale > 0        # held traffic was bounded-stale cut
    # reconvergence: the post-heal tail settles near the never-partitioned
    # run's tail (both near the all-target mean)
    tm0 = np.mean([m for _, m in tail0[-6:]])
    tm1 = np.mean([m for _, m in tail1[-6:]])
    assert abs(tm0 - tm1) < 0.5, (tm0, tm1)
    # deterministic: the same seeds replay bit-identically
    s2, r2, tail2 = run(True)
    np.testing.assert_array_equal(s1.global_params()["w"],
                                  s2.global_params()["w"])
    assert r1.timeline == r2.timeline
    assert (r1.rejected_stale, r1.site_updates, r1.gossip_merges) \
        == (r2.rejected_stale, r2.site_updates, r2.gossip_merges)


def test_async_schedule_two_seed_determinism():
    """Same seed -> identical async event schedule (timeline, counters,
    bit-identical global); different seed -> a different schedule that
    still completes."""
    def run(seed):
        fed, session = _async_session("fedavg", versions=8, seed=seed,
                                      period_jitter_s=0.25)
        report = scenarios.play_async(
            session, _pull_train, max_time_s=120.0,
            initial_params={"w": np.zeros(4, np.float32)})
        return np.array(session.global_params()["w"]), report

    g_a, r_a = run(3)
    g_b, r_b = run(3)
    g_c, r_c = run(4)
    np.testing.assert_array_equal(g_a, g_b)
    assert r_a.timeline == r_b.timeline
    assert (r_a.admitted, r_a.rejected_stale, r_a.virtual_time_s) \
        == (r_b.admitted, r_b.rejected_stale, r_b.virtual_time_s)
    assert r_c.final_state == "terminated"
    assert r_a.timeline != r_c.timeline     # jitter reseeded the schedule


# ---------------------------------------------------------------------------
# Cross-broker bridge lag
# ---------------------------------------------------------------------------

def test_bridge_link_model_delays_cross_broker_traffic():
    clock = SimClock()
    b1, b2 = SimBroker("b1"), SimBroker("b2")
    b1.bridge(b2, ["shared/#"], delay_s=0.25, clock=clock)
    local_t, remote_t = [], []
    b1.connect("c1", lambda m: local_t.append(clock.now))
    b1.subscribe("c1", "shared/x")
    b2.connect("c2", lambda m: remote_t.append(clock.now))
    b2.subscribe("c2", "shared/x")
    b1.publish("shared/x", b"p")
    assert local_t == [0.0] and remote_t == []     # in flight cross-broker
    clock.run_until_idle()
    assert remote_t == [pytest.approx(0.25)]
    assert b1.sys_stats()["bridge_forwards"] == 1


def test_bridge_drop_retransmits_qos1_and_loses_qos0():
    """The bridge honors QoS like a link: a drawn drop loses QoS-0 traffic
    but retransmits QoS-1 (arriving at 2x the bridge delay)."""
    clock = SimClock()
    b1, b2 = SimBroker("b1"), SimBroker("b2")
    b1.bridge(b2, ["t/#"], delay_s=0.1, drop_p=1.0, clock=clock)
    got = []
    b2.connect("c2", lambda m: got.append((m.payload, clock.now)))
    b2.subscribe("c2", "t/x", qos=1)
    b1.publish("t/x", b"q0", qos=0)
    b1.publish("t/x", b"q1", qos=1)
    clock.run_until_idle()
    assert got == [(b"q1", pytest.approx(0.2))]    # late, but delivered
    link = b1._bridges[0]
    assert link.dropped == 1 and link.retransmitted == 1


def test_bridged_federation_sees_cross_broker_lag():
    """Two bridged brokers under one clock: a round on broker A completes,
    and broker B's mirror of the global model arrives a bridge-delay later
    on the shared virtual clock."""
    clock = SimClock()
    b1, b2 = SimBroker("b1"), SimBroker("b2")
    b1.bridge(b2, ["sdflmq/session/+/global"], delay_s=0.5, clock=clock)
    fed = Federation(transport=LatencyTransport(b1, clock=clock,
                                                delay_s=0.01))
    local, mirror = [], []
    b1.connect("local_obs", lambda m: local.append(clock.now))
    b1.subscribe("local_obs", "sdflmq/session/+/global")
    b2.connect("observer", lambda m: mirror.append(clock.now))
    b2.subscribe("observer", "sdflmq/session/+/global")
    clients = [fed.client(f"c{i}") for i in range(3)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)
    p = {"w": np.ones(3, np.float32)}
    session.run_round(lambda cid, g, r: (p, 1))
    assert local and mirror                # both regions saw the global...
    assert mirror[0] >= local[0] + 0.5 - 1e-9   # ...B a bridge-delay later
