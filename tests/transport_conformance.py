"""Shared Transport-conformance contract (paper §III-B: what SDFLMQ needs
from MQTT).

One suite, parameterized over every ``repro.api.transport.Transport``
backend — ``SimBroker``, ``LatencyTransport`` (event-driven delivery
queue), and ``PahoTransport`` against the bundled in-process MQTT 3.1.1
mini-broker (both the builtin stdlib client and, when the ``repro[mqtt]``
extra is installed, real paho-mqtt) — so all backends are certified
against one behavioral contract:

  * exact-topic and wildcard (``+``/``#``) delivery, matching the
    ``topic_matches`` oracle,
  * the MQTT-4.7.2-1 rule: ``$``-topics are invisible to wildcard-rooted
    filters but reachable by exact filters,
  * per-sender FIFO ordering (one client's publishes never reorder),
  * one delivery per client even under overlapping filters,
  * retained messages: late-subscriber replay (with the retain bit set),
    last-value-wins overwrite, empty-payload clear,
  * last-will testament: published on ungraceful connection drop, silent
    on graceful disconnect,
  * unsubscribe and reconnect tearing down deliveries.

The module is imported by ``tests/test_transport_conformance.py`` (the sim
backends always run; the MQTT legs skip cleanly when their dependency is
missing) and by the CI ``mqtt`` job, which runs all four legs.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.api.transport import LatencyTransport, SimClock
from repro.core.broker import (Message, SimBroker, frame_part_info,
                               topic_matches)
from repro.core.mqttfc import MQTTFC
from repro.obs import SYS_CORE

BACKENDS = [
    "simbroker",
    "latency",
    pytest.param("mqtt-builtin", marks=pytest.mark.mqtt),
    pytest.param("mqtt-paho", marks=pytest.mark.mqtt),
]


class Backend:
    """One Transport implementation under test, plus the knob that makes
    its delivery model uniform: ``settle()`` blocks until every in-flight
    message has been dispatched to its subscriber callbacks."""

    def __init__(self, label: str):
        self.label = label
        self._broker = None
        if label == "simbroker":
            self.transport = SimBroker()
            self._settle = lambda: None
        elif label == "latency":
            clock = SimClock()
            self.transport = LatencyTransport(
                SimBroker(), delay_s=0.002, jitter_s=0.001, seed=7,
                clock=clock)
            self._settle = clock.run_until_idle
        elif label in ("mqtt-builtin", "mqtt-paho"):
            from repro.api.mini_broker import MiniBroker
            from repro.api.mqtt_transport import PahoTransport, \
                paho_available
            if label == "mqtt-paho" and not paho_available():
                pytest.skip("optional dependency paho-mqtt not installed "
                            "(pip install 'repro-sdflmq[mqtt]')")
            self._broker = MiniBroker(port=0).start()
            self.transport = PahoTransport(
                port=self._broker.port,
                backend=label.removeprefix("mqtt-"))
            self._settle = self.transport.settle
        else:                                    # pragma: no cover
            raise ValueError(label)

    def settle(self) -> None:
        self._settle()

    def teardown(self) -> None:
        if self._broker is not None:
            self.transport.close()
            self._broker.stop()

    # -- helpers -----------------------------------------------------------
    def collector(self, client_id: str, will: Message = None):
        """Connect ``client_id`` with a recording callback; returns the
        list of (topic, payload, qos, retain) tuples it receives."""
        got: list[tuple] = []
        self.transport.connect(
            client_id,
            lambda m: got.append((m.topic, bytes(m.payload), m.qos,
                                  bool(m.retain))),
            will=will)
        return got


@pytest.fixture(params=BACKENDS)
def backend(request):
    b = Backend(request.param)
    yield b
    b.teardown()


def topics_of(got) -> list:
    return [t for t, *_ in got]


def payloads_of(got) -> list:
    return [p for _, p, *_ in got]


# ---------------------------------------------------------------------------
# basic delivery + ordering
# ---------------------------------------------------------------------------

def test_exact_topic_roundtrip(backend):
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "sdflmq/room/1", qos=1)
    backend.transport.publish("sdflmq/room/1", b"payload-1", qos=1,
                              sender="pub")
    backend.settle()
    assert got == [("sdflmq/room/1", b"payload-1", 1, False)]


def test_no_delivery_without_matching_subscription(backend):
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "sdflmq/a", qos=0)
    backend.transport.publish("sdflmq/b", b"x", sender="pub")
    backend.settle()
    assert got == []


def test_per_sender_fifo_ordering(backend):
    """One client's publishes ride one ordered connection: they never
    overtake each other, whatever the link model does."""
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "sdflmq/seq", qos=1)
    for i in range(40):
        backend.transport.publish("sdflmq/seq", f"m{i:03d}".encode(),
                                  qos=1, sender="pub")
    backend.settle()
    assert payloads_of(got) == [f"m{i:03d}".encode() for i in range(40)]


def test_self_delivery(backend):
    """MQTT 3.1.1 has no noLocal: a publisher subscribed to the topic
    receives its own message."""
    got = backend.collector("node")
    backend.transport.subscribe("node", "sdflmq/self", qos=1)
    backend.transport.publish("sdflmq/self", b"me", qos=1, sender="node")
    backend.settle()
    assert payloads_of(got) == [b"me"]


def test_fanout_to_all_matching_subscribers(backend):
    got_a = backend.collector("a")
    got_b = backend.collector("b")
    got_c = backend.collector("c")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("a", "sdflmq/fan", qos=1)
    backend.transport.subscribe("b", "sdflmq/fan", qos=1)
    backend.transport.subscribe("c", "sdflmq/other", qos=1)
    backend.transport.publish("sdflmq/fan", b"x", qos=1, sender="pub")
    backend.settle()
    assert payloads_of(got_a) == [b"x"]
    assert payloads_of(got_b) == [b"x"]
    assert got_c == []


def test_overlapping_filters_deliver_once(backend):
    """A client holding several filters matching one topic gets exactly
    one copy (first matching filter wins, as in SimBroker)."""
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "sdflmq/ov/+", qos=1)
    backend.transport.subscribe("sub", "sdflmq/ov/x", qos=1)
    backend.transport.subscribe("sub", "sdflmq/#", qos=1)
    backend.transport.publish("sdflmq/ov/x", b"once", qos=1, sender="pub")
    backend.settle()
    assert payloads_of(got) == [b"once"]


# ---------------------------------------------------------------------------
# wildcard / $-topic rules
# ---------------------------------------------------------------------------

WILDCARD_CASES = [
    ("sdflmq/+/agg", "sdflmq/c1/agg", True),
    ("sdflmq/+/agg", "sdflmq/c1/status", False),
    ("sdflmq/+/agg", "sdflmq/a/b/agg", False),
    ("sdflmq/#", "sdflmq/session/s1/global", True),
    ("sdflmq/#", "sdflmq", True),              # '#' covers the parent level
    ("sdflmq/#", "other/x", False),
    ("+/coord/create", "sdflmq/coord/create", True),
    ("sdflmq/session/+/cluster/+/agg",
     "sdflmq/session/s1/cluster/c0/agg", True),
]


@pytest.mark.parametrize("filt,topic,expect", WILDCARD_CASES)
def test_wildcard_filter_semantics(backend, filt, topic, expect):
    assert topic_matches(filt, topic) == expect     # oracle sanity
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", filt, qos=1)
    backend.transport.publish(topic, b"w", qos=1, sender="pub")
    backend.settle()
    assert (payloads_of(got) == [b"w"]) == expect


def test_dollar_topics_invisible_to_wildcards(backend):
    """[MQTT-4.7.2-1]: filters starting with a wildcard never match topics
    whose first level starts with '$'."""
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "#", qos=1)
    backend.transport.subscribe("sub", "+/load", qos=1)
    backend.transport.publish("$SYS/load", b"hidden", qos=1, sender="pub")
    backend.transport.publish("plain/load", b"seen", qos=1, sender="pub")
    backend.settle()
    assert payloads_of(got) == [b"seen"]


def test_dollar_topics_reachable_by_exact_filter(backend):
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "$SYS/broker/load", qos=1)
    backend.transport.publish("$SYS/broker/load", b"42", qos=1, sender="pub")
    backend.settle()
    assert payloads_of(got) == [b"42"]


# ---------------------------------------------------------------------------
# retained messages
# ---------------------------------------------------------------------------

def test_retained_replay_to_late_subscriber(backend):
    backend.transport.connect("pub", lambda m: None)
    backend.transport.publish("sdflmq/topo", b"v1", qos=1, retain=True,
                              sender="pub")
    backend.settle()
    got = backend.collector("late")
    backend.transport.subscribe("late", "sdflmq/topo", qos=1)
    backend.settle()
    assert [(t, p, r) for t, p, _q, r in got] == \
        [("sdflmq/topo", b"v1", True)]


def test_retained_last_value_wins(backend):
    backend.transport.connect("pub", lambda m: None)
    backend.transport.publish("sdflmq/topo", b"v1", qos=1, retain=True,
                              sender="pub")
    backend.transport.publish("sdflmq/topo", b"v2", qos=1, retain=True,
                              sender="pub")
    backend.settle()
    got = backend.collector("late")
    backend.transport.subscribe("late", "sdflmq/#", qos=1)
    backend.settle()
    assert payloads_of(got) == [b"v2"]


def test_retained_not_replayed_for_earlier_subscriptions(backend):
    """[MQTT-3.3.1-6]: retained replay covers the filters of the NEW
    subscribe only — a later subscribe to an unrelated filter must not
    re-deliver retained state already replayed to an older filter."""
    backend.transport.connect("pub", lambda m: None)
    backend.transport.publish("sdflmq/topo", b"v1", qos=1, retain=True,
                              sender="pub")
    backend.settle()
    got = backend.collector("sub")
    backend.transport.subscribe("sub", "sdflmq/topo", qos=1)
    backend.settle()
    backend.transport.subscribe("sub", "sdflmq/unrelated", qos=1)
    backend.settle()
    assert payloads_of(got) == [b"v1"]      # exactly once, not re-replayed


def test_retained_multipart_replay(backend):
    """A retained multi-frame MQTTFC call replays EVERY frame to a late
    subscriber (the broker keys the retained sequence by (sender, call_id)),
    not just the last frame — which would make large retained globals
    unreassemblable after a reconnect."""
    pub = MQTTFC(backend.transport, "rpub", max_batch_bytes=256,
                 compress_threshold=1 << 30)
    arr = np.arange(512, dtype=np.float32)          # ~2 KiB -> many frames
    pub.call("sdflmq/session/s/global", arr, retain=True)
    backend.settle()
    assert pub.wire_stats()["parts_sent"] > 1       # genuinely multi-part
    got = []
    late = MQTTFC(backend.transport, "rlate", compress_threshold=1 << 30)
    late.subscribe_raw("sdflmq/session/s/global",
                       lambda t, p: got.append(np.array(p["a"][0])))
    backend.settle()
    assert len(got) == 1                            # reassembled exactly once
    np.testing.assert_array_equal(got[0], arr)

    # last-value-wins still holds call-to-call: a later retained call
    # (here a short single-frame one) replaces the whole sequence
    small = np.ones(4, dtype=np.float32)
    pub.call("sdflmq/session/s/global", small, retain=True)
    backend.settle()
    got2 = []
    late2 = MQTTFC(backend.transport, "rlate2", compress_threshold=1 << 30)
    late2.subscribe_raw("sdflmq/session/s/global",
                        lambda t, p: got2.append(np.array(p["a"][0])))
    backend.settle()
    assert len(got2) == 1
    np.testing.assert_array_equal(got2[0], small)


def test_retained_quantized_global_replay(backend):
    """The int8 downlink codec composes with retained replay: a quantized
    multi-frame global (the exact message shape the root's ``_flush``
    publishes with ``downlink_codec="int8"``) survives late-subscriber
    replay on every backend, and the reassembled payload dequantizes to
    the published global within the int8 error bound."""
    from repro.core.client import _as_params, _bundle_or_params
    from repro.dist.compression import quantize_int8

    rng = np.random.default_rng(3)
    glob = {"w/kernel": rng.standard_normal((16, 32)).astype(np.float32),
            "b/bias": rng.standard_normal((64,)).astype(np.float32)}
    qd, sd = {}, {}
    for k, v in glob.items():
        q, s = quantize_int8(v, xp=np)
        qd[k], sd[k] = q, np.asarray(s, np.float32)
    msg = {"params": qd, "scales": sd, "quantized": True,
           "version": 3, "round": 3}
    pub = MQTTFC(backend.transport, "qpub", max_batch_bytes=256,
                 compress_threshold=1 << 30)
    pub.call("sdflmq/session/q/global", msg, retain=True, quantized=True)
    backend.settle()
    assert pub.wire_stats()["parts_sent"] > 1       # genuinely multi-part

    got = []
    late = MQTTFC(backend.transport, "qlate", compress_threshold=1 << 30)
    late.subscribe_raw("sdflmq/session/q/global",
                       lambda t, p: got.append(p["a"][0]))
    backend.settle()
    assert len(got) == 1                            # reassembled exactly once
    body = got[0]
    assert body.get("quantized") and body.get("version") == 3
    params = _as_params(_bundle_or_params(body))
    for k, v in glob.items():
        assert params[k].shape == v.shape
        assert params[k].dtype == np.float32
        bound = float(np.abs(v).max()) / 127.0 + 1e-6
        np.testing.assert_allclose(params[k], v, atol=bound)


def test_frame_part_info_sniffer_tolerates_opaque_payloads():
    """The retained-store sniffer must never misparse application bytes."""
    import msgpack
    assert frame_part_info(b"") is None
    assert frame_part_info(b"v1") is None
    assert frame_part_info(b"\x00\x00\x00\x04abcd") is None
    assert frame_part_info(b"\xff\xff\xff\xff" + b"x" * 16) is None
    # a msgpack body that is not a frame header tuple
    junk = msgpack.packb({"a": 1})
    assert frame_part_info(len(junk).to_bytes(4, "big") + junk) is None
    # a genuine frame header parses
    hdr = msgpack.packb(("me", 7, 1, 4, 0, None, 1024, 256))
    payload = len(hdr).to_bytes(4, "big") + hdr + b"chunk"
    assert frame_part_info(payload) == ("me", 7, 1, 4)


def test_retained_cleared_by_empty_payload(backend):
    backend.transport.connect("pub", lambda m: None)
    backend.transport.publish("sdflmq/topo", b"v1", qos=1, retain=True,
                              sender="pub")
    backend.transport.publish("sdflmq/topo", b"", qos=1, retain=True,
                              sender="pub")
    backend.settle()
    got = backend.collector("late")
    backend.transport.subscribe("late", "sdflmq/topo", qos=1)
    backend.settle()
    assert got == []


# ---------------------------------------------------------------------------
# last-will testament
# ---------------------------------------------------------------------------

def test_lwt_fires_on_ungraceful_drop(backend):
    got = backend.collector("watcher")
    backend.transport.subscribe("watcher", "sdflmq/will/+", qos=1)
    backend.collector("mortal",
                      will=Message("sdflmq/will/mortal", b"gone", qos=1))
    backend.settle()
    backend.transport.disconnect("mortal", graceful=False)
    backend.settle()
    assert [(t, p) for t, p, *_ in got] == [("sdflmq/will/mortal", b"gone")]


def test_lwt_silent_on_graceful_disconnect(backend):
    got = backend.collector("watcher")
    backend.transport.subscribe("watcher", "sdflmq/will/+", qos=1)
    backend.collector("mortal",
                      will=Message("sdflmq/will/mortal", b"gone", qos=1))
    backend.settle()
    backend.transport.disconnect("mortal", graceful=True)
    backend.settle()
    assert got == []


# ---------------------------------------------------------------------------
# subscription lifecycle
# ---------------------------------------------------------------------------

def test_unsubscribe_stops_delivery(backend):
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "sdflmq/u", qos=1)
    backend.transport.publish("sdflmq/u", b"one", qos=1, sender="pub")
    backend.settle()
    backend.transport.unsubscribe("sub", "sdflmq/u")
    backend.transport.publish("sdflmq/u", b"two", qos=1, sender="pub")
    backend.settle()
    assert payloads_of(got) == [b"one"]


def test_reconnect_drops_old_subscriptions(backend):
    got_old = backend.collector("node")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("node", "sdflmq/r", qos=1)
    backend.settle()
    got_new = backend.collector("node")     # clean-session reconnect
    backend.transport.publish("sdflmq/r", b"after", qos=1, sender="pub")
    backend.settle()
    assert got_old == [] and got_new == []


def test_qos0_delivery(backend):
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "sdflmq/q0", qos=0)
    backend.transport.publish("sdflmq/q0", b"fire-and-forget", qos=0,
                              sender="pub")
    backend.settle()
    assert payloads_of(got) == [b"fire-and-forget"]


def test_sys_stats_exposed(backend):
    """Every backend reports broker-side counters (shape is free, the
    surface must exist and survive traffic)."""
    got = backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "sdflmq/s", qos=1)
    backend.transport.publish("sdflmq/s", b"x", qos=1, sender="pub")
    backend.settle()
    stats = backend.transport.sys_stats()
    assert isinstance(stats, dict) and stats
    assert payloads_of(got) == [b"x"]


# ---------------------------------------------------------------------------
# stats parity (the surface the metrics layer scrapes)
# ---------------------------------------------------------------------------

def test_sys_stats_core_schema(backend):
    """Every backend exposes the canonical SYS_CORE counter names with
    consistent values after deterministic traffic, so ``repro.obs`` can
    scrape any of them interchangeably."""
    backend.collector("sub")
    backend.transport.connect("pub", lambda m: None)
    backend.transport.subscribe("sub", "sdflmq/core", qos=1)
    for _ in range(3):
        backend.transport.publish("sdflmq/core", b"x" * 10, qos=1,
                                  sender="pub")
    backend.settle()
    stats = backend.transport.sys_stats()
    for k in SYS_CORE:
        assert k in stats, k
        assert isinstance(stats[k], int) and stats[k] >= 0, k
    # 3 publishes in, 3 deliveries out — whichever side of the wire the
    # backend counts from, both directions saw at least that much
    assert stats["messages_received"] >= 3
    assert stats["messages_sent"] >= 3
    assert stats["bytes_received"] >= 30
    assert stats["bytes_sent"] >= 30


def test_wire_stats_schema_parity(backend):
    """MQTTFC endpoints report the same wire_stats key set on every
    backend, and sender/receiver counters agree: what one endpoint sent is
    exactly what the other received."""
    tx = MQTTFC(backend.transport, "wtx", compress_threshold=1 << 30)
    rx = MQTTFC(backend.transport, "wrx", compress_threshold=1 << 30)
    got = []
    rx.subscribe_raw("sdflmq/wire/x", lambda t, p: got.append(p["a"][0]))
    arr = np.arange(64, dtype=np.float32)
    tx.call("sdflmq/wire/x", arr)
    tx.call("sdflmq/wire/x", arr)
    backend.settle()
    assert len(got) == 2
    s, r = tx.wire_stats(), rx.wire_stats()
    assert set(s) == set(r)                         # one schema everywhere
    assert s["calls_sent"] == 2
    assert r["calls_received"] == s["calls_sent"]
    assert r["parts_received"] == s["parts_sent"]
    assert r["bytes_received"] == s["bytes_sent"]
    assert s["arena_reuse_hits"] >= 1               # steady-state encode


# ---------------------------------------------------------------------------
# at-least-once: duplicate redelivery, session resumption, shared subs
# ---------------------------------------------------------------------------

def test_duplicate_qos1_redelivery_is_deduped(backend):
    """QoS 1 is at-least-once: a link (or a reconnecting client) may
    redeliver any PUBLISH verbatim.  The MQTTFC layer must swallow the
    replay — the application callback fires once, and the endpoint counts
    the drop."""
    tx = MQTTFC(backend.transport, "dtx", compress_threshold=1 << 30)
    rx = MQTTFC(backend.transport, "drx", compress_threshold=1 << 30)
    got = []
    rx.subscribe_raw("sdflmq/dup/x", lambda t, p: got.append(p["a"][0]))

    sent: list[tuple] = []
    real_publish = backend.transport.publish

    def tap(topic, payload, qos=0, retain=False, sender=""):
        sent.append((topic, bytes(payload), qos, retain, sender))
        return real_publish(topic, payload, qos=qos, retain=retain,
                            sender=sender)

    backend.transport.publish = tap
    try:
        tx.call("sdflmq/dup/x", np.arange(64, dtype=np.float32))
        backend.settle()
    finally:
        backend.transport.publish = real_publish
    assert len(got) == 1
    # the wire redelivers every captured QoS-1 frame, byte-for-byte
    replayed = 0
    for topic, payload, qos, retain, sender in sent:
        if qos >= 1 and not retain:
            real_publish(topic, payload, qos=qos, retain=retain,
                         sender=sender)
            replayed += 1
    assert replayed >= 1
    backend.settle()
    st = rx.wire_stats()
    assert len(got) == 1                        # callback fired exactly once
    assert st["calls_received"] == 1
    assert st["duplicate_drops"] >= replayed


def test_persistent_session_resumes_offline_qos1(backend):
    """clean_session=False: the subscription survives a disconnect, QoS-1
    traffic routed while offline is queued, and a resume WITHOUT
    re-subscribing delivers it."""
    got: list = []
    backend.transport.connect(
        "dur", lambda m: got.append(bytes(m.payload)), clean_session=False)
    backend.transport.subscribe("dur", "sdflmq/resume/+", qos=1)
    backend.transport.connect("pub", lambda m: None)
    backend.transport.publish("sdflmq/resume/a", b"live", qos=1,
                              sender="pub")
    backend.settle()
    backend.transport.disconnect("dur", graceful=True)
    backend.settle()
    backend.transport.publish("sdflmq/resume/a", b"offline", qos=1,
                              sender="pub")
    backend.settle()
    assert got == [b"live"]                     # nothing while offline
    backend.transport.connect(
        "dur", lambda m: got.append(bytes(m.payload)), clean_session=False)
    backend.settle()
    assert got == [b"live", b"offline"]


def test_clean_session_discards_offline_traffic(backend):
    """The default clean session keeps the old contract: a reconnect comes
    back empty — no stored subscription, no queued traffic."""
    got: list = []
    backend.transport.connect("cln", lambda m: got.append(bytes(m.payload)))
    backend.transport.subscribe("cln", "sdflmq/cln/+", qos=1)
    backend.transport.connect("pub", lambda m: None)
    backend.transport.disconnect("cln", graceful=True)
    backend.settle()
    backend.transport.publish("sdflmq/cln/a", b"lost", qos=1, sender="pub")
    backend.settle()
    backend.transport.connect("cln", lambda m: got.append(bytes(m.payload)))
    backend.settle()
    assert got == []


def test_shared_subscription_round_robins_group(backend):
    """$share/<group>/<filter>: each message goes to exactly ONE member of
    the group, and a healthy group shares the load evenly."""
    members: dict[str, list] = {f"w{i}": [] for i in range(3)}
    for w, box in members.items():
        backend.transport.connect(
            w, lambda m, _b=box: _b.append(bytes(m.payload)))
        backend.transport.subscribe(w, "$share/pool/sdflmq/jobs/+", qos=1)
    backend.transport.connect("pub", lambda m: None)
    expect = [f"t{i}".encode() for i in range(6)]
    for p in expect:
        backend.transport.publish("sdflmq/jobs/j", p, qos=1, sender="pub")
    backend.settle()
    assert sorted(p for box in members.values() for p in box) == expect
    assert sorted(len(box) for box in members.values()) == [2, 2, 2]
