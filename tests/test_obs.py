"""Telemetry subsystem (``repro.obs``) tests: the metrics registry and its
Prometheus text exposition, the bounded-ring round tracer, the stdlib HTTP
exporter, the reusable encode arena, and — the acceptance criteria — a fully
instrumented federation: ``Federation(metrics=None)`` stays bit-identical to
the uninstrumented path, while ``metrics=True`` exposes 20+ series spanning
broker/wire/accumulator/async/coordinator and renders partition → heal →
reconvergence timelines in virtual-time order."""
import doctest
import json
import urllib.request

import numpy as np
import pytest

from repro.api import Federation, scenarios
from repro.core import wire
from repro.core.broker import SimBroker
from repro.core.mqttfc import MQTTFC
from repro.obs import (MetricsRegistry, Telemetry, Tracer, render_prom,
                       serve_metrics, timeline_json, write_timeline_json)
from repro.obs.registry import DEFAULT_BUCKETS


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_inc_and_value(self):
        reg = MetricsRegistry()
        c = reg.counter("sdflmq_x_total", "x", labels=("kind",))
        c.labels(kind="a").inc()
        c.labels(kind="a").inc(4)
        c.labels(kind="b").inc(2)
        assert c.labels(kind="a").value == 5.0
        assert c.labels(kind="b").value == 2.0

    def test_counter_rejects_negative(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.counter("sdflmq_neg_total").inc(-1)

    def test_gauge_set_inc_dec(self):
        reg = MetricsRegistry()
        g = reg.gauge("sdflmq_depth")
        g.set(7)
        g.inc(3)
        g.dec(1)
        assert g.value == 9.0

    def test_histogram_buckets_are_cumulative_in_render(self):
        reg = MetricsRegistry()
        h = reg.histogram("sdflmq_lat", buckets=(0.1, 1.0, 5.0))
        for v in (0.05, 0.5, 0.5, 3.0, 99.0):
            h.observe(v)
        text = reg.render_prom()
        assert 'sdflmq_lat_bucket{le="0.1"} 1' in text
        assert 'sdflmq_lat_bucket{le="1.0"} 3' in text
        assert 'sdflmq_lat_bucket{le="5.0"} 4' in text
        assert 'sdflmq_lat_bucket{le="+Inf"} 5' in text
        assert "sdflmq_lat_count 5" in text
        assert h.value["count"] == 5

    def test_histogram_value_on_bucket_boundary_counts_le(self):
        reg = MetricsRegistry()
        h = reg.histogram("sdflmq_edge", buckets=(1.0, 2.0))
        h.observe(1.0)                      # le="1.0" is inclusive
        assert 'sdflmq_edge_bucket{le="1.0"} 1' in reg.render_prom()

    def test_same_name_returns_same_family(self):
        reg = MetricsRegistry()
        a = reg.counter("sdflmq_same_total", labels=("k",))
        b = reg.counter("sdflmq_same_total", labels=("k",))
        assert a is b

    def test_kind_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.counter("sdflmq_clash")
        with pytest.raises(ValueError, match="already registered as counter"):
            reg.gauge("sdflmq_clash")

    def test_label_mismatch_raises(self):
        reg = MetricsRegistry()
        c = reg.counter("sdflmq_lbl_total", labels=("a",))
        with pytest.raises(ValueError):
            reg.counter("sdflmq_lbl_total", labels=("b",))
        with pytest.raises(ValueError):
            c.labels(wrong="x")

    def test_labeled_family_requires_labels_call(self):
        reg = MetricsRegistry()
        c = reg.counter("sdflmq_need_total", labels=("k",))
        with pytest.raises(ValueError, match="call .labels"):
            c.inc()

    def test_label_values_are_escaped(self):
        reg = MetricsRegistry()
        g = reg.gauge("sdflmq_esc", labels=("path",))
        g.labels(path='a"b\\c\nd').set(1)
        text = reg.render_prom()
        assert 'path="a\\"b\\\\c\\nd"' in text

    def test_collector_runs_on_every_exposition(self):
        reg = MetricsRegistry()
        g = reg.gauge("sdflmq_mirrored")
        source = {"n": 0}
        reg.register_collector(lambda: g.set(source["n"]))
        source["n"] = 41
        assert "sdflmq_mirrored 41" in reg.render_prom()
        source["n"] = 42
        assert reg.snapshot()["sdflmq_mirrored"]["samples"][""] == 42.0

    def test_series_count_counts_histogram_lines(self):
        reg = MetricsRegistry()
        reg.counter("sdflmq_a_total").inc()
        reg.histogram("sdflmq_h", buckets=(1.0, 2.0)).observe(1.5)
        # 1 counter line + (2 buckets + +Inf + _sum + _count)
        assert reg.series_count() == 1 + 5
        rendered = [l for l in reg.render_prom().splitlines()
                    if l and not l.startswith("#")]
        assert len(rendered) == reg.series_count()

    def test_snapshot_is_json_safe(self):
        reg = MetricsRegistry()
        reg.counter("sdflmq_j_total", labels=("k",)).labels(k="x").inc()
        reg.histogram("sdflmq_jh").observe(0.2)
        json.dumps(reg.snapshot())          # must not raise

    def test_default_buckets_are_sorted(self):
        assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)

    def test_module_doctests_pass(self):
        """Satellite: the documented MetricsRegistry tour is executable."""
        import repro.obs.registry as mod
        result = doctest.testmod(mod)
        assert result.attempted > 0
        assert result.failed == 0


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self):
        self.now = 0.0


class TestTracer:
    def test_virtual_clock_timestamps(self):
        clock = _FakeClock()
        tr = Tracer(clock=clock)
        tr.emit("round_start", session="s", round=0)
        clock.now = 2.5
        tr.emit("round_complete", session="s", round=0)
        ts = [e["t"] for e in tr.events()]
        assert ts == [0.0, 2.5]

    def test_ring_is_bounded_and_counts_drops(self):
        tr = Tracer(maxlen=8)
        for i in range(20):
            tr.emit("tick", i=i)
        assert len(tr.events()) == 8
        assert tr.emitted == 20
        assert tr.dropped == 12
        assert [e["i"] for e in tr.events()] == list(range(12, 20))

    def test_kinds_and_filtered_events(self):
        tr = Tracer(clock=_FakeClock())
        tr.emit("publish", topic="t")
        tr.emit("publish", topic="u")
        tr.emit("mint", version=1)
        assert tr.kinds() == {"publish": 2, "mint": 1}
        assert [e["topic"] for e in tr.events("publish")] == ["t", "u"]

    def test_timeline_excludes_noisy_kinds_by_default(self):
        clock = _FakeClock()
        tr = Tracer(clock=clock)
        tr.emit("publish", topic="t")
        clock.now = 1.0
        tr.emit("partition", groups=2)
        clock.now = 3.0
        tr.emit("heal", released=5)
        tl = tr.timeline()
        assert tl == [(1.0, "partition groups=2"), (3.0, "heal released=5")]
        only_pub = tr.timeline(include=("publish",))
        assert only_pub == [(0.0, "publish topic=t")]

    def test_timeline_is_sorted_by_timestamp(self):
        clock = _FakeClock()
        tr = Tracer(clock=clock)
        clock.now = 5.0
        tr.emit("late")
        clock.now = 1.0                     # out-of-order emission
        tr.emit("early")
        assert [lbl for _, lbl in tr.timeline()] == ["early", "late"]

    def test_to_json_shape(self):
        tr = Tracer(clock=_FakeClock(), maxlen=4)
        tr.emit("mint", version=1)
        doc = json.loads(tr.to_json())
        assert doc["clock"] == "virtual"
        assert doc["emitted"] == 1 and doc["dropped"] == 0
        assert doc["events"][0]["kind"] == "mint"
        assert json.loads(Tracer().to_json())["clock"] == "wall"

    def test_clear(self):
        tr = Tracer()
        tr.emit("x")
        tr.clear()
        assert tr.events() == [] and tr.emitted == 1


# ---------------------------------------------------------------------------
# Exporters: /metrics endpoint + timeline files
# ---------------------------------------------------------------------------

def _get(url):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.status, dict(resp.headers), resp.read().decode("utf-8")


class TestExporters:
    def test_http_metrics_endpoint(self):
        reg = MetricsRegistry()
        reg.counter("sdflmq_http_total").inc(3)
        tr = Tracer(clock=_FakeClock())
        tr.emit("mint", version=2)
        srv = serve_metrics(reg, tracer=tr)
        try:
            status, headers, body = _get(srv.url + "/metrics")
            assert status == 200
            assert headers["Content-Type"].startswith("text/plain")
            assert "sdflmq_http_total 3" in body
            assert body == render_prom(reg)
            status, _, body = _get(srv.url + "/timeline.json")
            assert status == 200
            assert json.loads(body)["events"][0]["version"] == 2
            status, _, body = _get(srv.url + "/")
            assert status == 200 and "/metrics" in body
        finally:
            srv.stop()

    def test_http_404s(self):
        srv = serve_metrics(MetricsRegistry())    # no tracer attached
        try:
            for path in ("/timeline.json", "/nope"):
                with pytest.raises(urllib.error.HTTPError) as ei:
                    _get(srv.url + path)
                assert ei.value.code == 404
        finally:
            srv.stop()

    def test_write_timeline_json(self, tmp_path):
        tr = Tracer(clock=_FakeClock())
        tr.emit("round_start", session="s", round=0)
        path = write_timeline_json(tr, str(tmp_path / "tl.json"))
        doc = json.loads(open(path).read())
        assert doc["events"][0]["kind"] == "round_start"
        assert timeline_json(tr) == tr.to_json(indent=1)


# ---------------------------------------------------------------------------
# FrameArena (satellite: reusable encode buffer)
# ---------------------------------------------------------------------------

def _call_payload(n=12):
    return {"a": [np.arange(n, dtype=np.float32)], "k": {}, "s": "me"}


class TestFrameArena:
    def test_take_grow_release_reuse(self):
        a = wire.FrameArena()
        mv = a.take(64)
        assert len(mv) == 64 and a.grows == 1
        a.release()
        a.take(32)                          # fits: reuse, no realloc
        assert a.reuse_hits == 1 and a.grows == 1
        a.release()
        a.take(128)                         # exceeds capacity: grow
        assert a.grows == 2 and len(a) == 128

    def test_busy_checkout_hands_out_fresh_buffer(self):
        a = wire.FrameArena()
        mv = a.take(16)
        mv[:] = b"\x00" * 16
        mv2 = a.take(16)                    # still checked out: fresh alloc
        assert a.busy_allocs == 1
        mv2[:] = b"\x01" * 16
        assert bytes(mv) == b"\x00" * 16    # the arena buffer is untouched

    def test_encode_body_with_arena_matches_plain_encode(self):
        obj = _call_payload()
        plain = bytes(wire.encode_body(obj))
        a = wire.FrameArena()
        assert bytes(wire.encode_body(obj, arena=a)) == plain
        a.release()
        # steady state: the reused buffer re-encodes without stale leakage
        assert bytes(wire.encode_body(obj, arena=a)) == plain
        assert a.reuse_hits == 1
        a.release()
        np.testing.assert_array_equal(
            wire.decode_body(wire.encode_body(obj, arena=a))["a"][0],
            obj["a"][0])

    def test_release_is_ownership_checked(self):
        a = wire.FrameArena()
        owned = a.take(8)
        stray = a.take(8)                   # busy fallback, off-arena
        a.release(stray)                    # no-op: not the arena buffer
        a.take(8)
        assert a.busy_allocs == 2           # checkout still held
        a.release(owned)
        a.take(8)
        assert a.reuse_hits == 1            # genuinely released
        a.release()                         # bare release: unconditional
        a.take(8)
        assert a.reuse_hits == 2

    def test_arena_released_when_compression_wins(self):
        broker = SimBroker()
        tx = MQTTFC(broker, "ctx", compress_threshold=64)
        rx = MQTTFC(broker, "crx")
        got = []
        rx.subscribe_raw("t/c", lambda t, p: got.append(np.array(p["a"][0])))
        arr = np.zeros(4096, dtype=np.float32)      # highly compressible
        tx.call("t/c", arr)
        tx.call("t/c", arr)
        st = tx.wire_stats()
        assert st["compress_wins"] >= 1
        assert st["arena_busy_allocs"] == 0         # checkout was released
        assert st["arena_reuse_hits"] >= 1
        assert len(got) == 2
        np.testing.assert_array_equal(got[-1], arr)

    def test_mqttfc_steady_state_reuses_arena(self):
        broker = SimBroker()
        tx = MQTTFC(broker, "tx")
        rx = MQTTFC(broker, "rx")
        got = []
        rx.subscribe_raw("t/x", lambda t, p: got.append(np.array(p["a"][0])))
        arr = np.arange(256, dtype=np.float32)
        tx.call("t/x", arr)
        tx.call("t/x", arr)
        assert len(got) == 2
        np.testing.assert_array_equal(got[0], arr)
        np.testing.assert_array_equal(got[1], arr)
        st = tx.wire_stats()
        assert st["arena_grows"] >= 1
        assert st["arena_reuse_hits"] >= 1   # second call reused the buffer
        assert st["arena_busy_allocs"] == 0


# ---------------------------------------------------------------------------
# Instrumented federation (the tentpole acceptance criteria)
# ---------------------------------------------------------------------------

def _run_session(metrics, rounds=2, n=4):
    fed = Federation(metrics=metrics)
    clients = [fed.client(f"c{i}") for i in range(n)]
    session = fed.create_session("s", "m", rounds=rounds,
                                 participants=clients)
    params = {f"c{i}": {"w": np.full((4, 2), float(i) + 0.25, np.float32)}
              for i in range(n)}
    session.run(lambda cid, g, r: (params[cid], 1 + int(cid[1:])),
                initial_params={"w": np.zeros((4, 2), np.float32)})
    return fed, session


def test_metrics_default_off_and_bit_identical():
    fed_off, s_off = _run_session(metrics=None)
    assert fed_off.obs is None
    assert fed_off.metrics is None and fed_off.tracer is None
    fed_on, s_on = _run_session(metrics=True)
    assert fed_on.metrics is not None
    np.testing.assert_array_equal(s_off.global_params()["w"],
                                  s_on.global_params()["w"])
    assert s_off.global_version() == s_on.global_version()


def test_instrumented_run_exposes_all_subsystems():
    fed, session = _run_session(metrics=True)
    text = fed.metrics.render_prom()
    series = [l for l in text.splitlines() if l and not l.startswith("#")]
    assert len(series) >= 20                 # acceptance: 20+ distinct series
    names = {l.split("{", 1)[0].split(" ", 1)[0] for l in series}
    for prefix in ("sdflmq_broker_", "sdflmq_wire_", "sdflmq_acc_",
                   "sdflmq_coordinator_", "sdflmq_trace_", "sdflmq_round_"):
        assert any(n.startswith(prefix) for n in names), prefix
    # pulled gauges mirror the source-of-truth counters exactly
    snap = fed.metrics.snapshot()
    assert snap["sdflmq_broker_messages_sent"]["samples"][""] == \
        fed.transport.sys_stats()["messages_sent"]
    c0 = 'client="c0"'
    assert snap["sdflmq_wire_calls_sent"]["samples"][c0] == \
        fed.clients["c0"].fc.wire_stats()["calls_sent"]


def test_trace_covers_round_lifecycle():
    fed, session = _run_session(metrics=True)
    kinds = fed.tracer.kinds()
    for kind in ("round_start", "train", "contribute", "flush", "mint",
                 "round_complete", "session_end", "publish", "deliver"):
        assert kinds.get(kind, 0) > 0, kind
    # per-round latency histograms were fed by the coordinator
    snap = fed.metrics.snapshot()
    virt = snap["sdflmq_round_virtual_seconds"]["samples"]['session="s"']
    assert virt["count"] == 2                # one observation per round
    # the trace counter agrees with the ring
    assert sum(kinds.values()) == fed.tracer.emitted


def test_metrics_accepts_registry_and_telemetry_instances():
    reg = MetricsRegistry()
    fed, _ = _run_session(metrics=reg)
    assert fed.metrics is reg
    tel = Telemetry()
    fed2 = Federation(metrics=tel)
    assert fed2.obs is tel and fed2.metrics is tel.registry


def test_partition_heal_timeline_in_virtual_order():
    """Acceptance: a partition-heal scenario's ``report.timeline`` shows the
    partition, the heal, and post-heal reconvergence (rounds completing,
    globals minting) as labeled events in virtual-time order."""
    n, rounds = 6, 6
    fed = Federation(latency=dict(delay_s=0.01, seed=11),
                     aggregator_ratio=0.4, metrics=True)
    clients = [fed.client(f"c{i}") for i in range(n)]
    session = fed.create_session("s", "m", rounds=rounds,
                                 participants=clients)
    groups = [[f"c{i}" for i in range(3)], [f"c{i}" for i in range(3, n)]]
    params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
              for i in range(n)}
    report = scenarios.play(
        session, lambda cid, g, r: (params[cid], 1),
        events=[scenarios.partition(groups, t0=1.5, t1=3.5)],
        rounds=rounds, round_time_s=1.0)

    assert report.final_state == "terminated" and not report.stalled
    ts = [t for t, _ in report.timeline]
    assert ts == sorted(ts)                  # virtual-time order
    labels = [lbl for _, lbl in report.timeline]
    i_part = next(i for i, l in enumerate(labels) if l.startswith("partition"))
    i_heal = next(i for i, l in enumerate(labels) if l.startswith("heal"))
    assert i_part < i_heal
    t_heal = report.timeline[i_heal][0]
    assert t_heal == pytest.approx(3.5)
    # reconvergence: rounds keep completing and globals keep minting after
    # the heal
    assert any(t > t_heal and l.startswith("round_complete")
               for t, l in report.timeline)
    assert any(t > t_heal and l.startswith("mint") for t, l in report.timeline)
    # the noisy data plane stays out of the compact timeline
    assert not any(l.startswith(("publish", "deliver")) for l in labels)


def test_timeline_breadcrumbs_preserved_when_metrics_off():
    fed = Federation(latency=dict(delay_s=0.01, seed=11))
    clients = [fed.client(f"c{i}") for i in range(3)]
    session = fed.create_session("s", "m", rounds=2, participants=clients)
    params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
              for i in range(3)}
    report = scenarios.play(session, lambda cid, g, r: (params[cid], 1),
                            rounds=2, round_time_s=1.0)
    assert report.timeline                   # the bare "round N" breadcrumbs
    assert all(lbl.startswith("round") for _, lbl in report.timeline)


_TARGETS = {f"c{i}": float(i) for i in range(8)}


def _pull_train(cid, g, r):
    base = np.zeros(4, np.float32) if g is None else np.asarray(g["w"])
    tgt = np.full(4, _TARGETS.get(cid, 3.0), np.float32)
    return {"w": (base + np.float32(0.4) * (tgt - base))}, 1


def test_async_run_feeds_staleness_histogram_and_timeline():
    fed = Federation(latency=dict(delay_s=0.01, jitter_s=0.005, seed=42),
                     aggregator_ratio=0.4, metrics=True)
    clients = [fed.client(f"c{i}") for i in range(5)]
    session = fed.create_session(
        "s", "m", rounds=6, participants=clients,
        async_mode=dict(buffer_k=3, staleness_bound=4, base_period_s=1.0,
                        period_jitter_s=0.1, seed=7))
    session.start()
    report = scenarios.play_async(
        session, _pull_train, max_time_s=120.0,
        initial_params={"w": np.zeros(4, np.float32)})
    assert report.final_state == "terminated"
    assert report.timeline                   # trace-derived timeline
    assert any(lbl.startswith("round_complete") for _, lbl in report.timeline)
    snap = fed.metrics.snapshot()
    hist = snap["sdflmq_async_staleness_versions"]["samples"][""]
    assert hist["count"] > 0                 # every async arrival observed
    admitted = sum(snap["sdflmq_async_admitted"]["samples"].values())
    assert admitted == report.admitted > 0
    kinds = fed.tracer.kinds()
    assert kinds.get("train", 0) > 0 and kinds.get("round_complete", 0) > 0
