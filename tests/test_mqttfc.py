"""MQTTFC tests: RFC binding, payload batching, compression, numpy wire."""
import numpy as np
import pytest

from repro.core.broker import SimBroker
from repro.core.mqttfc import MQTTFC, decode, encode


def test_encode_decode_numpy_roundtrip():
    obj = {"a": [np.arange(12, dtype=np.float32).reshape(3, 4)],
           "k": {"w": np.ones(5, np.int8), "x": 3, "y": "s"},
           "s": "me"}
    back = decode(encode(obj))
    np.testing.assert_array_equal(back["a"][0], obj["a"][0])
    assert back["a"][0].dtype == np.float32
    np.testing.assert_array_equal(back["k"]["w"], obj["k"]["w"])


def test_rfc_call():
    b = SimBroker()
    callee = MQTTFC(b, "callee")
    caller = MQTTFC(b, "caller")
    got = []
    callee.bind("fns/add", lambda x, y, scale=1: got.append((x + y) * scale))
    caller.call("fns/add", 2, 3, scale=10)
    assert got == [50]


def test_large_payload_batching_reassembly():
    b = SimBroker()
    callee = MQTTFC(b, "callee", max_batch_bytes=1024)
    caller = MQTTFC(b, "caller", max_batch_bytes=1024)
    got = []
    callee.bind("fns/blob", lambda arr: got.append(arr))
    big = np.random.default_rng(0).normal(size=(100, 100)).astype(np.float32)
    caller.call("fns/blob", big)
    assert caller.parts_sent > 5          # really chunked
    assert len(got) == 1
    np.testing.assert_array_equal(got[0], big)


def test_compression_shrinks_wire_bytes():
    b = SimBroker()
    callee = MQTTFC(b, "callee")
    caller = MQTTFC(b, "caller", codec="zlib", compress_threshold=128)
    got = []
    callee.bind("fns/z", lambda arr: got.append(arr))
    compressible = np.zeros((64, 64), np.float32)
    caller.call("fns/z", compressible)
    assert caller.bytes_sent < caller.raw_bytes_sent / 2
    np.testing.assert_array_equal(got[0], compressible)


def test_wildcard_raw_handler():
    b = SimBroker()
    fc = MQTTFC(b, "x")
    caller = MQTTFC(b, "y")
    got = []
    fc.subscribe_raw("evt/+", lambda topic, payload: got.append(topic))
    caller.call("evt/a", 1)
    caller.call("evt/b", 2)
    assert got == ["evt/a", "evt/b"]


def test_unbind_stops_delivery():
    b = SimBroker()
    fc = MQTTFC(b, "x")
    caller = MQTTFC(b, "y")
    got = []
    fc.bind("t/f", lambda: got.append(1))
    caller.call("t/f")
    fc.unbind("t/f")
    caller.call("t/f")
    assert got == [1]
