"""MQTTFC tests: RFC binding, payload batching, compression, numpy wire."""
import numpy as np
import pytest

from repro.core.broker import SimBroker
from repro.core.mqttfc import MQTTFC, decode, encode


def test_encode_decode_numpy_roundtrip():
    obj = {"a": [np.arange(12, dtype=np.float32).reshape(3, 4)],
           "k": {"w": np.ones(5, np.int8), "x": 3, "y": "s"},
           "s": "me"}
    back = decode(encode(obj))
    np.testing.assert_array_equal(back["a"][0], obj["a"][0])
    assert back["a"][0].dtype == np.float32
    np.testing.assert_array_equal(back["k"]["w"], obj["k"]["w"])


def test_rfc_call():
    b = SimBroker()
    callee = MQTTFC(b, "callee")
    caller = MQTTFC(b, "caller")
    got = []
    callee.bind("fns/add", lambda x, y, scale=1: got.append((x + y) * scale))
    caller.call("fns/add", 2, 3, scale=10)
    assert got == [50]


def test_large_payload_batching_reassembly():
    b = SimBroker()
    callee = MQTTFC(b, "callee", max_batch_bytes=1024)
    caller = MQTTFC(b, "caller", max_batch_bytes=1024)
    got = []
    callee.bind("fns/blob", lambda arr: got.append(arr))
    big = np.random.default_rng(0).normal(size=(100, 100)).astype(np.float32)
    caller.call("fns/blob", big)
    assert caller.parts_sent > 5          # really chunked
    assert len(got) == 1
    np.testing.assert_array_equal(got[0], big)


def test_compression_shrinks_wire_bytes():
    b = SimBroker()
    callee = MQTTFC(b, "callee")
    caller = MQTTFC(b, "caller", codec="zlib", compress_threshold=128)
    got = []
    callee.bind("fns/z", lambda arr: got.append(arr))
    compressible = np.zeros((64, 64), np.float32)
    caller.call("fns/z", compressible)
    assert caller.bytes_sent < caller.raw_bytes_sent / 2
    np.testing.assert_array_equal(got[0], compressible)


def test_wildcard_raw_handler():
    b = SimBroker()
    fc = MQTTFC(b, "x")
    caller = MQTTFC(b, "y")
    got = []
    fc.subscribe_raw("evt/+", lambda topic, payload: got.append(topic))
    caller.call("evt/a", 1)
    caller.call("evt/b", 2)
    assert got == ["evt/a", "evt/b"]


def test_unbind_stops_delivery():
    b = SimBroker()
    fc = MQTTFC(b, "x")
    caller = MQTTFC(b, "y")
    got = []
    fc.bind("t/f", lambda: got.append(1))
    caller.call("t/f")
    fc.unbind("t/f")
    caller.call("t/f")
    assert got == [1]


# ---------------------------------------------------------------------------
# at-least-once dedup (QoS-1 redelivery protection)
# ---------------------------------------------------------------------------

class TestDuplicateDedup:
    def _pair(self):
        from repro.core.broker import SimBroker
        from repro.core.mqttfc import MQTTFC
        t = SimBroker()
        tx = MQTTFC(t, "tx", compress_threshold=1 << 30)
        rx = MQTTFC(t, "rx", compress_threshold=1 << 30)
        return t, tx, rx

    def test_replayed_single_frame_call_dropped(self):
        t, tx, rx = self._pair()
        got = []
        rx.subscribe_raw("x/y", lambda topic, p: got.append(p["a"][0]))
        frames = []
        real = t.publish
        t.publish = lambda *a, **k: (frames.append((a, k)), real(*a, **k))[1]
        tx.call("x/y", 7)
        t.publish = real
        for a, k in frames:                     # verbatim redelivery
            real(*a, **k)
        assert len(got) == 1
        assert rx.wire_stats()["duplicate_drops"] == len(frames)
        assert rx.wire_stats()["calls_received"] == 1

    def test_duplicate_part_inside_open_assembly_dropped(self):
        import numpy as np
        t, tx, rx = self._pair()
        tx.max_batch_bytes = 256
        got = []
        rx.subscribe_raw("x/big", lambda topic, p: got.append(p["a"][0]))
        frames = []
        real = t.publish
        t.publish = lambda *a, **k: (frames.append((a, k)), real(*a, **k))[1]
        tx.call("x/big", np.arange(256, dtype=np.float32))
        t.publish = real
        assert len(frames) > 1
        assert len(got) == 1
        # replay only the FIRST part: the call is complete, highwater drops
        a, k = frames[0]
        real(*a, **k)
        assert rx.wire_stats()["duplicate_drops"] == 1
        assert len(got) == 1

    def test_retained_replay_exempt_from_dedup(self):
        """Retained frames legitimately re-arrive (replay on every new
        matching subscribe); the dedup highwater must not eat them."""
        t, tx, rx = self._pair()
        got = []
        tx.call("x/cfg", 41, retain=True)
        rx.subscribe_raw("x/cfg", lambda topic, p: got.append(p["a"][0]))
        rx.subscribe_raw("x/+", lambda topic, p: got.append(p["a"][0]))
        assert got == [41, 41]                  # both filters replayed
        assert rx.wire_stats()["duplicate_drops"] == 0

    def test_dedup_highwater_bounded(self):
        t, tx, rx = self._pair()
        rx._dedup_cap = 8
        rx.subscribe_raw("x/y", lambda topic, p: None)
        for i in range(50):
            tx.call(f"x/y", i)
        assert len(rx._dedup_hw) <= 8

    def test_fresh_calls_still_flow_after_duplicates(self):
        t, tx, rx = self._pair()
        got = []
        rx.subscribe_raw("x/y", lambda topic, p: got.append(p["a"][0]))
        frames = []
        real = t.publish
        t.publish = lambda *a, **k: (frames.append((a, k)), real(*a, **k))[1]
        tx.call("x/y", 1)
        t.publish = real
        for a, k in frames:
            real(*a, **k)
        tx.call("x/y", 2)                       # newer call_id passes
        assert got == [1, 2]
