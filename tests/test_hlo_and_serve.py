"""HLO analyzer unit tests (loop-aware cost extraction) + ServeEngine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, smoke_config
from repro.launch.hlo_analysis import analyze, parse_module
from repro.models import model_api
from repro.serve.engine import ServeEngine


class TestHLOAnalysis:
    def test_scan_trip_count_scaling(self):
        def body(x, w):
            return jnp.tanh(jnp.dot(x, w)), None

        def f(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
        comp = jax.jit(f).lower(x, ws).compile()
        cost = analyze(comp.as_text(), 1)
        assert 6 in cost.while_trips
        np.testing.assert_allclose(cost.flops, 6 * 2 * 32 * 64 * 64, rtol=.01)

    def test_nested_scan(self):
        def inner(x, w):
            return jnp.dot(x, w), None

        def outer(x, ws):
            def ob(x, _):
                return jax.lax.scan(inner, x, ws)[0], None
            return jax.lax.scan(ob, x, None, length=3)[0]

        x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
        comp = jax.jit(outer).lower(x, ws).compile()
        cost = analyze(comp.as_text(), 1)
        np.testing.assert_allclose(cost.flops, 3 * 4 * 2 * 16 * 32 * 32,
                                   rtol=0.01)

    def test_unrolled_matches_plain(self):
        def f(x, w):
            for _ in range(5):
                x = jnp.dot(x, w)
            return x
        x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
        w = jax.ShapeDtypeStruct((8, 8), jnp.float32)
        comp = jax.jit(f).lower(x, w).compile()
        cost = analyze(comp.as_text(), 1)
        np.testing.assert_allclose(cost.flops, 5 * 2 * 8 * 8 * 8, rtol=0.01)

    def test_parse_synthetic_collective_line(self):
        hlo = """
ENTRY %main (p: f32[16,64]) -> f32[16,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %ar = f32[16,64]{1,0} all-reduce(%p), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %out = f32[16,64]{1,0} copy(%ar)
}
"""
        cost = analyze(hlo, 8)
        want = 2 * (4 - 1) / 4 * 16 * 64 * 4
        np.testing.assert_allclose(cost.coll_bytes, want, rtol=1e-6)
        assert cost.coll_counts["all-reduce"] == 1

    def test_mem_ops_counted_with_symbol_table(self):
        hlo = """
ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  ROOT %c = f32[128]{0} copy(%p)
}
"""
        cost = analyze(hlo, 1)
        assert cost.hbm_bytes == 2 * 128 * 4


class TestServeEngine:
    @pytest.fixture(scope="class")
    def engine(self):
        cfg = smoke_config(get_arch("qwen2-7b"))
        params = model_api.init_params(cfg, jax.random.PRNGKey(0))
        return ServeEngine(cfg, params, batch_size=2, max_seq=64)

    def test_batched_requests_complete(self, engine):
        rng = np.random.default_rng(0)
        reqs = [engine.submit(rng.integers(0, 200, size=8), max_new=4)
                for _ in range(5)]
        done = engine.run()
        assert len(done) == 5
        for r in done:
            assert r.done and len(r.out) == 4
        assert engine.stats["decode_steps"] > 0
        assert engine.stats["prefill_tokens"] >= 5 * 8

    def test_greedy_is_deterministic(self, engine):
        prompt = np.arange(10) % 50
        r1 = engine.submit(prompt, max_new=5)
        engine.run()
        r2 = engine.submit(prompt, max_new=5)
        engine.run()
        assert r1.out == r2.out
