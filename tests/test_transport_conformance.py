"""Collection shim: runs the shared Transport-conformance contract
(``tests/transport_conformance.py``) under the default test session.

The contract itself is parameterized over SimBroker, LatencyTransport,
and PahoTransport-over-mini-broker (builtin + paho legs); the paho leg
self-skips when the optional ``repro[mqtt]`` extra is not installed.
"""
from transport_conformance import *          # noqa: F401,F403
