"""Per-architecture smoke tests (reduced same-family configs) + numerical
equivalence of attention / linear-attention implementations + decode
consistency with full-sequence prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_arch, list_archs, smoke_config
from repro.models import inputs as minputs
from repro.models import model_api

ARCHS = list_archs()
TRAIN = ShapeConfig("t", 32, 4, "train")
PRE = ShapeConfig("p", 32, 4, "prefill")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_train_step(arch, rng):
    """One forward + loss + grad step on CPU: shapes + finiteness."""
    cfg = smoke_config(get_arch(arch))
    params = model_api.init_params(cfg, rng)
    batch = minputs.make_batch(cfg, TRAIN, rng)
    mod = model_api.get_model(cfg)
    logits, aux = jax.jit(lambda p, b: mod.forward(cfg, p, b))(params, batch)
    vpad = ((cfg.vocab + 127) // 128) * 128
    assert logits.shape == (4, 32, vpad)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"

    loss, parts = model_api.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))

    grads = jax.grad(lambda p: model_api.loss_fn(cfg, p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode_consistency(arch, rng):
    """Greedy decode after prefill matches slice of full-seq forward:
    the cache path and the parallel path implement the same model."""
    cfg = smoke_config(get_arch(arch))
    params = model_api.init_params(cfg, rng)
    mod = model_api.get_model(cfg)
    batch = minputs.make_batch(cfg, PRE, rng)
    S = batch["tokens"].shape[1]

    plog, cache = jax.jit(lambda p, b: mod.prefill(cfg, p, b))(params, batch)
    fbatch = dict(batch)
    flog, _ = jax.jit(lambda p, b: mod.forward(cfg, p, b))(params, fbatch)
    np.testing.assert_allclose(np.asarray(plog, np.float32),
                               np.asarray(flog[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)

    # one decode step with the prefilled cache == forward of seq+1
    if cfg.window is None and cfg.family != "rwkv":
        from repro.models.kvcache import pad_cache
        cache = pad_cache(cache, S + 8)   # headroom: no ring-wrap eviction
    tok = jnp.argmax(plog, -1).astype(jnp.int32)[:, None]
    dlog, cache2 = jax.jit(lambda p, c, b: mod.decode_step(cfg, p, c, b))(
        params, cache, {"token": tok, "pos": jnp.full((4,), S, jnp.int32)})
    ext = dict(fbatch)
    ext["tokens"] = jnp.concatenate([fbatch["tokens"], tok], axis=1)
    flog2, _ = jax.jit(lambda p, b: mod.forward(cfg, p, b))(params, ext)
    np.testing.assert_allclose(np.asarray(dlog, np.float32),
                               np.asarray(flog2[:, -1], np.float32),
                               rtol=5e-2, atol=5e-2)


def test_moe_capacity_drops_are_bounded():
    """With generous capacity, MoE output must involve (almost) all tokens:
    compare against capacity so large nothing drops."""
    from repro.models.moe import moe_apply
    cfg = smoke_config(get_arch("mixtral-8x22b"))
    big = cfg.replace(moe=cfg.moe.__class__(
        n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
        d_ff_expert=cfg.moe.d_ff_expert, capacity_factor=100.0))
    key = jax.random.PRNGKey(1)
    from repro.dist import sharding as shd
    from repro.models.moe import moe_decl
    p = shd.materialize(moe_decl(big), key)
    x = jax.random.normal(key, (2, 16, big.d_model), jnp.float32).astype(jnp.bfloat16)
    y_full, _ = moe_apply(big, p, x)
    y_drop, _ = moe_apply(cfg, p, x)   # cf=1.25
    # most tokens should agree exactly (those not dropped)
    same = np.isclose(np.asarray(y_full, np.float32),
                      np.asarray(y_drop, np.float32), atol=1e-2).mean()
    assert same > 0.5


def test_vocab_padding_is_multiple_of_128():
    from repro.models.layers import pad_vocab
    for arch in ARCHS:
        cfg = get_arch(arch)
        assert pad_vocab(cfg.vocab) % 128 == 0
        assert pad_vocab(cfg.vocab) >= cfg.vocab


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    spec = {
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 163840),
        "mixtral-8x22b": (56, 6144, 48, 8, 32768),
        "whisper-small": (12, 768, 12, 12, 51865),
        "internlm2-20b": (48, 6144, 48, 8, 92544),
        "qwen1.5-4b": (40, 2560, 20, 20, 151936),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 32000),
        "qwen2-7b": (28, 3584, 28, 4, 152064),
        "rwkv6-7b": (32, 4096, 64, 64, 65536),
        "internvl2-2b": (24, 2048, 16, 8, 92553),
        "hymba-1.5b": (32, 1600, 25, 5, 32001),
    }
    for name, (L, d, H, kv, V) in spec.items():
        cfg = get_arch(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.vocab) == (L, d, H, kv, V), name
    assert get_arch("kimi-k2-1t-a32b").moe.n_experts == 384
    assert get_arch("kimi-k2-1t-a32b").moe.top_k == 8
    assert get_arch("mixtral-8x22b").moe.n_experts == 8
    assert get_arch("hymba-1.5b").ssm_state == 16
    assert get_arch("qwen2-7b").d_ff == 18944


def test_kimi_total_params_about_1t():
    from repro.dist import sharding as shd
    cfg = get_arch("kimi-k2-1t-a32b")
    n = shd.param_count(model_api.param_decls(cfg))
    assert 0.9e12 < n < 1.2e12, n
