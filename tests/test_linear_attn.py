"""Linear attention (RWKV6 / SSD) equivalences: chunked == recurrent,
decode continuation, state carry across calls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.linear_attn import chunked, decode_step, recurrent


def _inputs(B, T, H, dk, dv, seed, scalar_decay=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (B, T, H, dk)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, dk)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, dv))
    wshape = (B, T, H, 1) if scalar_decay else (B, T, H, dk)
    w = -jnp.exp(jax.random.normal(ks[3], wshape) * 0.5)
    u = jax.random.normal(ks[4], (H, dk)) * 0.3
    return r, k, v, w, u


@pytest.mark.parametrize("B,T,H,dk,dv,chunk", [
    (2, 32, 3, 8, 16, 8), (1, 48, 2, 16, 16, 16), (2, 64, 1, 4, 8, 32),
    (1, 16, 2, 8, 8, 16),
])
@pytest.mark.parametrize("use_u", [True, False])
def test_chunked_equals_recurrent(B, T, H, dk, dv, chunk, use_u):
    r, k, v, w, u = _inputs(B, T, H, dk, dv, seed=T + use_u)
    uu = u if use_u else None
    o1, s1 = recurrent(r, k, v, w, u=uu)
    o2, s2 = chunked(r, k, v, w, u=uu, chunk=chunk)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_scalar_decay_ssd_form():
    r, k, v, w, _ = _inputs(2, 32, 3, 8, 16, seed=5, scalar_decay=True)
    o1, s1 = recurrent(r, k, v, w, u=None)
    o2, s2 = chunked(r, k, v, w, u=None, chunk=8)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_state_carry_split_invariance():
    """Running [0:T/2] then [T/2:T] with carried state == full run."""
    r, k, v, w, u = _inputs(1, 32, 2, 8, 8, seed=9)
    o_full, s_full = chunked(r, k, v, w, u=u, chunk=8)
    h = 16
    o1, s1 = chunked(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u=u, chunk=8)
    o2, s2 = chunked(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u=u, s0=s1,
                     chunk=8)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), o_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s2, s_full, rtol=2e-4, atol=2e-4)


def test_decode_step_matches_recurrent_tail():
    r, k, v, w, u = _inputs(2, 9, 2, 8, 8, seed=11)
    o_full, s_full = recurrent(r, k, v, w, u=u)
    _, s_prefix = recurrent(r[:, :-1], k[:, :-1], v[:, :-1], w[:, :-1], u=u)
    o_t, s_t = decode_step(r[:, -1], k[:, -1], v[:, -1], w[:, -1],
                           s_prefix, u=u)
    np.testing.assert_allclose(o_t, o_full[:, -1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(s_t, s_full, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(T=st.integers(2, 24), chunk=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 50), use_u=st.booleans())
def test_property_chunk_size_invariance(T, chunk, seed, use_u):
    r, k, v, w, u = _inputs(1, T, 1, 4, 4, seed=seed)
    uu = u if use_u else None
    o_ref, s_ref = recurrent(r, k, v, w, u=uu)
    o, s = chunked(r, k, v, w, u=uu, chunk=chunk)
    np.testing.assert_allclose(o, o_ref, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(s, s_ref, rtol=3e-3, atol=3e-3)
