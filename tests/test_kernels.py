"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
with shape/dtype sweeps + hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.fedavg.ops import fedavg, fedavg_pytree
from repro.kernels.fedavg.ref import fedavg_ref, fedavg_tree_ref
from repro.kernels.flash_attn.ops import flash
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.quant8.ops import dequantize, quantize
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_ref
from repro.kernels.wkv6.ops import wkv
from repro.kernels.wkv6.ref import wkv_ref


class TestFedavgKernel:
    @pytest.mark.parametrize("K,N,dtype", [
        (4, 512, jnp.float32), (16, 1000, jnp.float32),
        (8, 4096, jnp.bfloat16), (2, 63, jnp.float32),
        (5, 70000, jnp.bfloat16),
    ])
    def test_matches_ref(self, K, N, dtype):
        ks = jax.random.split(jax.random.PRNGKey(K + N), 2)
        x = jax.random.normal(ks[0], (K, N), jnp.float32).astype(dtype)
        w = jax.random.uniform(ks[1], (K,)) + 0.1
        got = fedavg(x, w, block=256, force="pallas")
        want = fedavg_ref(x, w)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_tree_ref_equals_flat_ref(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
        w = jnp.arange(1.0, 9.0)
        flat = fedavg_ref(x, w)
        tree = fedavg_tree_ref(x, w, [(0, 1, 2), (3, 4), (5, 6, 7)])
        np.testing.assert_allclose(flat, tree, rtol=1e-5)

    def test_pytree_api(self):
        params = {"a": jnp.ones((4, 3, 5)), "b": jnp.zeros((4, 7))}
        w = jnp.ones((4,))
        out = fedavg_pytree(params, w, force="pallas")
        assert out["a"].shape == (3, 5)
        np.testing.assert_allclose(out["a"], 1.0)

    @settings(max_examples=20, deadline=None)
    @given(K=st.integers(2, 10), N=st.integers(1, 600),
           seed=st.integers(0, 99))
    def test_property_convex_combination(self, K, N, seed):
        """FedAvg output is within [min, max] of the inputs elementwise and
        exactly linear in the inputs."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        x = jax.random.normal(ks[0], (K, N), jnp.float32)
        w = jax.random.uniform(ks[1], (K,)) + 0.05
        out = np.asarray(fedavg(x, w, block=128, force="pallas"))
        xn = np.asarray(x)
        assert (out <= xn.max(0) + 1e-5).all()
        assert (out >= xn.min(0) - 1e-5).all()
        # linearity: fedavg(2x) = 2 fedavg(x)
        out2 = np.asarray(fedavg(2 * x, w, block=128, force="pallas"))
        np.testing.assert_allclose(out2, 2 * out, rtol=1e-4, atol=1e-5)


class TestQuant8Kernel:
    @pytest.mark.parametrize("shape,dtype", [
        ((3, 517), jnp.float32), ((1024,), jnp.bfloat16),
        ((7, 7, 7), jnp.float32), ((65536,), jnp.bfloat16),
    ])
    def test_roundtrip_error_bounded(self, shape, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
             * 5).astype(dtype)
        q, s, n = quantize(x, force="pallas")
        back = dequantize(q, s, n, force="pallas")[:x.size]
        xf = np.asarray(x, np.float32).reshape(-1)
        err = np.abs(np.asarray(back) - xf).max()
        # per-block bound: scale/2 per element
        assert err <= np.abs(xf).max() / 127.0 + 1e-6

    def test_pallas_equals_ref_bitexact(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (2048,)) * 3
        q1, s1, _ = quantize(x, force="pallas")
        q2, s2, _ = quantize(x, force="ref")
        np.testing.assert_array_equal(np.asarray(q1).reshape(-1),
                                      np.asarray(q2).reshape(-1))
        np.testing.assert_allclose(s1, s2, rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 2000), scale=st.floats(1e-3, 1e3),
           seed=st.integers(0, 99))
    def test_property_relative_error(self, n, scale, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale
        q, s, nn = quantize(x, force="pallas")
        back = dequantize(q, s, nn, force="pallas")[:n]
        amax = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(back - x))) <= amax / 127 + 1e-9


class TestWkvKernels:
    def _inputs(self, B, T, H, dk, dv, seed=0, scalar=False):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        r = jax.random.normal(ks[0], (B, T, H, dk)) * 0.5
        k = jax.random.normal(ks[1], (B, T, H, dk)) * 0.5
        v = jax.random.normal(ks[2], (B, T, H, dv))
        wshape = (B, T, H, 1) if scalar else (B, T, H, dk)
        w = -jnp.exp(jax.random.normal(ks[3], wshape) * 0.5)
        u = jax.random.normal(ks[4], (H, dk)) * 0.3
        return r, k, v, w, u

    @pytest.mark.parametrize("B,T,H,dk,dv,chunk", [
        (2, 32, 3, 8, 16, 8), (1, 64, 2, 16, 16, 16), (1, 16, 1, 4, 4, 4),
    ])
    def test_wkv6_interpret_matches_oracle(self, B, T, H, dk, dv, chunk):
        r, k, v, w, u = self._inputs(B, T, H, dk, dv, seed=T)
        o1, s1 = wkv(r, k, v, w, u=u, chunk=chunk, force="pallas")
        o2, s2 = wkv_ref(r, k, v, w, u=u)
        np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(s1, s2, rtol=3e-4, atol=3e-4)

    def test_wkv6_state_continuation(self):
        r, k, v, w, u = self._inputs(1, 32, 2, 8, 8, seed=3)
        _, s_half = wkv(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u=u,
                        chunk=8, force="pallas")
        o2, s2 = wkv(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u=u,
                     s0=s_half, chunk=8, force="pallas")
        o_ref, s_ref = wkv_ref(r, k, v, w, u=u)
        np.testing.assert_allclose(o2, o_ref[:, 16:], rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(s2, s_ref, rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("B,T,H,N,hd", [(2, 32, 3, 8, 16), (1, 24, 2, 4, 8)])
    def test_ssm_scan_matches_oracle(self, B, T, H, N, hd):
        r, k, v, w, _ = self._inputs(B, T, H, N, hd, seed=7, scalar=True)
        o1, s1 = ssm_scan(r, k, v, w, chunk=8, force="pallas")
        o2, s2 = ssm_ref(r, k, v, w)
        np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(s1, s2, rtol=3e-4, atol=3e-4)


class TestFlashKernel:
    @pytest.mark.parametrize("B,S,H,K,hd,causal,window", [
        (2, 64, 4, 2, 16, True, None),
        (1, 128, 4, 4, 32, True, 24),
        (2, 64, 2, 1, 8, False, None),
    ])
    def test_interpret_matches_exact(self, B, S, H, K, hd, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(S), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, K, hd))
        v = jax.random.normal(ks[2], (B, S, K, hd))
        o1 = flash(q, k, v, causal=causal, window=window, force="pallas")
        o2 = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-5)
