"""SimBroker unit tests: MQTT semantics SDFLMQ depends on."""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.broker import Message, SimBroker, topic_matches


def _collector():
    got = []
    return got, lambda m: got.append((m.topic, m.payload))


class TestTopicMatching:
    @pytest.mark.parametrize("filt,topic,expected", [
        ("a/b/c", "a/b/c", True),
        ("a/b/c", "a/b", False),
        ("a/+/c", "a/x/c", True),
        ("a/+/c", "a/x/y", False),
        ("a/#", "a/b/c/d", True),
        ("a/#", "a", True),    # MQTT spec: the parent level matches '#'
        ("#", "anything/at/all", True),
        ("+/b", "a/b", True),
        ("+/b", "a/b/c", False),
        ("sdflmq/session/+/global", "sdflmq/session/s1/global", True),
        ("sdflmq/session/+/global", "sdflmq/session/s1/cluster/x/agg", False),
    ])
    def test_matching(self, filt, topic, expected):
        assert topic_matches(filt, topic) is expected

    @pytest.mark.parametrize("filt,topic,expected", [
        # [MQTT-4.7.1-2/3] '+' is exactly one level, also at the root
        ("+", "a", True),
        ("+", "a/b", False),
        ("+/+", "/finance", True),      # spec example: leading empty level
        ("/+", "/finance", True),
        ("+", "/finance", False),
        ("sport/+", "sport/", True),    # empty trailing level matches '+'
        ("sport/+", "sport", False),
        # '#' is only valid as the last level; elsewhere it matches nothing
        ("a/#/b", "a/x/b", False),
        ("a/#/b", "a/#/b", False),
        ("#/a", "x/a", False),
        # [MQTT-4.7.2-1] topics starting '$' never match wildcard-rooted
        # filters ($SYS stays out of '#' and '+/...' subscriptions)
        ("#", "$SYS/broker/load", False),
        ("+/monitor", "$SYS/monitor", False),
        ("+/#", "$SYS/broker", False),
        ("$SYS/#", "$SYS/broker/load", True),
        ("$SYS/monitor/+", "$SYS/monitor/clients", True),
        ("$SYS/broker", "$SYS/broker", True),
    ])
    def test_mqtt_311_spec_cases(self, filt, topic, expected):
        assert topic_matches(filt, topic) is expected


def _oracle(filt: str, topic: str) -> bool:
    """Independent recursive reference of MQTT 3.1.1 §4.7 matching."""
    f, t = filt.split("/"), topic.split("/")
    if t[0].startswith("$") and f[0] in ("+", "#"):
        return False

    def rec(fi: int, ti: int) -> bool:
        if fi == len(f):
            return ti == len(t)
        if f[fi] == "#":
            return fi == len(f) - 1      # trailing '#' swallows the rest
        if ti == len(t):                 # (including the parent level)
            return False
        if f[fi] == "+" or f[fi] == t[ti]:
            return rec(fi + 1, ti + 1)
        return False

    return rec(0, 0)


@settings(max_examples=300, deadline=None)
@given(seed=st.integers(0, 10**9))
def test_property_topic_matching_against_spec_oracle(seed):
    """Randomized topics/filters (wildcards anywhere, empty levels, $SYS
    roots) must agree with an independently written spec oracle."""
    rng = random.Random(seed)
    levels = ["a", "b", "cc", ""]
    topic = "/".join(rng.choice(levels) for _ in range(rng.randint(1, 4)))
    if rng.random() < 0.25:
        topic = "$SYS/" + topic
    parts = [rng.choice(levels + ["+"]) for _ in range(rng.randint(1, 4))]
    if rng.random() < 0.35:
        parts[rng.randrange(len(parts))] = "#"   # sometimes mid-filter
    filt = "/".join(parts)
    assert topic_matches(filt, topic) == _oracle(filt, topic)


class TestBroker:
    def test_basic_pubsub(self):
        b = SimBroker()
        got, cb = _collector()
        b.connect("c1", cb)
        b.subscribe("c1", "t/x")
        b.publish("t/x", b"hello")
        b.publish("t/y", b"nope")
        assert got == [("t/x", b"hello")]

    def test_wildcard_delivery(self):
        b = SimBroker()
        got, cb = _collector()
        b.connect("c1", cb)
        b.subscribe("c1", "t/+/z")
        b.publish("t/a/z", b"1")
        b.publish("t/b/z", b"2")
        assert len(got) == 2

    def test_retained_message_on_late_subscribe(self):
        b = SimBroker()
        b.publish("cfg/topo", b"v1", retain=True)
        got, cb = _collector()
        b.connect("late", cb)
        b.subscribe("late", "cfg/#")
        assert got == [("cfg/topo", b"v1")]

    def test_retained_overwrite_and_clear(self):
        b = SimBroker()
        b.publish("r", b"old", retain=True)
        b.publish("r", b"new", retain=True)
        got, cb = _collector()
        b.connect("c", cb)
        b.subscribe("c", "r")
        assert got == [("r", b"new")]
        b.publish("r", b"", retain=True)   # clear
        got2, cb2 = _collector()
        b.connect("c2", cb2)
        b.subscribe("c2", "r")
        assert got2 == []

    def test_last_will_fires_on_abnormal_disconnect_only(self):
        b = SimBroker()
        got, cb = _collector()
        b.connect("watcher", cb)
        b.subscribe("watcher", "will/#")
        b.connect("c1", lambda m: None, will=Message("will/c1", b"dead"))
        b.disconnect("c1", graceful=True)
        assert got == []
        b.connect("c2", lambda m: None, will=Message("will/c2", b"dead"))
        b.disconnect("c2", graceful=False)
        assert got == [("will/c2", b"dead")]

    def test_reentrant_publish_is_fifo(self):
        b = SimBroker()
        order = []

        def on_a(m):
            order.append("a")
            b.publish("t/b", b"")

        b.connect("c1", on_a)
        b.subscribe("c1", "t/a")
        b.connect("c2", lambda m: order.append("b"))
        b.subscribe("c2", "t/b")
        b.connect("c3", lambda m: order.append("a2"))
        b.subscribe("c3", "t/a")
        b.publish("t/a", b"")
        assert order == ["a", "a2", "b"]   # queued, not recursive

    def test_bridging_no_loops(self):
        b1, b2 = SimBroker("b1"), SimBroker("b2")
        b1.bridge(b2, ["shared/#"])
        got1, cb1 = _collector()
        got2, cb2 = _collector()
        b1.connect("c1", cb1)
        b1.subscribe("c1", "shared/x")
        b2.connect("c2", cb2)
        b2.subscribe("c2", "shared/x")
        b1.publish("shared/x", b"from1")
        b2.publish("shared/x", b"from2")
        assert got1 == [("shared/x", b"from1"), ("shared/x", b"from2")]
        assert got2 == [("shared/x", b"from1"), ("shared/x", b"from2")]
        # regional topics do not cross
        b1.publish("local/x", b"l")
        assert ("local/x", b"l") not in got2

    def test_sys_stats_counters(self):
        b = SimBroker()
        got, cb = _collector()
        b.connect("c", cb)
        b.subscribe("c", "t")
        b.publish("t", b"12345")
        b.publish("unrouted", b"x")
        st = b.sys_stats()
        assert st["messages_received"] == 2
        assert st["messages_sent"] == 1
        assert st["bytes_sent"] == 5
        assert st["dropped_no_subscriber"] == 1


class TestTopicTrie:
    """The routing trie must agree exactly with ``topic_matches`` and keep
    its per-topic cache coherent across subscription churn."""

    @settings(max_examples=150, deadline=None)
    @given(seed=st.integers(0, 10**9))
    def test_trie_agrees_with_linear_scan(self, seed):
        from repro.core.broker import TopicTrie
        rng = random.Random(seed)
        levels = ["a", "b", "cc", ""]
        filters = []
        for _ in range(rng.randint(1, 8)):
            parts = [rng.choice(levels + ["+"])
                     for _ in range(rng.randint(1, 4))]
            if rng.random() < 0.3:
                parts[-1] = "#"
            filters.append("/".join(parts))
        trie = TopicTrie()
        for i, f in enumerate(filters):
            trie.insert(f, (i, f))
        for _ in range(6):
            topic = "/".join(rng.choice(levels)
                             for _ in range(rng.randint(1, 4)))
            if rng.random() < 0.25:
                topic = "$SYS/" + topic
            expect = [(i, f) for i, f in enumerate(filters)
                      if topic_matches(f, topic)]
            assert list(trie.match(topic)) == expect, (filters, topic)

    def test_cache_invalidation_on_subscription_churn(self):
        from repro.core.broker import TopicTrie
        trie = TopicTrie()
        trie.insert("a/+", "w")
        assert list(trie.match("a/x")) == ["w"]       # cached now
        trie.insert("a/x", "e")
        assert list(trie.match("a/x")) == ["w", "e"]  # cache invalidated
        trie.remove("a/+", "w")
        assert list(trie.match("a/x")) == ["e"]
        trie.remove("a/x", "e")
        assert list(trie.match("a/x")) == []
        assert trie.size == 0

    def test_broker_routing_survives_resubscribe_and_disconnect(self):
        b = SimBroker()
        got, cb = _collector()
        b.connect("c", cb)
        b.subscribe("c", "t/#")
        b.publish("t/a", b"1")
        b.unsubscribe("c", "t/#")
        b.publish("t/a", b"2")              # cached topic must NOT deliver
        b.subscribe("c", "t/+")
        b.publish("t/a", b"3")
        b.disconnect("c")
        b.publish("t/a", b"4")
        assert [p for _t, p in got] == [b"1", b"3"]
        assert b.sys_stats()["dropped_no_subscriber"] == 2

    def test_reconnect_drops_old_subscriptions(self):
        b = SimBroker()
        got1, cb1 = _collector()
        b.connect("c", cb1)
        b.subscribe("c", "t/#")
        got2, cb2 = _collector()
        b.connect("c", cb2)                 # reconnect: fresh session
        b.publish("t/a", b"x")
        assert got1 == [] and got2 == []    # old subs died with the session
