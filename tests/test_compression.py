"""Property tests for ``repro.dist.compression`` — the shared quantizer
behind the compiled "compressed" schedule AND the host MQTT uplink codecs.

Properties locked down:
  * int8 round-trip error is bounded by half a quantization step per row,
  * error feedback conserves mass exactly: dequantized + residual == input,
  * top-k EF conservation: densify(sent) + residual == input, including the
    un-sent coordinates (they ride the residual untouched),
  * top-k index invariants: sorted, unique, in-range, correct count, and
    the selected magnitudes dominate the rejected ones,
  * degenerate inputs (zeros, constants, denormals, empty tensors) neither
    crash nor produce non-finite outputs,
  * the numpy and jax.numpy code paths agree bit-for-bit on tie-free
    inputs (the host uplink and the compiled schedule share one codec).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.dist import compression as C


def _arr(seed: int, shape, spread: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    return x * np.float32(10.0 ** spread)


# ---------------------------------------------------------------------------
# int8 row quantizer
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), rows=st.integers(1, 8),
       cols=st.integers(1, 96), spread=st.integers(-3, 3))
@settings(max_examples=25, deadline=None)
def test_int8_roundtrip_error_bound(seed, rows, cols, spread):
    x = _arr(seed, (rows, cols), spread)
    q, s = C.quantize_int8(x, xp=np)
    assert q.dtype == np.int8 and s.shape == (rows, 1)
    err = np.abs(C.dequantize_int8(q, s, xp=np) - x)
    assert np.all(err <= s / 2 + np.abs(x) * 1e-6 + 1e-12)


@given(seed=st.integers(0, 10_000), rows=st.integers(1, 6),
       cols=st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_ef_conservation_and_bounded_residual(seed, rows, cols):
    x = _arr(seed, (rows, cols))
    err0 = _arr(seed + 1, (rows, cols)) * np.float32(0.01)
    q, s, new_err = C.quantize_with_error_feedback(x, err0, xp=np)
    # mass conservation: what was dequantized plus what is carried forward
    # is exactly what went in
    np.testing.assert_allclose(C.dequantize_int8(q, s, xp=np) + new_err,
                               x + err0, rtol=1e-6, atol=1e-6)
    # the residual never exceeds half a quantization step
    assert np.all(np.abs(new_err) <= s / 2 + 1e-6)


def test_repeated_ef_rounds_do_not_drift():
    x = _arr(7, (4, 32))
    err = np.zeros_like(x)
    for _ in range(25):
        q, s, err = C.quantize_with_error_feedback(x, err, xp=np)
        assert np.all(np.abs(err) <= s / 2 + 1e-6)
        assert np.all(np.isfinite(err))


# ---------------------------------------------------------------------------
# top-k sparsifier + the combined uplink codec
# ---------------------------------------------------------------------------

@given(n=st.integers(0, 100_000), density=st.floats(1e-6, 1.0))
@settings(max_examples=50, deadline=None)
def test_topk_count_properties(n, density):
    k = C.topk_count(n, density)
    if n == 0:
        assert k == 0
    else:
        assert 1 <= k <= n
        assert C.topk_count(n, 1.0) == n
        assert C.topk_count(n, density / 2) <= k   # monotone in density


@given(seed=st.integers(0, 10_000), n=st.integers(1, 400),
       density=st.floats(0.001, 1.0))
@settings(max_examples=25, deadline=None)
def test_topk_index_invariants(seed, n, density):
    x = _arr(seed, (n,))
    idx, vals = C.topk_sparsify(x, density, xp=np)
    k = C.topk_count(n, density)
    assert idx.shape == (k,) and vals.shape == (k,)
    assert idx.dtype == np.int32
    assert np.all(np.diff(idx) > 0)                   # sorted, unique
    assert idx.min() >= 0 and idx.max() < n
    np.testing.assert_array_equal(vals, x[idx])
    # magnitude dominance: nothing rejected beats anything selected
    mask = np.zeros(n, bool)
    mask[idx] = True
    if k < n:
        assert np.abs(x[idx]).min() >= np.abs(x[~mask]).max()


@given(seed=st.integers(0, 10_000), rows=st.integers(1, 6),
       cols=st.integers(1, 48), density=st.floats(0.01, 1.0))
@settings(max_examples=25, deadline=None)
def test_topk_ef_mass_conservation(seed, rows, cols, density):
    x = _arr(seed, (rows, cols))
    err0 = _arr(seed + 1, (rows, cols)) * np.float32(0.05)
    idx, q, scale, new_err = C.quantize_topk_int8_ef(x, err0, density, xp=np)
    assert q.dtype == np.int8 and scale.shape == (1,)
    assert new_err.shape == x.shape
    dense = C.densify_topk(idx, q, scale, x.shape, xp=np)
    # sent + residual == input, exactly (un-sent coordinates ride the
    # residual untouched; sent ones carry only their quantization error)
    np.testing.assert_allclose(dense + new_err, x + err0,
                               rtol=1e-6, atol=1e-6)
    # un-selected coordinates are exactly the input in the residual
    t = (x + err0).reshape(-1)
    mask = np.zeros(t.size, bool)
    mask[idx] = True
    np.testing.assert_array_equal(new_err.reshape(-1)[~mask], t[~mask])


@given(seed=st.integers(0, 10_000), n=st.integers(1, 256),
       density=st.floats(0.01, 1.0))
@settings(max_examples=25, deadline=None)
def test_densify_scatter_roundtrip(seed, n, density):
    x = _arr(seed, (n,))
    idx, q, scale, _ = C.quantize_topk_int8_ef(x, np.float32(0.0), density,
                                               xp=np)
    dense = C.densify_topk(idx, q, scale, (n,), xp=np)
    mask = np.zeros(n, bool)
    mask[idx] = True
    assert np.all(dense[~mask] == 0.0)
    np.testing.assert_array_equal(dense[mask],
                                  q.astype(np.float32) * scale[0])


# ---------------------------------------------------------------------------
# degenerate inputs
# ---------------------------------------------------------------------------

def test_zero_input_edges():
    x = np.zeros((3, 8), np.float32)
    q, s = C.quantize_int8(x, xp=np)
    assert np.all(q == 0) and np.all(s == np.float32(1.0 / 127.0))
    np.testing.assert_array_equal(C.dequantize_int8(q, s, xp=np), x)
    idx, qq, sc, err = C.quantize_topk_int8_ef(x, np.zeros_like(x), 0.25,
                                               xp=np)
    assert np.all(qq == 0) and np.all(err == 0.0)
    np.testing.assert_array_equal(
        C.densify_topk(idx, qq, sc, x.shape, xp=np), x)


def test_constant_input_edges():
    x = np.full((2, 16), 3.7, np.float32)
    q, s = C.quantize_int8(x, xp=np)
    assert np.all(q == 127)
    err = np.abs(C.dequantize_int8(q, s, xp=np) - x)
    assert np.all(err <= s / 2 + 1e-6)


def test_denormal_input_edges():
    x = np.full((4,), 1e-42, np.float32)          # subnormal f32
    q, s = C.quantize_int8(x, xp=np)
    assert np.all(np.isfinite(s))
    assert np.all(np.isfinite(C.dequantize_int8(q, s, xp=np)))
    idx, qq, sc, err = C.quantize_topk_int8_ef(x, np.zeros_like(x), 0.5,
                                               xp=np)
    assert np.all(np.isfinite(err)) and np.all(np.isfinite(sc))


def test_empty_tensor_edges():
    x = np.zeros((0,), np.float32)
    idx, vals = C.topk_sparsify(x, 0.5, xp=np)
    assert idx.size == 0 and vals.size == 0
    i2, q2, s2, e2 = C.quantize_topk_int8_ef(x, x.copy(), 0.5, xp=np)
    assert i2.size == 0 and q2.size == 0 and e2.size == 0
    assert C.densify_topk(i2, q2, s2, (0,), xp=np).shape == (0,)
    assert C.topk_count(0, 0.5) == 0


# ---------------------------------------------------------------------------
# numpy <-> jax.numpy parity (the two halves of the shared codec)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def jnp():
    return pytest.importorskip("jax.numpy")


def _tie_free(seed: int, n: int) -> np.ndarray:
    """Strictly distinct magnitudes -> top-k selection is unambiguous, so
    both backends must agree bit-for-bit."""
    rng = np.random.default_rng(seed)
    mags = np.linspace(0.5, 2.0, n, dtype=np.float32)
    signs = np.where(rng.random(n) < 0.5, -1.0, 1.0).astype(np.float32)
    return rng.permutation(mags) * signs


@pytest.mark.parametrize("seed,n", [(0, 17), (1, 64), (2, 255)])
def test_int8_np_jnp_parity(jnp, seed, n):
    x = _tie_free(seed, n).reshape(1, -1)
    qn, sn = C.quantize_int8(x, xp=np)
    qj, sj = C.quantize_int8(jnp.asarray(x), xp=jnp)
    np.testing.assert_array_equal(qn, np.asarray(qj))
    np.testing.assert_array_equal(sn, np.asarray(sj))


@pytest.mark.parametrize("seed,n,density", [(3, 33, 0.1), (4, 128, 0.25),
                                            (5, 300, 0.03)])
def test_topk_np_jnp_parity(jnp, seed, n, density):
    x = _tie_free(seed, n)
    err = np.zeros_like(x)
    inp, qnp, snp, enp = C.quantize_topk_int8_ef(x, err, density, xp=np)
    ijx, qjx, sjx, ejx = C.quantize_topk_int8_ef(
        jnp.asarray(x), jnp.asarray(err), density, xp=jnp)
    np.testing.assert_array_equal(inp, np.asarray(ijx))
    np.testing.assert_array_equal(qnp, np.asarray(qjx))
    np.testing.assert_array_equal(snp, np.asarray(sjx))
    np.testing.assert_allclose(enp, np.asarray(ejx), rtol=1e-6, atol=1e-7)
    dn = C.densify_topk(inp, qnp, snp, x.shape, xp=np)
    dj = C.densify_topk(ijx, qjx, sjx, x.shape, xp=jnp)
    np.testing.assert_array_equal(dn, np.asarray(dj))
