import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# Minimal deterministic `hypothesis` shim.  The container has no hypothesis
# wheel and installing one is not allowed; the property tests only use
# given/settings + four strategies, so provide a seeded-sweep stand-in that
# keeps them running (each @given test executes max_examples deterministic
# draws).  If the real hypothesis is installed it is used untouched.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_at(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                for i in range(n):
                    rng = random.Random(f"{fn.__module__}.{fn.__name__}:{i}")
                    drawn = {k: s.example_at(rng)
                             for k, s in sorted(strategies.items())}
                    fn(*args, **drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._hypothesis_stub = True
            return wrapper
        return deco

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.floats = floats
    _st.booleans = booleans
    _st.sampled_from = sampled_from
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
