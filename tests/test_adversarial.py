"""Adversarial robustness suite: attack injection x Byzantine-robust
defenses, plus the self-defending control plane (norm-gate screening,
reputation-weighted combines, heartbeat liveness, reputation-aware role
rotation).  Everything runs on fixed seeds over the virtual clock — the
matrix must be deterministic, and the defended clean run bit-identical to
the undefended one (screening is pure bookkeeping until something is
actually rejected)."""
import os
import subprocess
import sys
import zlib

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import Federation, scenarios
from repro.api.strategies import get_strategy, list_strategies

pytestmark = pytest.mark.adversarial

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

STACK_STRATEGIES = [n for n in list_strategies()
                    if get_strategy(n).reduction == "stack"]
DEFENSES = ["krum", "multi_krum", "weighted_median",
            "clipped_weighted_trimmed_mean"]


# ---------------------------------------------------------------------------
# Attack x defense matrix (headline deliverable)
# ---------------------------------------------------------------------------

N, ROUNDS, DIM = 10, 5, 8
ATTACKERS = [f"c{i}" for i in (0, 3, 7)]           # 30% adversarial
TARGET = np.linspace(-1.0, 1.0, DIM).astype(np.float32)


def _pull_train(cid, g, r):
    """Contractive honest dynamics: pull the global halfway to TARGET plus
    seeded noise — the attack-free run lands near TARGET, so attacker-induced
    drift is measurable as distance from the clean run."""
    base = g["w"] if g is not None else np.zeros(DIM, np.float32)
    rng = np.random.default_rng(zlib.crc32(f"{cid}/{r}".encode()))
    step = 0.5 * (TARGET - base) + rng.normal(0, 0.05, DIM).astype(np.float32)
    return {"w": (base + step).astype(np.float32)}, 1


def _matrix_run(strategy, events=()):
    fed = Federation(round_deadline_s=10.0)
    cls = [fed.client(f"c{i}") for i in range(N)]
    s = fed.create_session("s", model_name="m", rounds=ROUNDS,
                           participants=cls, strategy=strategy)
    report = scenarios.play(s, _pull_train, events=list(events),
                            rounds=ROUNDS, round_time_s=1.0,
                            initial_params={"w": np.zeros(DIM, np.float32)})
    assert report.final_state == "terminated" and not report.stalled
    return np.asarray(s.global_params()["w"])


_ATTACKS = {
    "scale": lambda: [scenarios.scale_poison(ATTACKERS, lam=20.0)],
    "flip": lambda: [scenarios.label_flip(ATTACKERS, flip_scale=3.0)],
}


@pytest.mark.parametrize("attack", sorted(_ATTACKS))
def test_fedavg_diverges_where_robust_strategies_hold(attack):
    """With 30% attackers, plain fedavg drifts far from its clean run while
    every robust strategy stays within tolerance of its own clean run."""
    fedavg_clean = _matrix_run("fedavg")
    fedavg_attacked = _matrix_run("fedavg", _ATTACKS[attack]())
    fedavg_drift = np.linalg.norm(fedavg_attacked - fedavg_clean)
    assert fedavg_drift > 2.0, f"attack too weak to matter: {fedavg_drift}"

    for strat in DEFENSES:
        clean = _matrix_run(strat)
        attacked = _matrix_run(strat, _ATTACKS[attack]())
        drift = np.linalg.norm(attacked - clean)
        # the defended run must hold near its clean trajectory AND beat
        # fedavg decisively (the scale attack is ~900x; label flips are
        # subtler, ~4.6x for the clipped trimmed mean)
        assert drift < 1.0, f"{strat} drifted {drift} under {attack}"
        assert fedavg_drift > 3 * drift, (strat, attack, fedavg_drift, drift)
        # a defense must not wreck the attack-free objective either
        assert np.linalg.norm(clean - TARGET) < 1.0, (strat, clean)


def test_attacked_runs_are_bit_identical_on_rerun():
    a = _matrix_run("multi_krum", _ATTACKS["scale"]())
    b = _matrix_run("multi_krum", _ATTACKS["scale"]())
    np.testing.assert_array_equal(a, b)
    c = _matrix_run("fedavg", _ATTACKS["flip"]())
    d = _matrix_run("fedavg", _ATTACKS["flip"]())
    np.testing.assert_array_equal(c, d)


def test_defense_screening_is_invisible_on_clean_runs():
    """Turning the defense on must not perturb an attack-free federation:
    same clients, same train fn -> bit-identical global."""
    def run(defense):
        fed = Federation(round_deadline_s=10.0)
        cls = [fed.client(f"c{i}") for i in range(4)]
        s = fed.create_session("s", model_name="m", rounds=3,
                               participants=cls, defense=defense)
        scenarios.play(s, _pull_train, rounds=3, round_time_s=1.0,
                       initial_params={"w": np.zeros(DIM, np.float32)})
        return np.asarray(s.global_params()["w"])
    np.testing.assert_array_equal(run(None), run(True))


# ---------------------------------------------------------------------------
# Self-defending control plane (acceptance scenario)
# ---------------------------------------------------------------------------

def _sybil_scenario(defense):
    """6 clients, 2-level tree, reputation-aware rotation; round 0's first
    cluster head turns scale-poisoner at round 1, a 3-sybil flood joins at
    round 2.  Returns (fed, session, attacker, per-round global deltas)."""
    def train(cid, g, r):
        base = g["w"] if g is not None else np.zeros(4, np.float32)
        return {"w": base + np.float32(1.0)}, 1

    fed = Federation(metrics=True, role_policy="reputation_aware",
                     levels=2, aggregator_ratio=0.4, round_deadline_s=5.0)
    cls = [fed.client(f"c{i}") for i in range(6)]
    s = fed.create_session("s", model_name="m", rounds=6, participants=cls,
                           defense=defense, capacity=(6, 12))
    s.start()                       # capacity'd session: promote at quorum
    heads0 = {c for c, a in fed.coordinator.assignments["s"].items()
              if a.duties}
    attacker = sorted(heads0)[0]

    deltas = []
    last = [np.zeros(4, np.float32)]

    def on_update(p, v):
        deltas.append(float(np.mean(np.asarray(p["w"]) - last[0])))
        last[0] = np.asarray(p["w"]).copy()
    s.on_global_update = on_update

    report = scenarios.play(
        s, train,
        events=[scenarios.scale_poison([attacker], lam=80.0, start_round=1),
                scenarios.sybil_flood(count=3, at_round=2, lam=40.0)],
        rounds=6, round_time_s=1.0,
        initial_params={"w": np.zeros(4, np.float32)})
    assert report.final_state == "terminated" and not report.stalled
    return fed, s, attacker, deltas


def test_poisoned_head_plus_sybil_flood_is_demoted_and_reconverges():
    """A poisoned cluster head + a sybil join flood: the norm gate rejects
    the attacker's partials, reputation penalties quarantine it, the
    reputation-aware policy rotates it out of aggregator duty, sybils join
    but are quarantined — and the defended federation keeps advancing at
    roughly the honest +1/round where the undefended one is swamped."""
    fed, s, attacker, deltas = _sybil_scenario(
        dict(norm_warmup=2, norm_gate_mult=3.0))

    book = fed.coordinator.books["s"]
    cfg = fed.coordinator.sessions["s"].defense_cfg
    # the attacker fell below the quarantine line...
    assert book.score(attacker) < cfg["demote_below"]
    # ...and out of the aggregator set
    heads_final = {c for c, a in fed.coordinator.assignments["s"].items()
                   if a.duties}
    assert attacker not in heads_final
    assert fed.coordinator.roles_rotations > 0
    # sybils were admitted through the elastic-join path, then quarantined
    sybils = [c for c in s.contributors() if c.startswith("sybil")]
    assert sybils, "sybil flood never joined"
    assert any(book.quarantined(c) for c in sybils)

    # trace timeline, in virtual-time order: the attack lands, then updates
    # are rejected, and for at least one malicious identity a rotation
    # demotes it *after* its own rejection (the poisoned head is often
    # already out of duty via benign moving-target rotation before its
    # attack starts, but sybils join trusted, get promoted, get caught and
    # are rotated out — closing the attack->reject->rotate loop).
    ev = fed.obs.tracer.events
    rejected_at = {}
    for e in ev("update_rejected"):
        rejected_at.setdefault(e["client"], e["t"])
    t_attack = min(e["t"] for e in ev("attack_injected"))
    assert t_attack <= min(rejected_at.values())
    assert any(attacker in e["demoted"] for e in ev("role_rotated"))
    closed = [(c, e["t"]) for e in ev("role_rotated") for c in e["demoted"]
              if c in rejected_at and e["t"] >= rejected_at[c]]
    assert closed, (rejected_at, ev("role_rotated"))

    # reconvergence: most defended rounds advance at the honest +1/round
    # (one cold-norm-gate leak is tolerated), and the defended trajectory
    # ends an order of magnitude closer to honest than the undefended one
    assert sum(abs(d - 1.0) < 0.6 for d in deltas) >= 4, deltas
    fed_off, s_off, _, deltas_off = _sybil_scenario(None)
    final_on = float(np.mean(s.global_params()["w"]))
    final_off = float(np.mean(s_off.global_params()["w"]))
    assert final_on < 0.25 * final_off, (final_on, final_off)
    assert np.median(deltas) < 2.0 < np.median(deltas_off)


def test_heartbeat_liveness_penalizes_silent_client():
    """A participant that stops heartbeating (without a clean leave) is
    caught by the coordinator's liveness sweep and penalized; clients that
    keep beating are not."""
    fed = Federation(metrics=True)
    cls = [fed.client(f"c{i}") for i in range(4)]
    s = fed.create_session("s", model_name="m", rounds=4, participants=cls,
                           defense=dict(heartbeat_period_s=0.2,
                                        liveness_misses=2))
    muted = "c3"
    # mute it: dropping it from the facade map stops its armed heartbeat
    # series while the coordinator still expects beats from a contributor
    s.participants.pop(muted)
    fed.clock.advance(5.0)

    book = fed.coordinator.books["s"]
    assert book.score(muted) < 1.0
    misses = fed.obs.tracer.events("heartbeat_miss")
    assert any(e["client"] == muted for e in misses)
    for i in range(3):                      # live clients kept beating
        assert book.score(f"c{i}") == 1.0


# ---------------------------------------------------------------------------
# Free-riders
# ---------------------------------------------------------------------------

def _run_free_rider(events, rounds=4, n=3):
    fed = Federation(round_deadline_s=10.0)
    cls = [fed.client(f"c{i}") for i in range(n)]
    s = fed.create_session("s", model_name="m", rounds=rounds,
                           participants=cls)
    seen = []
    s.on_global_update = lambda p, v: seen.append(np.asarray(p["w"]).copy())
    scenarios.play(s, lambda cid, g, r:
                   ({"w": (g["w"] if g is not None
                           else np.zeros(2, np.float32)) + np.float32(1.0)},
                    1),
                   events=list(events), rounds=rounds, round_time_s=1.0,
                   initial_params={"w": np.zeros(2, np.float32)})
    return seen


def test_free_rider_zero_drags_the_global():
    """A zero free-rider republishes the current global: with 1/3 riders
    the per-round gain drops from +1 to exactly +2/3."""
    honest = _run_free_rider([])
    ridden = _run_free_rider([scenarios.free_rider(["c0"], mode="zero")])
    np.testing.assert_allclose(honest[-1], np.full(2, 4.0), rtol=1e-6)
    np.testing.assert_allclose(ridden[-1], np.full(2, 4 * 2 / 3), rtol=1e-5)


def test_free_rider_replay_trains_once_then_replays():
    """Replay mode contributes a genuine update in its first active round
    (identical round-0 global) and the stale copy forever after (strictly
    smaller later globals)."""
    honest = _run_free_rider([])
    replay = _run_free_rider([scenarios.free_rider(["c0"], mode="replay")])
    np.testing.assert_allclose(replay[0], honest[0], rtol=1e-6)
    assert np.all(replay[-1] < honest[-1])
    assert np.all(np.isfinite(replay[-1]))


# ---------------------------------------------------------------------------
# combine_masked edge cases — every registered stack strategy
# ---------------------------------------------------------------------------

def _stacked(rng, n):
    return {"w": rng.normal(size=(n, 5, 3)).astype(np.float32),
            "b": rng.normal(size=(n, 4)).astype(np.float32)}


@settings(max_examples=15 * len(STACK_STRATEGIES), deadline=None)
@given(name=st.sampled_from(STACK_STRATEGIES),
       seed=st.integers(0, 2**31 - 1), n_live=st.integers(1, 6))
def test_combine_masked_matches_live_subset_oracle(name, seed, n_live):
    """Zero-weight (dead/churned) rows must not shift the statistic:
    combine_masked over the full stack == combine over just the live rows,
    for every registered stack strategy."""
    n = 6
    rng = np.random.default_rng(seed)
    stacked = _stacked(rng, n)
    live = sorted(rng.choice(n, size=n_live, replace=False).tolist())
    weights = np.zeros(n)
    weights[live] = rng.uniform(0.5, 3.0, size=n_live)
    # dead rows carry garbage that would dominate any statistic it leaks into
    for leaf in stacked.values():
        for i in range(n):
            if i not in live:
                leaf[i] = 1e6

    strat = get_strategy(name)
    got = strat.combine_masked(stacked, weights, np)
    want = strat.combine({k: v[live] for k, v in stacked.items()},
                         weights[live], np)
    for k in stacked:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=1e-6, err_msg=(name, k))


@pytest.mark.parametrize("name", STACK_STRATEGIES)
def test_combine_masked_all_dead_and_single_live(name):
    strat = get_strategy(name)
    rng = np.random.default_rng(7)
    stacked = _stacked(rng, 4)
    # weights sum to zero (all dead): finite output, no NaN/Inf blowup
    out = strat.combine_masked(stacked, np.zeros(4), np)
    for k in stacked:
        assert np.isfinite(np.asarray(out[k])).all(), (name, k)
    # a single live row passes through exactly
    w = np.zeros(4)
    w[2] = 1.7
    out = strat.combine_masked(stacked, w, np)
    for k in stacked:
        np.testing.assert_allclose(np.asarray(out[k]), stacked[k][2],
                                   rtol=1e-6, err_msg=(name, k))


# ---------------------------------------------------------------------------
# Host path == compiled shard_map path for every defense strategy
# ---------------------------------------------------------------------------

def run_sub(code, devices=8, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=ROOT)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_defense_strategies_identical_on_compiled_path():
    """The compiled shard_map data plane must agree with the numpy host
    reference for every defense strategy — including the shard-local premap
    (norm clipping) that runs before the all_gather on the stack path, and
    dead-row masking at zero weight."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.api.strategies import get_strategy
from repro.core.aggregation import aggregate_params
from repro.core.topology import flat_schedule

mesh = jax.make_mesh((4, 2), ("data", "model"))
n = 4
rng = np.random.default_rng(7)
pw = rng.normal(size=(n, 8, 6)).astype(np.float32)
pb = rng.normal(size=(n, 5)).astype(np.float32)
pw[3] = 50.0 * rng.normal(size=(8, 6)).astype(np.float32)  # dead garbage row
pb[3] = -50.0 * np.ones(5, np.float32)
params = {"w": jnp.asarray(pw), "b": jnp.asarray(pb)}
specs = {"w": P("data", None, None), "b": P("data", None)}
weights = jnp.asarray([1.0, 2.0, 3.0, 0.0])
rw = rng.normal(size=(8, 6)).astype(np.float32)
rb = rng.normal(size=(5,)).astype(np.float32)
ref = {"w": jnp.asarray(np.broadcast_to(rw, (n, 8, 6)).copy()),
       "b": jnp.asarray(np.broadcast_to(rb, (n, 5)).copy())}
sched = flat_schedule(n)
wv = np.asarray(weights, np.float64)

for name in ("krum", "multi_krum", "weighted_trimmed_mean",
             "weighted_median", "clipped_weighted_trimmed_mean",
             "norm_clip"):
    strat = get_strategy(name)
    with mesh:
        out = jax.jit(lambda p, w, r: aggregate_params(
            p, w, mesh, "data", sched, specs, strategy=name,
            ref_params=r if strat.needs_ref else None))(params, weights, ref)
    rows_w, rows_b = [], []
    for i in range(n):
        pi = {"w": pw[i], "b": pb[i]}
        if strat.needs_ref:
            pi = strat.premap(pi, {"w": rw, "b": rb}, np)
        rows_w.append(np.asarray(pi["w"], np.float32))
        rows_b.append(np.asarray(pi["b"], np.float32))
    sw, sb = np.stack(rows_w), np.stack(rows_b)
    if strat.reduction == "stack":
        want = strat.combine_masked({"w": sw, "b": sb}, wv, np)
        want_w, want_b = np.asarray(want["w"]), np.asarray(want["b"])
    else:
        want_w = (sw * wv[:, None, None]).sum(0) / wv.sum()
        want_b = (sb * wv[:, None]).sum(0) / wv.sum()
    for i in range(n):
        np.testing.assert_allclose(np.asarray(out["w"])[i], want_w,
                                   rtol=2e-5, atol=1e-5, err_msg=name)
        np.testing.assert_allclose(np.asarray(out["b"])[i], want_b,
                                   rtol=2e-5, atol=1e-5, err_msg=name)
print("COMPILED DEFENSE PARITY OK")
""")
    assert "COMPILED DEFENSE PARITY OK" in out


# ---------------------------------------------------------------------------
# flaky_link list/pair forms (satellite)
# ---------------------------------------------------------------------------

def test_flaky_link_accepts_client_lists_and_pairs():
    from repro.api.scenarios import _link_endpoints
    ev = scenarios.flaky_link(["c0", "c1", "c0"], dup_p=0.5)
    assert _link_endpoints(ev.clients) == ["c0", "c1"]   # deduped, ordered
    ev2 = scenarios.flaky_link([("a", "b"), ("b", "c")], p=0.1)
    assert _link_endpoints(ev2.clients) == ["a", "b", "c"]
    ev3 = scenarios.flaky_link("solo", jitter_s=0.01)
    assert _link_endpoints(ev3.clients) == ["solo"]


def test_flaky_link_list_degrades_every_listed_client():
    """One list-form flaky_link event must dup traffic on every listed
    client's link, and restore them all at t1."""
    fed = Federation(latency=dict(delay_s=0.01, seed=3))
    cls = [fed.client(f"c{i}") for i in range(4)]
    s = fed.create_session("s", model_name="m", rounds=3, participants=cls)
    scenarios.play(
        s, lambda cid, g, r: ({"w": np.ones(3, np.float32)}, 1),
        events=[scenarios.flaky_link(["c0", "c1", "c2"], dup_p=0.9,
                                     t0=0.5)],
        rounds=3, round_time_s=1.0)
    links = fed.transport.sys_stats()["links"]
    for cid in ("c0", "c1", "c2"):
        assert links[cid]["duplicates"] > 0, (cid, links[cid])
    assert np.isfinite(s.global_params()["w"]).all()
