"""Real-MQTT backend tests: the bundled mini-broker speaking actual MQTT
3.1.1 over TCP, PahoTransport's connection pool / delivery bridge /
flush-barrier quiescence, and the whole Federation stack (sync, robust
strategies, LWT failure detection, async FedBuff sessions, multi-part
model frames) running unchanged over real sockets.

Everything here is hermetic — the mini-broker binds an ephemeral port on
127.0.0.1 — and runs on the builtin stdlib client, so no optional wheel is
required.  Backend parity with paho itself is covered by the conformance
suite's ``mqtt-paho`` leg when the ``repro[mqtt]`` extra is installed.
"""
import socket
import struct

import numpy as np
import pytest

from repro.api import Federation
from repro.api.mini_broker import (CONNACK, SUBACK, MiniBroker,
                                   encode_utf8, packet, publish_packet)
from repro.api.mqtt_transport import PahoTransport
from repro.core.broker import Message

pytestmark = pytest.mark.mqtt


@pytest.fixture
def broker():
    b = MiniBroker(port=0).start()
    yield b
    b.stop()


@pytest.fixture
def transport(broker):
    t = PahoTransport(port=broker.port, backend="builtin")
    yield t
    t.close()


def mqtt_federation(broker, **kw):
    return Federation(transport=PahoTransport(port=broker.port,
                                              backend="builtin"), **kw)


# ---------------------------------------------------------------------------
# the mini-broker speaks real MQTT over a real socket
# ---------------------------------------------------------------------------

def _read_pkt(f):
    first = f.read(1)[0]
    length, mult = 0, 1
    while True:
        b = f.read(1)[0]
        length += (b & 0x7F) * mult
        if not b & 0x80:
            break
        mult *= 128
    return first >> 4, first & 0x0F, f.read(length)


def test_minibroker_raw_socket_handshake(broker):
    """A hand-rolled socket (no repro client code at all) can CONNECT,
    SUBSCRIBE, PUBLISH, and get the message echoed back — proof the broker
    speaks the actual wire protocol, not an in-process shortcut."""
    s = socket.create_connection(("127.0.0.1", broker.port), timeout=5)
    f = s.makefile("rb")
    connect = (encode_utf8("MQTT") + b"\x04" + b"\x02" + b"\x00\x00"
               + encode_utf8("raw-client"))
    s.sendall(packet(1, 0, connect))                     # CONNECT
    ptype, _, body = _read_pkt(f)
    assert (ptype, body) == (CONNACK, b"\x00\x00")       # accepted, rc=0
    sub = struct.pack(">H", 1) + encode_utf8("raw/t") + b"\x01"
    s.sendall(packet(8, 0x02, sub))                      # SUBSCRIBE
    ptype, _, body = _read_pkt(f)
    assert ptype == SUBACK and body == b"\x00\x01\x01"   # granted qos 1
    s.sendall(publish_packet("raw/t", b"ping", qos=0))
    ptype, flags, body = _read_pkt(f)                    # echoed PUBLISH
    assert ptype == 3 and body.endswith(b"ping")
    s.close()


def test_session_takeover_fires_old_will(transport):
    """[MQTT-3.1.4-2]: a second CONNECT with the same client id closes the
    first connection as a network failure, so its LWT is published."""
    got = []
    transport.connect("watch", lambda m: got.append(bytes(m.payload)))
    transport.subscribe("watch", "w/+", qos=1)
    first = PahoTransport(port=transport.port, backend="builtin")
    first.connect("dup", lambda m: None,
                  will=Message("w/dup", b"taken-over", qos=1))
    transport.connect("dup", lambda m: None)     # same id, second transport
    transport.settle()
    assert got == [b"taken-over"]
    first.close()


def test_multipart_frames_reassemble_over_wire(broker):
    """A model payload far above max_batch_bytes crosses the real socket
    as many MQTT PUBLISHes and reassembles bit-exactly."""
    from repro.core.mqttfc import MQTTFC
    t = PahoTransport(port=broker.port, backend="builtin")
    rx = MQTTFC(t, "rx", max_batch_bytes=2048)
    tx = MQTTFC(t, "tx", max_batch_bytes=2048)
    got = []
    rx.bind("sdflmq/model", lambda payload: got.append(payload), qos=1)
    big = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64)}
    tx.call("sdflmq/model", big)
    t.settle()
    assert tx.parts_sent > 1
    assert len(got) == 1
    np.testing.assert_array_equal(np.asarray(got[0]["w"]), big["w"])
    t.close()


# ---------------------------------------------------------------------------
# the Federation facade, unchanged, over real sockets
# ---------------------------------------------------------------------------

def step(cid, g, rnd, dim=4):
    base = g["w"] if g is not None else np.zeros(dim, np.float32)
    i = int(cid[1:])
    return {"w": base + np.float32(i + 1) * np.float32(0.5 + rnd)}, i + 1


def test_federation_bit_identical_to_simbroker(broker):
    """Acceptance: the same workload reaches the same final fedavg global
    — bit-identical — on SimBroker and on PahoTransport+mini-broker."""
    def run(fed):
        clients = [fed.client(f"c{i}") for i in range(5)]
        s = fed.create_session("s1", model_name="m", rounds=3,
                               participants=clients, strategy="fedavg")
        s.run(step, initial_params={"w": np.zeros(4, np.float32)})
        out = s.global_params()["w"]
        fed.close()
        return out, s.global_version()

    sim, sim_v = run(Federation())
    mqtt, mqtt_v = run(mqtt_federation(broker))
    assert sim_v == mqtt_v == 3
    assert sim.dtype == mqtt.dtype
    np.testing.assert_array_equal(sim, mqtt)


def test_robust_strategy_over_mqtt(broker):
    """trimmed_mean (a stack-reduction strategy: rows ride TensorStacks up
    the tree) survives the real-network path and drops the poisoned row."""
    fed = mqtt_federation(broker)
    clients = [fed.client(f"c{i}") for i in range(5)]
    s = fed.create_session("s1", model_name="m", rounds=1,
                           participants=clients, strategy="trimmed_mean")
    vals = {f"c{i}": float(i) for i in range(4)} | {"c4": 1e6}  # poisoned
    s.run_round(lambda cid, g, r: ({"w": np.full(3, vals[cid],
                                               np.float32)}, 1))
    w = s.global_params()["w"]
    assert w.max() < 10.0, "poisoned client leaked through trimmed_mean"
    fed.close()


def test_lwt_failure_detection_over_mqtt(broker):
    """An abrupt socket death (no DISCONNECT) reaches the coordinator as a
    broker-published LWT; the tree rearranges and the round completes with
    the live set."""
    fed = mqtt_federation(broker)
    clients = [fed.client(f"c{i}") for i in range(6)]
    s = fed.create_session("s1", model_name="m", rounds=2,
                           participants=clients)
    params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
              for i in range(6)}
    s.fail("c5")
    fed.deliver()
    assert "c5" not in s.contributors()
    s.run_round(lambda cid, g, r: (params[cid], 1))
    want = np.mean([params[f"c{i}"]["w"] for i in range(5)], axis=0)
    np.testing.assert_allclose(s.global_params()["w"], want, rtol=1e-5)
    fed.close()


def test_elastic_join_over_mqtt(broker):
    fed = mqtt_federation(broker)
    clients = [fed.client(f"c{i}") for i in range(4)]
    s = fed.create_session("s1", model_name="m", rounds=3,
                           participants=clients, capacity=(4, 8))
    assert s.state == "waiting"          # headroom left for elastic joins
    assert s.start()                     # waiting time elapsed: quorum ok
    p = {"w": np.ones(3, np.float32)}
    s.run_round(lambda cid, g, r: (p, 1))
    late = fed.client("late")
    assert s.join(late)
    assert "late" in s.contributors()
    s.run_round(lambda cid, g, r: (p, 1))
    np.testing.assert_allclose(s.global_params()["w"], 1.0)
    fed.close()


def test_latency_model_composes_with_real_transport(broker):
    """LatencyTransport's virtual-time outbound model stacks on top of the
    real network: modeled per-link delays are observed on the shared clock
    while frames genuinely cross TCP."""
    fed = mqtt_federation(broker, latency=dict(delay_s=0.010))
    clients = [fed.client(f"c{i}") for i in range(3)]
    s = fed.create_session("s1", model_name="m", rounds=1,
                           participants=clients)
    s.run_round(step)
    assert s.global_version() == 1
    assert fed.clock.now > 0.0          # virtual latency genuinely modeled
    fed.close()


def test_async_fedbuff_session_over_mqtt(broker):
    """The async-FL subsystem (paced clients, K-of-N FedBuff admission on
    virtual time) drives its event loop over the real transport."""
    fed = mqtt_federation(broker)
    clients = [fed.client(f"c{i}") for i in range(4)]
    s = fed.create_session(
        "s1", model_name="m", rounds=3, participants=clients,
        async_mode=dict(buffer_k=4, base_period_s=1.0))
    report = s.run_async(step, max_time_s=300.0,
                         initial_params={"w": np.zeros(4, np.float32)})
    assert s.global_version() >= 3
    assert report.timeline                # versions minted on virtual time
    fed.close()


def test_reconnect_does_not_inherit_undispatched_inbox(transport):
    """A clean-session reconnect must not receive frames that arrived for
    the OLD session but were never dispatched before the takeover."""
    import time
    transport.connect("pub", lambda m: None)
    got_old, got_new = [], []
    transport.connect("node", lambda m: got_old.append(bytes(m.payload)))
    transport.subscribe("node", "sdflmq/rc", qos=1)
    transport.publish("sdflmq/rc", b"pre-reconnect", qos=1, sender="pub")
    deadline = time.monotonic() + 5.0
    while transport.sys_stats()["pending_dispatch"] == 0:   # in inbox,
        assert time.monotonic() < deadline                  # undispatched
        time.sleep(0.005)
    transport.connect("node", lambda m: got_new.append(bytes(m.payload)))
    transport.settle()
    assert got_old == [] and got_new == []


def test_builtin_client_honors_keepalive_with_pings(broker):
    """With keepalive_s > 0 the builtin client must heartbeat (a real
    broker would otherwise drop the idle connection and fire its LWT)."""
    import time
    t = PahoTransport(port=broker.port, backend="builtin", keepalive_s=1)
    t.connect("idle-node", lambda m: None)
    deadline = time.monotonic() + 5.0
    while broker.pings == 0 and time.monotonic() < deadline:
        time.sleep(0.05)
    assert broker.pings >= 1, "no PINGREQ within the keepalive interval"
    t.close()


def test_wire_stats_and_broker_stats_surface(broker):
    fed = mqtt_federation(broker)
    clients = [fed.client(f"c{i}") for i in range(3)]
    s = fed.create_session("s1", model_name="m", rounds=1,
                           participants=clients)
    s.run_round(step)
    stats = fed.broker.sys_stats()
    assert stats["publishes"] > 0 and stats["pending_dispatch"] == 0
    assert stats["barrier_supported"] is True
    bstats = broker.sys_stats()
    assert bstats["messages_sent"] > 0
    fed.close()


# ---------------------------------------------------------------------------
# survival: reconnect/backoff, session resumption, concurrency
# ---------------------------------------------------------------------------

def test_builtin_client_threaded_publish_stress(broker):
    """Packet-id allocation and the ack/inflight tables are shared across
    publisher threads: hammering one endpoint from many threads must
    neither collide on packet ids nor lose a single QoS-1 message."""
    import threading
    t = PahoTransport(port=broker.port, backend="builtin")
    got = []
    t.connect("rx", lambda m: got.append(bytes(m.payload)))
    t.subscribe("rx", "sdflmq/stress", qos=1)
    t.connect("tx", lambda m: None)
    n, workers = 50, 8

    def pump(k):
        for i in range(n):
            t.publish("sdflmq/stress", f"{k}:{i:02d}".encode(), qos=1,
                      sender="tx")

    threads = [threading.Thread(target=pump, args=(k,))
               for k in range(workers)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert t.settle()
    want = sorted(f"{k}:{i:02d}".encode()
                  for k in range(workers) for i in range(n))
    assert sorted(got) == want
    assert t.sys_stats()["send_failures"] == 0
    t.close()


def test_builtin_client_reconnects_after_broker_restart(broker):
    """clean_session=False turns reconnect on ("auto"): after the broker
    dies and comes back, both endpoints re-dial under bounded backoff, the
    subscriber re-subscribes on its own (the restarted broker reports no
    session), and traffic flows again."""
    import time
    t = PahoTransport(port=broker.port, backend="builtin",
                      clean_session=False, backoff_base_s=0.02,
                      backoff_max_s=0.25)
    assert t.reconnect_enabled
    got = []
    t.connect("rx", lambda m: got.append(bytes(m.payload)))
    t.subscribe("rx", "sdflmq/surv", qos=1)
    t.connect("tx", lambda m: None)
    t.publish("sdflmq/surv", b"before", qos=1, sender="tx")
    assert t.settle()
    assert got == [b"before"]
    broker.kill()
    broker.start()
    deadline = time.monotonic() + 10.0
    while t.reconnects < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    st = t.sys_stats()
    assert st["connection_drops"] >= 2 and st["reconnects"] >= 2
    assert st["reconnect_failures"] == 0
    t.publish("sdflmq/surv", b"after", qos=1, sender="tx")
    assert t.settle()
    assert got == [b"before", b"after"]
    t.close()


def test_reconnect_off_by_default_for_clean_sessions(transport):
    """The default transport (clean sessions) keeps the old semantics:
    a lost connection stays lost — no resurrection behind the session
    takeover rule's back."""
    assert transport.clean_session is True
    assert transport.reconnect_enabled is False


def test_publish_while_broker_down_is_retransmitted(broker):
    """A QoS-1 publish attempted DURING the outage parks in the in-flight
    window and replays (DUP) once the broker is back — the at-least-once
    contract spans the outage."""
    import time
    t = PahoTransport(port=broker.port, backend="builtin",
                      clean_session=False, backoff_base_s=0.02,
                      backoff_max_s=0.25)
    got = []
    t.connect("rx", lambda m: got.append(bytes(m.payload)))
    t.subscribe("rx", "sdflmq/outage", qos=1)
    t.connect("tx", lambda m: None)
    t.settle()
    broker.kill()
    time.sleep(0.05)                    # let the reader threads notice
    t.publish("sdflmq/outage", b"queued-in-window", qos=1, sender="tx")
    broker.start()
    deadline = time.monotonic() + 10.0
    while not got and time.monotonic() < deadline:
        t.settle(block=False)           # drain whatever has arrived
        time.sleep(0.01)
    assert got == [b"queued-in-window"]
    assert t.sys_stats()["reconnects"] >= 2
    t.close()


def test_minibroker_redelivers_unacked_qos1_with_dup(broker):
    """Raw-socket persistent session: a PUBLISH the client never PUBACKed
    is redelivered on resume with the DUP flag and the SAME packet id
    [MQTT-4.4.0-1], and the CONNACK reports session-present."""
    def dial(clean):
        s = socket.create_connection(("127.0.0.1", broker.port), timeout=5)
        f = s.makefile("rb")
        flags = 0x02 if clean else 0x00
        body = (encode_utf8("MQTT") + b"\x04" + bytes((flags,))
                + b"\x00\x00" + encode_utf8("dur-raw"))
        s.sendall(packet(1, 0, body))
        ptype, _, ack = _read_pkt(f)
        assert ptype == CONNACK and ack[1] == 0
        return s, f, ack[0] & 0x01

    s, f, present = dial(clean=False)
    assert present == 0
    sub = struct.pack(">H", 1) + encode_utf8("raw/dur") + b"\x01"
    s.sendall(packet(8, 0x02, sub))
    assert _read_pkt(f)[0] == SUBACK
    pub = socket.create_connection(("127.0.0.1", broker.port), timeout=5)
    pf = pub.makefile("rb")
    pub.sendall(packet(1, 0, encode_utf8("MQTT") + b"\x04\x02\x00\x00"
                       + encode_utf8("pub-raw")))
    assert _read_pkt(pf)[0] == CONNACK
    pub.sendall(publish_packet("raw/dur", b"must-arrive", qos=1, mid=9))

    def parse_pub(flags, body):
        tlen = int.from_bytes(body[:2], "big")
        mid = int.from_bytes(body[2 + tlen:4 + tlen], "big")
        return bool(flags & 0x08), mid, body[4 + tlen:]

    ptype, flags, body = _read_pkt(f)
    assert ptype == 3
    dup, mid1, payload = parse_pub(flags, body)
    assert (dup, payload) == (False, b"must-arrive")
    s.close()                                   # die without PUBACK
    s2, f2, present = dial(clean=False)
    assert present == 1                         # session survived
    ptype, flags, body = _read_pkt(f2)
    assert ptype == 3
    dup, mid2, payload = parse_pub(flags, body)
    assert dup is True and mid2 == mid1 and payload == b"must-arrive"
    # acking it settles the redelivery: a THIRD resume is silent
    s2.sendall(packet(4, 0, mid2.to_bytes(2, "big")))
    s2.sendall(packet(14, 0))                   # graceful DISCONNECT
    s2.close()
    s3, f3, present = dial(clean=False)
    assert present == 1
    s3.settimeout(0.3)
    import pytest as _pytest
    with _pytest.raises((TimeoutError, socket.timeout)):
        _read_pkt(f3)
    s3.close()
    pub.close()
