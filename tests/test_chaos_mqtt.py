"""Chaos wall for the real-MQTT survival path (the network WILL fail).

Three attack surfaces, one invariant — the federation reconverges to the
bit-identical global it would have computed on a healthy network:

  * broker death mid-round: the mini-broker is killed (socket aborts, no
    DISCONNECTs — SIGKILL semantics) while a round is training, restarted,
    and every endpoint must rejoin on its own under bounded backoff, with
    QoS-1 retransmission replaying whatever the outage swallowed,
  * a genuine ``SIGKILL`` of a broker *subprocess*, for the avoidance of
    any in-process shortcuts,
  * at-least-once duplication: a link that redelivers QoS-1 frames
    (``dup_p``) must not double-count any contribution — receiver-side
    dedup drops the replays and the accumulators admit each client once.

Everything is hermetic (ephemeral ports on 127.0.0.1, builtin client).
Train values are dyadic rationals, so float sums are exact and
order-independent — bit-identity is a meaningful assertion even when
reconnects reorder arrivals.
"""
import os
import signal
import socket
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.api import Federation
from repro.api.mini_broker import MiniBroker
from repro.api.mqtt_transport import PahoTransport

pytestmark = pytest.mark.mqtt


def step(cid, g, rnd, dim=4):
    base = g["w"] if g is not None else np.zeros(dim, np.float32)
    i = int(cid[1:])
    return {"w": base + np.float32(i + 1) * np.float32(0.5 + rnd)}, i + 1


def survivor_transport(port, **kw):
    """The deployment-recommended survival config: persistent sessions
    (which auto-enables reconnect) + fast bounded backoff for tests."""
    kw.setdefault("backoff_base_s", 0.02)
    kw.setdefault("backoff_max_s", 0.25)
    return PahoTransport(port=port, backend="builtin",
                         clean_session=False, **kw)


def run_reference(n_clients=5, rounds=3):
    """The uninterrupted run every chaos leg must reproduce bit-exactly."""
    broker = MiniBroker(port=0).start()
    fed = Federation(transport=survivor_transport(broker.port))
    clients = [fed.client(f"c{i}") for i in range(n_clients)]
    s = fed.create_session("s1", model_name="m", rounds=rounds,
                           participants=clients, strategy="fedavg")
    s.run(step, initial_params={"w": np.zeros(4, np.float32)})
    out = np.array(s.global_params()["w"])
    v = s.global_version()
    fed.close()
    broker.stop()
    return out, v


def test_broker_kill_mid_round_reconverges():
    """Kill the broker while round 2 is training; every endpoint must
    reconnect under bounded backoff, the round must complete (QoS-1
    retransmission), and the final global must be bit-identical to the
    uninterrupted run."""
    want, want_v = run_reference()

    broker = MiniBroker(port=0).start()
    t = survivor_transport(broker.port)
    fed = Federation(transport=t)
    clients = [fed.client(f"c{i}") for i in range(5)]
    s = fed.create_session("s1", model_name="m", rounds=3,
                           participants=clients, strategy="fedavg")
    killed = []

    def chaos_step(cid, g, rnd):
        if rnd == 1 and not killed:
            # first trainer of round 2: the round has started, nothing of
            # it has hit the wire yet — then the broker dies and comes
            # back empty (in-memory sessions do not survive a SIGKILL)
            killed.append(True)
            broker.kill()
            broker.start()
        return step(cid, g, rnd)

    s.run(chaos_step, initial_params={"w": np.zeros(4, np.float32)})
    assert killed, "chaos hook never fired"
    st = t.sys_stats()
    assert st["reconnect_enabled"] is True
    assert st["connection_drops"] >= 1, "nobody noticed the broker die"
    assert st["reconnects"] >= st["connection_drops"]
    assert st["reconnect_failures"] == 0
    assert s.global_version() == want_v
    got = np.array(s.global_params()["w"])
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(got, want)
    fed.close()
    broker.stop()


def _wait_port(port, deadline_s=10.0):
    deadline = time.monotonic() + deadline_s
    while time.monotonic() < deadline:
        try:
            socket.create_connection(("127.0.0.1", port), timeout=0.2).close()
            return
        except OSError:
            time.sleep(0.02)
    raise RuntimeError(f"broker on :{port} never came up")


def _spawn_broker(port):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.api.mini_broker", "--port", str(port)],
        env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    _wait_port(port)
    return proc


def test_broker_subprocess_sigkill_mid_round_reconverges():
    """The same invariant against a broker in a separate PROCESS, killed
    with an actual ``SIGKILL`` — no in-process shortcut can soften this."""
    want, want_v = run_reference()

    with socket.socket() as probe:                  # pick a free port
        probe.bind(("127.0.0.1", 0))
        port = probe.getsockname()[1]
    proc = _spawn_broker(port)
    restarted = []
    try:
        t = survivor_transport(port)
        fed = Federation(transport=t)
        clients = [fed.client(f"c{i}") for i in range(5)]
        s = fed.create_session("s1", model_name="m", rounds=3,
                               participants=clients, strategy="fedavg")

        def chaos_step(cid, g, rnd):
            nonlocal proc
            if rnd == 1 and not restarted:
                restarted.append(True)
                proc.send_signal(signal.SIGKILL)
                proc.wait(timeout=10)
                proc = _spawn_broker(port)
            return step(cid, g, rnd)

        s.run(chaos_step, initial_params={"w": np.zeros(4, np.float32)})
        assert restarted
        st = t.sys_stats()
        assert st["connection_drops"] >= 1 and st["reconnect_failures"] == 0
        assert s.global_version() == want_v
        np.testing.assert_array_equal(np.array(s.global_params()["w"]), want)
        fed.close()
    finally:
        proc.kill()
        proc.wait(timeout=10)


def test_dup_p_duplicates_deduped_bit_identically():
    """Acceptance: under a duplicating link every endpoint's receiver-side
    dedup fires (``duplicate_drops > 0``), each accumulator admits exactly
    the live cohort, and the final global is bit-identical to the clean
    run — duplicates change nothing."""
    def run(dup_p):
        fed = Federation(metrics=True,
                         latency=dict(delay_s=0.002, jitter_s=0.004,
                                      dup_p=dup_p, seed=11))
        clients = [fed.client(f"c{i}") for i in range(5)]
        s = fed.create_session("s1", model_name="m", rounds=3,
                               participants=clients, strategy="fedavg")
        s.run(step, initial_params={"w": np.zeros(4, np.float32)})
        out = np.array(s.global_params()["w"])
        drops = sum(cl.fc.wire_stats()["duplicate_drops"]
                    for cl in fed.clients.values())
        drops += fed.coordinator.fc.wire_stats()["duplicate_drops"]
        dups = sum(link["duplicates"]
                   for link in fed.transport.sys_stats()["links"].values())
        flushes = sorted((e["client"], e["cluster"], e["received"])
                         for e in fed.tracer.events("flush"))
        fed.close()
        return out, drops, dups, flushes

    clean, drops0, dups0, flushes0 = run(0.0)
    dirty, drops1, dups1, flushes1 = run(0.6)
    assert drops0 == 0 and dups0 == 0
    assert dups1 > 0, "the link never injected a duplicate"
    assert drops1 > 0, "duplicates arrived but dedup never fired"
    # accumulator count == live cohort size: every aggregator flushed with
    # exactly the same contribution count as in the duplicate-free run —
    # no flush was triggered early or double-counted by a replayed frame
    assert flushes1 == flushes0 and flushes0
    assert all(n > 0 for _, _, n in flushes0)
    assert clean.dtype == dirty.dtype
    np.testing.assert_array_equal(clean, dirty)
